"""Shared harness for the paper's experimental comparison (§4):
DQGAN vs CPOAdam vs CPOAdam-GQ on synthetic data, with the paper's metric
shape (quality-vs-epoch curves) reproduced via:

  * mode coverage + high-quality-sample fraction on a 2-D Gaussian mixture
  * "synthetic FID": Fréchet distance between real/fake feature statistics
    in a fixed random projection feature space (the offline stand-in for
    Inception features, DESIGN.md §6)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.data import gaussian_mixture_sampler
from repro.models.gan import GANConfig, clip_disc, gan_field_fn, mlp_gan_init, mlp_generate
from repro.strategy import Compression, ExchangePlan, Strategy


# Per-method distribution strategy (single-process: no worker axes) and
# optimizer/message pairing. The paper's baselines are points in the
# strategy lattice; anything schedule/participation-shaped is layered on
# via `strategy_overrides` below.
_SINGLE = ExchangePlan(kind="sim", worker_axes=())
METHOD_STRATEGIES = {
    "CPOAdam": Strategy(compression=Compression(compressor="identity",
                                                error_feedback=False),
                        exchange=_SINGLE),
    "CPOAdam-GQ": Strategy(compression=Compression(error_feedback=False),
                           exchange=_SINGLE),
    "DQGAN": Strategy(exchange=_SINGLE),
    "DQGAN-noEF": Strategy(compression=Compression(error_feedback=False),
                           exchange=_SINGLE),
}
METHODS = {
    # name: (optimizer, message)
    "CPOAdam": ("oadam", "grad"),
    "CPOAdam-GQ": ("oadam", "grad"),
    "DQGAN": ("omd", "update"),
    "DQGAN-noEF": ("omd", "update"),
}


# per-method default LRs ("chosen by an inspection of grid search results",
# paper §4): Adam-family needs a smaller step than plain OMD here.
METHOD_LR = {"CPOAdam": 1e-3, "CPOAdam-GQ": 1e-3, "DQGAN": 3e-3,
             "DQGAN-noEF": 3e-3}


def make_trainer(method: str, cfg: GANConfig, lr: float,
                 dq_overrides: dict | None = None,
                 strategy_overrides: dict | None = None,
                 mesh=None):
    opt, msg = METHODS[method]
    strat = METHOD_STRATEGIES[method]
    if strategy_overrides:
        strat = strat.evolve(**strategy_overrides)
    if mesh is not None and not strat.exchange.worker_axes:
        # multi-worker run (comm_adaptive frontier): the mesh's data axis
        # becomes the paper's M machines
        strat = strat.evolve(worker_axes=("data",))
    # Adam preconditioning normalizes the field-level critic boost away;
    # restore the n_critic=5 ratio post-preconditioning (TTUR).
    mults = (("disc", cfg.disc_grad_mult),) if opt in ("adam", "oadam") else ()
    dq = DQConfig.from_strategy(strat, optimizer=opt, message=msg, lr=lr,
                                lr_mults=mults)
    if dq_overrides:
        import dataclasses
        dq = dataclasses.replace(dq, **dq_overrides)
    batch_spec = None
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        batch_spec = P(("data",))
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
                 batch_spec=batch_spec)


def frechet_distance(feats_a, feats_b):
    """Fréchet distance between Gaussians fit to two feature sets, with a
    diagonal-covariance approximation (stable without scipy sqrtm)."""
    mu_a, mu_b = feats_a.mean(0), feats_b.mean(0)
    va, vb = feats_a.var(0), feats_b.var(0)
    return float(np.sum((mu_a - mu_b) ** 2)
                 + np.sum(va + vb - 2 * np.sqrt(np.maximum(va * vb, 0))))


def random_features(key, x, dim=64):
    """Fixed random 2-layer projection as the stand-in feature extractor."""
    d = x.shape[-1]
    w1 = jax.random.normal(key, (d, 128)) / np.sqrt(d)
    w2 = jax.random.normal(jax.random.fold_in(key, 1), (128, dim)) / np.sqrt(128)
    return np.asarray(jnp.tanh(jnp.tanh(x @ w1) @ w2))


def eval_mixture_gan(params, cfg, sample_real, centers, key, n=2000):
    z = jax.random.normal(key, (n, cfg.latent_dim))
    fake = mlp_generate(params["gen"], cfg, z)
    real = sample_real(jax.random.fold_in(key, 1), n)
    d = jnp.linalg.norm(fake[:, None] - centers[None], axis=-1)
    nearest = jnp.min(d, axis=1)
    assign = jnp.argmin(d, axis=1)
    covered = int((np.bincount(np.asarray(assign), minlength=len(centers))
                   > n * 0.01).sum())
    hq = float(jnp.mean(nearest < 0.25))          # near a mode (5σ)
    fid = frechet_distance(random_features(jax.random.key(123), fake),
                           random_features(jax.random.key(123), real))
    return {"modes": covered, "hq_frac": round(hq, 3),
            "fid": round(fid, 4)}


def train_mixture_gan(method: str, steps=1500, batch=256, lr=None, seed=0,
                      eval_every=0, dq_overrides: dict | None = None,
                      strategy_overrides: dict | None = None,
                      mesh=None):
    """Train the 2-D mixture GAN; `strategy_overrides` patches the
    method's distribution strategy by legacy field name (e.g.
    {"schedule": "delayed", "staleness_tau": 4} for the convergence-vs-
    staleness frontier of `benchmarks.run --only sched`); `dq_overrides`
    patches optimizer-side DQConfig fields. `mesh` runs the workers over
    the mesh's data axis (the comm_adaptive frontier's M machines)."""
    from contextlib import nullcontext

    from repro.parallel.compat import set_mesh

    lr = METHOD_LR.get(method, 1e-3) if lr is None else lr
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128, weight_clip=0.1)
    sample_real, centers = gaussian_mixture_sampler(n_modes=8)
    key = jax.random.key(seed)
    params = mlp_gan_init(key, cfg)
    tr = make_trainer(method, cfg, lr, dq_overrides, strategy_overrides,
                      mesh=mesh)
    with set_mesh(mesh) if mesh is not None else nullcontext():
        st = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,), donate_argnums=0)
        sched = tr.strategy.schedule.runtime()
        curve = []
        for i in range(steps):
            k = jax.random.fold_in(key, i)
            batch_data = {"real": sample_real(k, batch)}
            out = step(st, batch_data, k, sched.is_exchange_step(i))
            st = out.state
            st = st._replace(params=clip_disc(st.params, cfg))
            if eval_every and (i + 1) % eval_every == 0:
                m = eval_mixture_gan(st.params, cfg, sample_real, centers,
                                     jax.random.fold_in(key, 10_000 + i))
                m["step"] = i + 1
                curve.append(m)
        final = eval_mixture_gan(st.params, cfg, sample_real, centers,
                                 jax.random.fold_in(key, 999_999))
    return final, curve, st
