"""Roofline reporting: read experiments/dryrun/*.json and emit the
§Roofline markdown table (per arch × shape × mesh: three terms in seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line
note on what would move the dominant term).

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                                 [--out experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS

NOTES = {
    "compute": ("compute-bound: raise MXU utilization (bf16 everywhere, "
                "larger per-chip tiles, fewer remat recomputes)"),
    "memory": ("memory-bound: cut HBM traffic (fuse elementwise chains, "
               "smaller remat footprint, flash-attention tiles, bf16 "
               "activations)"),
    "collective": ("collective-bound: compress/overlap the exchange "
                   "(DQGAN int8 two-phase, async collectives, reshard to "
                   "cut all-gathers)"),
}


def load(dirpath, tag=""):
    recs = []
    for fn in sorted(os.listdir(dirpath)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dirpath, fn)) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def fmt(x, digits=4):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.2e}"
    return f"{x:.{digits}f}"


def table(recs):
    lines = [
        "| arch | shape | mesh | layout | compute_s | memory_s | "
        "collective_s | bottleneck | MF/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                   "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"],
                                       shape_order.get(r["shape"], 9),
                                       r["mesh"]))
    for r in recs:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | skip | "
                f"skip | skip | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r.get('layout','?')} | ERR | ERR | ERR | — | — | "
                f"{r.get('error','')[:60]} |")
            continue
        rf = r["roofline"]
        chips = r.get("chips", 256)
        if "analytic_flops" in r:
            # useful fraction: parameter-FLOPs share of all modeled compute
            useful = r["mf"] / max(r["analytic_flops"], 1.0)
        else:
            useful = r["mf"] / chips / max(r["flops"], 1.0)
        note = NOTES.get(r["bottleneck"], "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['layout']} | "
            f"{fmt(rf['compute_s'])} | {fmt(rf['memory_s'])} | "
            f"{fmt(rf['collective_s'])} | **{r['bottleneck']}** | "
            f"{useful:.2f} | {note[:58]} |")
    return "\n".join(lines)


def pick_hillclimb_pairs(recs):
    """The three §Perf pairs: worst roofline fraction (most wasteful),
    most collective-bound, and the most technique-representative train run."""
    ok = [r for r in recs if r["status"] == "ok"]

    def waste(r):  # low useful-compute fraction = most wasteful
        if "analytic_flops" in r:
            # roofline fraction: compute term / total time proxy
            rf = r["roofline"]
            tot = max(sum(rf.values()), 1e-12)
            return rf["compute_s"] / tot
        chips = r.get("chips", 256)
        return r["mf"] / chips / max(r["flops"], 1.0)

    worst = min(ok, key=waste, default=None)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(sum(r["roofline"].values()), 1e-12), default=None)
    train = [r for r in ok if r["shape"] == "train_4k"
             and r.get("n_workers", 1) > 1]
    rep = max(train, key=lambda r: r["params"], default=None)
    return {"worst_fraction": worst, "most_collective_bound": coll,
            "technique_representative": rep}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.tag)
    md = [f"# Roofline table ({len(recs)} combos, "
          f"v5e: {PEAK_FLOPS/1e12:.0f} TF/s, {HBM_BW/1e9:.0f} GB/s HBM, "
          f"{ICI_BW/1e9:.0f} GB/s ICI)", "", table(recs), ""]
    picks = pick_hillclimb_pairs(recs)
    md.append("## Hillclimb picks")
    for why, r in picks.items():
        if r:
            md.append(f"- **{why}**: {r['arch']} × {r['shape']} × {r['mesh']}"
                      f" (bottleneck: {r.get('bottleneck')})")
    out = "\n".join(md)
    with open(args.out, "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
