"""Benchmark harness — one section per paper table/figure plus the kernel
micro-benchmarks. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  convergence : paper Figs 2–3 — DQGAN vs CPOAdam vs CPOAdam-GQ quality
  speedup     : paper Fig 4 — time/step and speedup vs workers from the
                sched.clock wall-clock model (homogeneous workers), with
                the original purely-analytic rows kept under "analytic"
  compression : compressor micro-bench (throughput, ratio, measured δ)
  kernels     : Pallas fused quantize+EF + flash attention vs jnp oracle
  comm        : repro.comm wire telemetry — bytes/step (per-step, cumulative,
                achieved ratio) and two_phase sim-fallback counts, seed
                per-tensor planner vs bucketed, on dcgan32 + gemma-2b smoke
  overlap     : measured split-phase overlap — the jitted mix step
                wall-clocked with exchange.overlap on vs off for
                delayed(τ) over 8 (forced) host devices; writes
                experiments/overlap_measured.json, which sched/speedup
                embed under "overlap_measured" (opt-in, like
                comm_adaptive)
  sched       : repro.sched — speedup-vs-M per exchange schedule
                (every_step / local_k / delayed) × compressor (f32 / 8-bit)
                under a straggler profile, plus the bounded-staleness
                τ∈{1,2,4,8} convergence-vs-staleness-vs-wall-clock
                frontier on the mixture benchmark (experiments/sched.json)
  fsdp        : ZeRO memory/wire frontier (opt-in) — modeled per-device
                peak bytes and per-round wire bytes for replicated
                two_phase vs compressed fsdp_zero2/zero3 on the dcgan32
                parameter count; asserts zero-3 peak < replicated at
                M=8 (experiments/fsdp.json, gated via
                experiments/baselines/fsdp_quick.json)
  serve       : repro.serve — continuous-batching engine vs sequential
                tokens/s (the engine must win at batch >= 4), a seeded
                offered-QPS sweep (latency p50/p99, tokens/s, KV-block
                occupancy) on a virtual clock, and the deterministic
                serve model rows the regression gate checks
                (experiments/serve.json)
  roofline    : benchmarks.roofline over the experiments/dryrun/*.json
                records — one row per (arch × shape × mesh) with the
                three roofline terms and the dominant bottleneck, plus
                the regenerated experiments/roofline.md. Missing records
                are reported explicitly (the dry-run sweep needs the
                production meshes; see repro.launch.dryrun)

Regression gate (CI): ``--check-against experiments/baselines/sched_quick.json``
re-runs the sched wall-clock model with the baseline's recorded compute
time and parameter count (so the model is fully deterministic across
hosts) and fails the run when any (schedule, compressor, M) row or any
τ-frontier row regresses >10% in modeled seconds/step or wire bytes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp


def _timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# run sink (repro.obs): when --obs-sink is given, every CSV row also
# lands in the structured event stream as a bench_row event (and
# bench_comm emits full comm_summary events), so CI can archive one
# JSONL artifact per benchmark run and `repro.obs report` can read it.
_SINK = None


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)
    if _SINK is not None:
        _SINK.emit("bench_row", name=name, us=round(us, 1),
                   derived=derived)


# --------------------------------------------------------------------------- #
def bench_convergence(quick: bool):
    """Paper Figs 2–3 analogue on the 2-D mixture benchmark."""
    from benchmarks.gan_common import train_mixture_gan

    steps = 400 if quick else 2000
    results = {}
    for method in ("CPOAdam", "CPOAdam-GQ", "DQGAN", "DQGAN-noEF"):
        t0 = time.perf_counter()
        final, _, _ = train_mixture_gan(method, steps=steps)
        us = (time.perf_counter() - t0) / steps * 1e6
        results[method] = final
        row(f"convergence/{method}", us,
            f"modes={final['modes']}/8 hq={final['hq_frac']} fid={final['fid']}")
    with open("experiments/convergence.json", "w") as f:
        json.dump({"steps": steps, "results": results}, f, indent=1)
    return results


# --------------------------------------------------------------------------- #
_COMPUTE_TIME_CACHE = {}


def _dcgan_compute_time(quick: bool):
    """(t_compute_seconds, d): measured DCGAN field time on this host and
    the exchanged parameter count — the inputs every speed model shares.
    Memoized so sched + speedup sections of one run agree (and the model
    only builds/compiles once)."""
    if quick in _COMPUTE_TIME_CACHE:
        return _COMPUTE_TIME_CACHE[quick]
    from repro.models.gan import GANConfig, dcgan_init, gan_field_fn

    cfg = GANConfig(image_size=32, channels=3, latent_dim=128,
                    base_width=32 if quick else 64)
    key = jax.random.key(0)
    params = dcgan_init(key, cfg)
    d = sum(x.size for x in jax.tree.leaves(params))
    field = jax.jit(gan_field_fn(cfg))
    batch = {"real": jax.random.normal(key, (64, 32, 32, 3))}
    t_compute_us = _timeit(lambda: field(params, batch, key), iters=5)
    _COMPUTE_TIME_CACHE[quick] = (t_compute_us / 1e6, d)
    return _COMPUTE_TIME_CACHE[quick]


# --------------------------------------------------------------------------- #
# shared sweep definitions (repro.strategy): the schedule × wire points
# are Strategy OBJECTS — one spelling for the speedup and sched sections,
# and the structural identity (strategy.short_hash()) the regression gate
# keys baselines by.
# --------------------------------------------------------------------------- #
def _wire_strategies():
    from repro.strategy import Strategy

    return {
        # f32 on the wire (exact averaging) vs int8 two-phase collectives
        "f32": Strategy.from_legacy(exchange="exact"),
        "8bit": Strategy.from_legacy(exchange="two_phase"),
    }


def _sched_strategies(K):
    from repro.strategy import Schedule, Strategy

    return (
        ("every_step", Strategy()),
        ("local_k", Strategy(schedule=Schedule.local_k(K))),
        ("delayed", Strategy(schedule=Schedule.delayed())),
    )


def sweep_points(K):
    """The full schedule × compressor sweep as composed Strategy objects:
    yields (schedule_label, wire_label, Strategy)."""
    import dataclasses

    for sname, s_st in _sched_strategies(K):
        for cname, w_st in _wire_strategies().items():
            yield sname, cname, dataclasses.replace(
                s_st, exchange=w_st.exchange)


def _wire_models(d):
    """Per-worker bytes of ONE exchange by wire label."""
    return {name: (lambda M, st=st: st.modeled_wire_bytes(d, M))
            for name, st in _wire_strategies().items()}


def bench_speedup(quick: bool):
    """Paper Fig 4 analogue, regenerated from the sched.clock wall-clock
    model: per-step time and speedup vs workers, f32 vs 8-bit, for each
    exchange schedule over homogeneous workers. The original purely
    analytic rows (T(M) = T₁/M + T_comm, no latency/overlap model) are
    kept under an "analytic" sub-key for comparison."""
    from repro import sched as S

    t_compute, d = _dcgan_compute_time(quick)
    wire = _wire_models(d)
    Ms = (1, 2, 4, 8, 16, 32)

    # -- the seed's analytic model, unchanged ------------------------------- #
    link_bw = 1e9   # bytes/s per worker link (10GbE PS uplink, the
    # regime of the paper's Fig 4; at NVLink speeds compression is moot)
    analytic = []
    for M in Ms:
        t_comm_f32 = wire["f32"](max(M, 2)) / link_bw if M > 1 else 0.0
        t_comm_q8 = wire["8bit"](max(M, 2)) / link_bw if M > 1 else 0.0
        tf32 = t_compute / M + t_comm_f32
        tq8 = t_compute / M + t_comm_q8
        analytic.append({"M": M, "speedup_f32": round(t_compute / tf32, 2),
                         "speedup_8bit": round(t_compute / tq8, 2)})

    # -- schedule-aware wall-clock model (homogeneous workers) -------------- #
    profile = S.get_profile("none")
    steps = SCHED_MODEL_STEPS[quick]
    base = S.baseline_mean_step(profile, steps, t_compute)
    rows = []
    for sname, strat in _sched_strategies(K=4):
        sch = strat.schedule.runtime()
        per = {}
        for cname, bfn in wire.items():
            per[cname] = {r["M"]: r for r in S.speedup_vs_M(
                sch, profile, Ms, steps, t_compute,
                lambda M, b=bfn: b(max(M, 2)), base=base)}
        for M in Ms:
            rows.append({"M": M, "schedule": sname,
                         "speedup_f32": round(per["f32"][M]["speedup"], 2),
                         "speedup_8bit": round(per["8bit"][M]["speedup"], 2),
                         "step_s_f32": per["f32"][M]["mean_step_s"],
                         "step_s_8bit": per["8bit"][M]["mean_step_s"]})
            row(f"speedup/{sname}/M={M}",
                per["f32"][M]["mean_step_s"] * 1e6,
                f"f32={rows[-1]['speedup_f32']}x "
                f"8bit={rows[-1]['speedup_8bit']}x")
    out = {"d": d, "t_compute_us": t_compute * 1e6,
           "model": "sched.clock (profile=none, LinkModel default)",
           "steps": steps,
           "rows": rows,
           "analytic": {"model": "T(M) = T1/M + bytes/bw",
                        "rows": analytic}}
    measured = _load_overlap_measured()
    if measured:
        out["overlap_measured"] = measured
    with open("experiments/speedup.json", "w") as f:
        json.dump(out, f, indent=1)
    return rows


# --------------------------------------------------------------------------- #
# measured split-phase overlap (exchange.overlap on vs off wall clocks)
# --------------------------------------------------------------------------- #
OVERLAP_TAUS = (1, 2, 4)
OVERLAP_M = 8


def bench_overlap(quick: bool):
    """Measured — not modeled — split-phase overlap: the mix trainer's
    jitted step wall-clocked with ``exchange.overlap`` on vs off for
    ``delayed(τ)``, τ ∈ {1, 2, 4}, over 8 workers (two_phase /
    shard_map, spans on). Writes experiments/overlap_measured.json;
    bench_sched and bench_speedup embed the rows under
    ``overlap_measured`` so the committed artifacts carry the measured
    overlap next to the modeled speedup rows.

    ``hidden_s`` = p50(off) − p50(on) is the step wall the split-phase
    lowering removed. On CPU backends XLA emits no async collectives,
    so any hidden time there comes from scheduler reordering only — the
    ≥50%-hidden expectation is a GPU/TPU-class statement (DESIGN.md
    §13); the artifact records the platform so readers can tell."""
    import subprocess

    from jax.sharding import PartitionSpec as P

    from repro.configs.base import DQConfig
    from repro.core.dqgan import DQGAN
    from repro.models.gan import GANConfig, gan_field_fn, mlp_gan_init
    from repro.obs.profile import overlap_ratio
    from repro.parallel.compat import make_mesh, set_mesh
    from repro.strategy import (Compression, ExchangePlan, Observability,
                                Schedule, Strategy)

    if jax.device_count() < 4:
        # a single device has no wire to hide; re-exec on forced host
        # devices (same dance as bench_comm_adaptive)
        print("# overlap: <4 devices — re-running with 8 forced host "
              "devices", flush=True)
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        cmd = [sys.executable, "-m", "benchmarks.run", "--only", "overlap"] \
            + (["--quick"] if quick else [])
        subprocess.run(cmd, check=True, env=env)
        return _load_overlap_measured()

    M = min(jax.device_count(), OVERLAP_M)
    mesh = make_mesh((M,), ("data",))
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    params = mlp_gan_init(jax.random.key(0), cfg)
    batch = {"real": jax.random.normal(jax.random.key(0), (64, 2))}
    warm, n_steps = (3, 24) if quick else (5, 96)

    def walls(tau, overlap):
        strat = Strategy(
            compression=Compression(plan="uniform", bucket_mb=0.03),
            exchange=ExchangePlan(kind="two_phase", spmd="shard_map",
                                  worker_axes=("data",), overlap=overlap),
            schedule=Schedule.delayed(tau=tau),
            observability=Observability(spans=True))
        dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
        tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
                   batch_spec=P(("data",)))
        out = []
        with set_mesh(mesh):
            st = tr.init(params)
            step = jax.jit(tr.step, static_argnums=(3,))
            for i in range(warm + n_steps):
                t0 = time.perf_counter()
                res = jax.block_until_ready(
                    step(st, batch, jax.random.key(i), True))
                st = res.state
                if i >= warm:
                    out.append(time.perf_counter() - t0)
        return out

    rows = []
    for tau in OVERLAP_TAUS:
        w_off = walls(tau, False)
        w_on = walls(tau, True)
        r = overlap_ratio(w_on, w_off)
        r.update({"tau": tau, "n_workers": M, "steps": n_steps,
                  "hidden_frac_step": (round(r["hidden_s"] / r["t_off_s"], 4)
                                       if r["t_off_s"] else 0.0)})
        rows.append(r)
        row(f"overlap/tau={tau}", r["t_on_s"] * 1e6,
            f"off={r['t_off_s'] * 1e6:.0f}us "
            f"hidden={r['hidden_s'] * 1e6:.0f}us "
            f"({r['hidden_frac_step'] * 100:.1f}% of step)")
    out = {"platform": jax.devices()[0].platform, "n_workers": M,
           "steps": n_steps,
           "note": ("hidden_s = p50(overlap=False) - p50(overlap=True) "
                    "step wall, measured on the recorded platform; CPU "
                    "XLA emits no async collectives, so the >=50%-hidden "
                    "expectation applies to GPU/TPU backends"),
           "rows": rows}
    with open("experiments/overlap_measured.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def _load_overlap_measured():
    """The last `--only overlap` artifact, if one has been generated —
    embedded verbatim into sched.json / speedup.json so the measured
    overlap rows travel with the modeled ones."""
    try:
        with open("experiments/overlap_measured.json") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


# --------------------------------------------------------------------------- #
# simulated steps per tier — the gate refuses cross-tier comparisons (wire
# bytes scale with steps), so this mapping is shared with main()'s check
SCHED_MODEL_STEPS = {True: 64, False: 256}


def bench_sched(quick: bool, model_inputs=None, convergence: bool = True,
                out_path: str = "experiments/sched.json"):
    """repro.sched: simulated speedup-vs-M per exchange schedule ×
    compressor under the 'mild' straggler profile, plus the bounded-
    staleness τ∈{1,2,4,8} frontier — server-dataflow wall clock AND real
    mixture-GAN convergence under delayed(τ). The acceptance
    inequalities — local_k and delayed strictly cheaper per step than
    every_step once M ≥ 4, cumulative wire bytes monotone over the τ
    sweep — are asserted, not just reported.

    ``model_inputs`` = (t_compute_seconds, d) overrides the measured
    DCGAN field time, making every wall-clock number deterministic —
    the ``--check-against`` regression gate passes the baseline's
    recorded values here so CI hosts of different speeds compare equal
    models. ``convergence=False`` skips the frontier's mixture-GAN
    training (gate mode: convergence metrics are never gated, so the
    CI run keeps only the deterministic model)."""
    from benchmarks.gan_common import train_mixture_gan

    from repro import sched as S

    t_compute, d = model_inputs or _dcgan_compute_time(quick)
    wire = _wire_models(d)
    profile = S.get_profile("mild")
    K = 4
    steps = SCHED_MODEL_STEPS[quick]
    Ms = (1, 2, 4, 8, 16, 32)
    # The M=1 baseline is schedule- and compressor-independent (no comm):
    # simulate it ONCE here; speedup_vs_M reuses it both as the reference
    # and as the Ms[0] row (the quick tier previously simulated it twice
    # per schedule × compressor sweep).
    base = S.baseline_mean_step(profile, steps, t_compute)
    rows = []
    for sname, cname, strat in sweep_points(K):
        sch = strat.schedule.runtime()
        bfn = wire[cname]
        for r in S.speedup_vs_M(sch, profile, Ms, steps, t_compute,
                                lambda M, b=bfn: b(max(M, 2)),
                                base=base):
            wire_mb = (bfn(max(r["M"], 2)) * r["n_exchanges"] / 1e6
                       if r["M"] > 1 else 0.0)
            r.update({"schedule": sname, "compressor": cname,
                      "strategy": strat.short_hash(),
                      "wire_mb": round(wire_mb, 3)})
            rows.append(r)
            row(f"sched/{sname}/{cname}/M={r['M']}",
                r["mean_step_s"] * 1e6,
                f"speedup={r['speedup']:.2f}x "
                f"t_ex={r['t_exchange_s']*1e6:.0f}us "
                f"exchanges={r['n_exchanges']}")

    def mean_step(s, c, M):
        return next(r["mean_step_s"] for r in rows
                    if r["schedule"] == s and r["compressor"] == c
                    and r["M"] == M)

    for c in ("f32", "8bit"):
        for M in (4, 8, 16, 32):
            assert mean_step("local_k", c, M) < mean_step("every_step", c, M)
            assert mean_step("delayed", c, M) < mean_step("every_step", c, M)

    # ---- bounded-staleness frontier: τ vs wall clock vs convergence ------- #
    taus = (1, 2, 4, 8)
    M_f = 8
    conv_steps = 300 if quick else 1500
    frontier = []
    cum_wire_mb = 0.0
    for tau in taus:
        strat_tau = _wire_strategies()["8bit"].evolve(
            schedule="delayed", staleness_tau=tau)
        sim = S.time_per_step(strat_tau.schedule.runtime(), profile, M_f,
                              steps, t_compute, wire["8bit"](M_f),
                              dataflow="server")
        wire_mb = wire["8bit"](M_f) * sim["n_exchanges"] / 1e6
        cum_wire_mb += wire_mb
        f_row = {
            # clock_M labels the wall-clock/wire MODEL only; the
            # convergence run below is single-worker (sim-compressed, the
            # staleness effect isolated from worker averaging)
            "tau": tau, "clock_M": M_f,
            "strategy": strat_tau.short_hash(),
            "mean_step_s": sim["mean_step_s"],
            "total_s": sim["total_s"],
            "n_exchanges": sim["n_exchanges"],
            "staleness_max": sim["staleness_max"],
            "staleness_mean": round(sim["staleness_mean"], 3),
            "wire_mb": round(wire_mb, 3),
            "cum_wire_mb": round(cum_wire_mb, 3),
        }
        derived = f"stale_max={sim['staleness_max']:.0f}"
        if convergence:
            final, _, _ = train_mixture_gan(
                "DQGAN", steps=conv_steps,
                strategy_overrides={"schedule": "delayed",
                                    "staleness_tau": tau})
            f_row.update({"conv_steps": conv_steps, "conv_workers": 1,
                          "modes": final["modes"],
                          "hq_frac": final["hq_frac"], "fid": final["fid"]})
            derived += (f" modes={final['modes']}/8 hq={final['hq_frac']} "
                        f"fid={final['fid']}")
        frontier.append(f_row)
        row(f"sched/tau_frontier/tau={tau}", sim["mean_step_s"] * 1e6,
            derived)
    # wire accounting is monotone: staleness changes WHEN bytes move, not
    # how many — every τ point must report the same per-run bytes (this
    # catches n_exchanges drift in the server model), the cumulative
    # ledger must agree with the per-row sum, and more slack must not
    # slow the modeled clock.
    for a, b in zip(frontier, frontier[1:]):
        assert b["wire_mb"] == a["wire_mb"], (a, b)
        assert b["cum_wire_mb"] > a["cum_wire_mb"], (a, b)
        assert b["total_s"] <= a["total_s"] * (1 + 1e-9), \
            "more staleness slack must not slow the modeled clock"
    total_mb = sum(f_row["wire_mb"] for f_row in frontier)
    assert abs(frontier[-1]["cum_wire_mb"] - total_mb) < 0.01, \
        (frontier[-1]["cum_wire_mb"], total_mb)
    for f_row in frontier:
        assert f_row["staleness_max"] <= f_row["tau"], f_row

    out = {"d": d, "t_compute_us": t_compute * 1e6,
           "profile": profile.name, "local_k": K, "steps": steps,
           "link": {"bandwidth_Bps": S.LinkModel().bandwidth_Bps,
                    "latency_s": S.LinkModel().latency_s},
           "rows": rows,
           "tau_frontier": frontier,
           # deterministic PlanFamily wire model (no training) — gated by
           # --check-against alongside the schedule rows
           "comm_adaptive": comm_adaptive_model_rows(),
           # deterministic serving-engine model (benchmarks.serve_load) —
           # gated the same way
           "serve": _serve_model_rows()}
    if convergence:
        # real benchmark run (not the replayed-constants gate): attach the
        # measured split-phase overlap rows when `--only overlap` has
        # produced them — never gated (host wall clocks, not a model)
        measured = _load_overlap_measured()
        if measured:
            out["overlap_measured"] = measured
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


# --------------------------------------------------------------------------- #
def _fsdp_strategies():
    from repro.strategy import (Compression, ExchangePlan, MomentCompression,
                                Strategy)

    def fsdp(zs, mom):
        return Strategy(
            compression=Compression(plan="uniform"),
            exchange=ExchangePlan(kind="two_phase", parallelism="fsdp",
                                  zero_stage=zs, worker_axes=("data",)),
            moments=MomentCompression(compressor=mom,
                                      error_feedback=mom != "identity"))

    repl = Strategy(compression=Compression(plan="uniform"),
                    exchange=ExchangePlan(kind="two_phase",
                                          worker_axes=("data",)))
    # the f32-moment variants isolate the all-gather leg's cost: same
    # memory frontier, 4 bytes/elem instead of ~1 on the return wire
    return (("replicated", repl),
            ("fsdp_zero2", fsdp(2, "qsgd8_linf")),
            ("fsdp_zero3", fsdp(3, "qsgd8_linf")),
            ("fsdp_zero2_f32mom", fsdp(2, "identity")),
            ("fsdp_zero3_f32mom", fsdp(3, "identity")))


def fsdp_model_rows(d, Ms):
    """Deterministic per-device memory + wire rows, keyed by
    strategy.short_hash() for the regression gate.

    Memory model (f32 Adam, message='grad'): the transient gradient
    buckets are 4d bytes on every path. Replicated DDP persists params
    + m + v (12d). fsdp shards the Adam moments and the all-gather EF
    residual down to 12d/W and (zero-3) adds the owner's parameter
    shard, 4d/W; the replicated parameter copy (4d) stays in the
    carried state on BOTH stages — the savings are the optimizer
    state, not the weights (DESIGN.md §15.6)."""
    rows = []
    for name, strat in _fsdp_strategies():
        for M in Ms:
            W = max(M, 1)
            if strat.exchange.fsdp:
                persistent = 4 * d + 12 * d / W + (
                    4 * d / W if strat.exchange.zero_stage == 3 else 0)
            else:
                persistent = 12 * d
            rows.append({
                "name": name, "M": M, "strategy": strat.short_hash(),
                "persistent_mb": round(persistent / 1e6, 4),
                "peak_mb": round((persistent + 4 * d) / 1e6, 4),
                "wire_mb": round(strat.modeled_wire_bytes(d, M) / 1e6, 4),
            })
    return rows


def bench_fsdp(quick: bool):
    """ZeRO memory/wire frontier on the dcgan32 parameter count
    (experiments/fsdp.json): modeled per-device peak bytes and
    per-round wire bytes for replicated two_phase vs compressed
    fsdp_zero2/zero3. The headline inequality — zero-3 peak strictly
    below replicated at M=8 — is asserted, not just reported."""
    from repro.models import gan

    cfg = gan.GANConfig().reduced() if quick else gan.GANConfig()
    params = gan.init(jax.random.key(0), cfg)
    d = sum(int(l.size) for l in jax.tree.leaves(params))
    Ms = (2, 4, 8) if quick else (2, 4, 8, 16, 32)
    rows = fsdp_model_rows(d, Ms)
    by = {(r["name"], r["M"]): r for r in rows}
    for r in rows:
        row(f"fsdp/{r['name']}/M={r['M']}", 0.0,
            f"peak={r['peak_mb']}MB wire={r['wire_mb']}MB")
    for M in Ms:
        repl, z2, z3 = (by[(n, M)] for n in
                        ("replicated", "fsdp_zero2", "fsdp_zero3"))
        # zero-3 ties replicated exactly at M=2 (4d + 12d/2 + 4d/2 = 12d)
        # and wins strictly from M=4 on
        assert z3["peak_mb"] <= repl["peak_mb"], (M, z3, repl)
        if M >= 4:
            assert z3["peak_mb"] < repl["peak_mb"], (M, z3, repl)
        assert z2["peak_mb"] <= z3["peak_mb"], (M, z2, z3)
        # quantizing the moments leg shrinks the wire, never the memory
        for name in ("fsdp_zero2", "fsdp_zero3"):
            q, f32 = by[(name, M)], by[(name + "_f32mom", M)]
            assert q["wire_mb"] < f32["wire_mb"], (M, q, f32)
            assert q["peak_mb"] == f32["peak_mb"], (M, q, f32)
    assert by[("fsdp_zero3", 8)]["peak_mb"] < by[("replicated", 8)]["peak_mb"]
    # sharding more workers only shrinks the per-device footprint
    for name in ("fsdp_zero2", "fsdp_zero3"):
        peaks = [by[(name, M)]["peak_mb"] for M in Ms]
        assert peaks == sorted(peaks, reverse=True), (name, peaks)
    out = {"quick": quick, "d": d, "Ms": list(Ms), "rows": rows}
    with open("experiments/fsdp.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def check_fsdp_regression(current: dict, baseline: dict,
                          tol: float = 0.10) -> list:
    """Gate experiments/fsdp.json rows against a committed baseline:
    rows matched by (strategy hash, M); >tol growth in modeled peak
    memory or wire bytes fails. Same stale-baseline refusal as the
    sched gate: zero hash matches means the schema/sweep moved."""
    fails = []
    base_rows = baseline.get("rows", [])
    cur_rows = current.get("rows", [])
    if base_rows and not all("strategy" in r for r in base_rows):
        return [
            "fsdp: baseline rows carry no strategy hash — regenerate "
            "with `python -m benchmarks.run --quick --only fsdp`"]
    base_by = {(r["strategy"], r["M"]): r for r in base_rows}
    matched = 0
    for r in cur_rows:
        b = base_by.get((r["strategy"], r["M"]))
        if b is None:
            continue
        matched += 1
        for f in ("peak_mb", "wire_mb"):
            if b.get(f) and r[f] > b[f] * (1 + tol):
                fails.append(
                    f"fsdp[{r['name']} M={r['M']} @{r['strategy']}] "
                    f"{f}: {r[f]:.6g} vs baseline {b[f]:.6g} "
                    f"(+{(r[f] / b[f] - 1) * 100:.1f}% > {tol * 100:.0f}%)")
    if base_rows and cur_rows and matched == 0:
        fails.append(
            "fsdp: no current row matches any baseline row by strategy "
            "hash — the sweep or strategy schema changed; regenerate "
            "the baseline")
    return fails


# --------------------------------------------------------------------------- #
def bench_compression(quick: bool):
    from repro.core import compressors as C

    n = 1 << (18 if quick else 22)
    key = jax.random.key(0)
    v = jax.random.normal(key, (n,))
    for name in ("qsgd8_linf", "qsgd8_l2", "qsgd8_l2_global",
                 "qsgd4_linf", "qsgd8_block256", "sign", "topk1"):
        comp = C.get(name)
        rt = jax.jit(lambda v, k, c=comp: c.roundtrip(v, k))
        us = _timeit(rt, v, key, iters=10)
        vhat = rt(v, key)
        err = float(jnp.sum((vhat - v) ** 2) / jnp.sum(v**2))
        ratio = 4 * n / comp.wire_bytes((n,))
        gbps = 4 * n / (us / 1e6) / 1e9
        row(f"compression/{name}", us,
            f"ratio={ratio:.1f}x delta_measured={1-err:.4f} gbps={gbps:.2f}")


# --------------------------------------------------------------------------- #
def bench_kernels(quick: bool):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.quantize import quantize_ef_blocked
    from repro.kernels.ref import flash_attention_ref, quantize_ef_ref

    R, Cc = (256, 512) if quick else (1024, 1024)
    key = jax.random.key(0)
    g = jax.random.normal(key, (R, Cc))
    e = jnp.zeros((R, Cc))
    r = jax.random.uniform(jax.random.fold_in(key, 1), (R, Cc))
    ref = jax.jit(quantize_ef_ref)
    us_ref = _timeit(ref, g, e, r, iters=10)
    bw = 4 * 3 * R * Cc / (us_ref / 1e6) / 1e9
    row("kernels/quantize_ef_ref(jnp)", us_ref, f"gbps={bw:.2f}")
    k_interp = jax.jit(lambda g, e, r: quantize_ef_blocked(g, e, r))
    us_k = _timeit(k_interp, g, e, r, iters=3, warmup=1)
    row("kernels/quantize_ef_pallas(interpret)", us_k,
        "correctness-path; TPU perf is the target")

    S, D = (256, 64) if quick else (1024, 128)
    q = jax.random.normal(key, (4, S, D))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (4, S, D))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (4, S, D))
    refa = jax.jit(lambda q, k, v: flash_attention_ref(
        q[:, :, None], k[:, :, None], v[:, :, None])[:, :, 0])
    us_ra = _timeit(refa, q, kk, vv, iters=5)
    row("kernels/attention_ref(jnp)", us_ra,
        f"gflops={4*S*S*D*4/(us_ra/1e6)/1e9:.1f}")
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v))
    us_fa = _timeit(fa, q, kk, vv, iters=2, warmup=1)
    row("kernels/flash_attention_pallas(interpret)", us_fa,
        "correctness-path; TPU perf is the target")


# --------------------------------------------------------------------------- #
def bench_roofline(quick: bool, dirpath: str = "experiments/dryrun"):
    """Roofline reporting as a first-class section: read the dry-run
    records (experiments/dryrun/*.json, produced by repro.launch.dryrun
    — the sweep itself needs a machine that can lower the production
    meshes), emit one row per record with the three roofline terms in
    seconds and the dominant bottleneck, and regenerate
    experiments/roofline.md. Rows ride the obs sink as bench_row events
    like every other section; absent records are a reported row, never a
    silent skip."""
    from benchmarks import roofline as R

    recs = R.load(dirpath) if os.path.isdir(dirpath) else []
    if not recs:
        row("roofline/none", 0.0,
            f"no dry-run records under {dirpath}/ — run `python -m "
            f"repro.launch.dryrun --all` on a host that can lower the "
            f"production meshes")
        return []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            row(name, 0.0,
                f"status={r['status']} "
                f"{(r.get('reason') or r.get('error') or '')[:60]}")
            continue
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        row(name, total * 1e6,
            f"bottleneck={r['bottleneck']} "
            f"compute_s={rf['compute_s']:.3e} "
            f"memory_s={rf['memory_s']:.3e} "
            f"collective_s={rf['collective_s']:.3e}")
    R.main(["--dir", dirpath,
            "--out", os.path.join(os.path.dirname(dirpath), "roofline.md")])
    return recs


# --------------------------------------------------------------------------- #
def bench_comm(quick: bool, sim_steps: int = 0):
    """repro.comm telemetry on the two smoke configs: per-step + cumulative
    wire bytes, achieved compression ratio, and how many tensors the seed
    per-tensor two_phase planner bounces to `sim` vs the bucketed planner.
    Two worker counts: 8 (power-of-two pod) and 12 (3 hosts x 4 chips —
    the non-power-of-two case where per-tensor chunking falls apart)."""
    import repro.configs as cfgs
    from repro import comm
    from repro.models import build
    from repro.strategy import Strategy

    # one Strategy object defines both modes' wire: the seed mode drops
    # its comm plan (per-tensor exchange), the bucketed mode keeps it
    strat = Strategy.from_legacy(exchange="two_phase",
                                 compressor="qsgd8_linf",
                                 comm_plan="uniform", bucket_mb=1.0)
    kind, comp = strat.exchange.kind, strat.compression.compressor
    sim_steps = sim_steps or (10 if quick else 100)
    out = {"sim_steps": sim_steps, "strategy": strat.to_json(),
           "configs": {}}
    for arch in ("dcgan32", "gemma-2b"):
        cfg = cfgs.get(arch).reduced()
        bundle = build(cfg)
        params = jax.eval_shape(lambda k: bundle.init(k, max_seq=32),
                                jax.random.key(0))
        shapes = jax.tree.map(lambda x: tuple(x.shape), params)
        rec = {}
        for W in (8, 12):
            for mode in ("seed", "bucketed"):
                if mode == "seed":
                    led = comm.CommLedger.from_tree(
                        kind, comp, shapes, None, W)
                else:
                    layout, plan = strat.compression.build(shapes, None, W)
                    led = comm.CommLedger.from_plan(
                        layout, plan, kind, W, comp)
                led.tick(sim_steps)
                s = led.summary()
                rec[f"{mode}_W{W}"] = s
                row(f"comm/{arch}/W{W}/{mode}", 0.0,
                    f"wire_mb_step={s['wire_bytes_per_step']/1e6:.3f} "
                    f"cum_wire_mb={s['cumulative_wire_bytes']/1e6:.1f} "
                    f"ratio={s['compression_ratio']} "
                    f"fallbacks={s['n_fallbacks']}/{s['n_entries']}")
                # the bucketed planner's per-bucket wire accounting
                # (bits, payload, analytic δ) rides along as CSV rows
                for pb in s.get("per_bucket", []):
                    row(f"comm/{arch}/W{W}/{mode}/bucket{pb['bucket']}",
                        0.0,
                        f"comp={pb['compressor']} bits={pb['bits']} "
                        f"elems={pb['elems']} "
                        f"payload_b={pb['payload_bytes']} "
                        f"delta={pb['delta']}")
                if _SINK is not None:
                    _SINK.emit("comm_summary", arch=arch, workers=W,
                               mode=mode, **s)
            assert (rec[f"bucketed_W{W}"]["n_fallbacks"]
                    <= rec[f"seed_W{W}"]["n_fallbacks"])
        # the non-power-of-two worker count is where bucketing pays off
        assert (rec["bucketed_W12"]["n_fallbacks"]
                < rec["seed_W12"]["n_fallbacks"])
        out["configs"][arch] = rec
    with open("experiments/comm.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


# --------------------------------------------------------------------------- #
# round-adaptive compression (repro.comm PlanFamily, DESIGN.md §10)
# --------------------------------------------------------------------------- #
# The mixture-GAN sizing for the adaptive frontier: a bucket cap small
# enough to give the descent real per-bucket structure, and a budget that
# bites at full participation (the ~41 KB 8-bit payload must not fit) so
# the family actually fans out across participation counts.
MIX_ADAPTIVE = {"bucket_mb": 0.0625, "comm_budget_mb": 0.024}
ADAPTIVE_PARTICIPATIONS = (1.0, 0.5, 0.25)
ADAPTIVE_M = 8


def _mix_adaptive_strategy(participation: float, adaptive: bool):
    """One frontier cell: the adaptive_budget/byte_budget pair resized
    for the 2-D mixture GAN, at a given participation."""
    from repro.strategy import get_preset

    return get_preset("adaptive_budget").evolve(
        participation=participation, comm_adaptive=adaptive,
        worker_axes=("data",), **MIX_ADAPTIVE)


def _mix_adaptive_ledger(strat, M):
    """(CommLedger, plan_for_n) for one frontier strategy over the
    mixture-GAN shapes — pure planner arithmetic, no devices."""
    from repro import comm
    from repro.models.gan import GANConfig, mlp_gan_init

    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    params = jax.eval_shape(lambda k: mlp_gan_init(k, cfg),
                            jax.random.key(0))
    shapes = jax.tree.map(lambda x: tuple(x.shape), params)
    comp = strat.compression
    if comp.adaptive:
        layout, family = comp.build_family(shapes, None, M)
        plan = family.full
    else:
        layout, plan = comp.build(shapes, None, M)
        family = None
    led = comm.CommLedger.from_plan(
        layout, plan, strat.exchange.kind, M, comp.compressor,
        family=family)
    return led, (family.plan_for if family is not None
                 else lambda n: plan)


def comm_adaptive_model_rows():
    """Deterministic PlanFamily wire model on the mixture-GAN shapes —
    the rows the benchmark-regression gate checks (no devices, no
    training: pure planner arithmetic, keyed by strategy.short_hash())."""
    from repro.sched import n_participants

    M = ADAPTIVE_M
    rows = []
    for p in ADAPTIVE_PARTICIPATIONS:
        for adaptive in (False, True):
            strat = _mix_adaptive_strategy(p, adaptive)
            led, plan_for = _mix_adaptive_ledger(strat, M)
            n = n_participants(p, M)
            rows.append({
                "strategy": strat.short_hash(),
                "mode": "adaptive" if adaptive else "static",
                "participation": p,
                "participants": n,
                "wire_mb": round(led.round_bytes(n)[0] / 1e6, 4),
                "payload_bytes": plan_for(n).payload_bytes,
            })
    return rows


def bench_comm_adaptive(quick: bool):
    """Measured bytes-vs-convergence frontier for round-adaptive
    compression: the mixture GAN trained over M=8 workers at
    participation ∈ {1.0, 0.5, 0.25}, static `byte_budget` descent vs
    the `adaptive_budget` PlanFamily (experiments/comm_adaptive.json).

    The acceptance inequalities are asserted, not just reported:
      * full participation: adaptive ≡ static (identical metrics — the
        single-selected-member family is bit-exact with the static plan);
      * the equal-bytes comparison: adaptive at participation 0.5 moves
        no more cumulative wire bytes than static at full participation
        and matches or beats its convergence metric;
      * at the same participation 0.5, adaptive (which re-spends the
        absent workers' budget on finer bits) is no worse than static.
    """
    import subprocess

    from benchmarks.gan_common import train_mixture_gan

    from repro.parallel.compat import make_mesh
    from repro.sched import n_participants

    if jax.device_count() < 4:
        # the frontier needs real workers; re-exec on forced host devices
        print("# comm_adaptive: <4 devices — re-running with 8 forced "
              "host devices", flush=True)
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        cmd = [sys.executable, "-m", "benchmarks.run",
               "--only", "comm_adaptive"] + (["--quick"] if quick else [])
        subprocess.run(cmd, check=True, env=env)
        return None

    M = ADAPTIVE_M if jax.device_count() >= ADAPTIVE_M else jax.device_count()
    mesh = make_mesh((M,), ("data",))
    steps = 400 if quick else 1500
    rows = []
    for p in ADAPTIVE_PARTICIPATIONS:
        for adaptive in (False, True):
            strat = _mix_adaptive_strategy(p, adaptive)
            overrides = dict(participation=p,
                             comm_adaptive=adaptive, **MIX_ADAPTIVE,
                             comm_plan="delta_budget",
                             exchange=strat.exchange.kind)
            final, _, st = train_mixture_gan(
                "DQGAN", steps=steps, strategy_overrides=overrides,
                mesh=mesh)
            # bill the run's bytes with the participation-aware ledger
            # (pure planner arithmetic on the same strategy — no second
            # trainer build)
            led, _ = _mix_adaptive_ledger(strat, M)
            n = n_participants(p, M)
            led.tick(steps, participants=n)
            r = {"mode": "adaptive" if adaptive else "static",
                 "participation": p, "participants": n, "steps": steps,
                 "strategy": strat.short_hash(),
                 "wire_mb_round": round(led.round_bytes(n)[0] / 1e6, 4),
                 "cum_wire_mb": round(led.cumulative_wire_bytes / 1e6, 2),
                 "modes": final["modes"], "hq_frac": final["hq_frac"],
                 "fid": final["fid"]}
            rows.append(r)
            row(f"comm_adaptive/{r['mode']}/p={p}", 0.0,
                f"cum_wire_mb={r['cum_wire_mb']} modes={r['modes']}/8 "
                f"hq={r['hq_frac']} fid={r['fid']}")

    by = {(r["mode"], r["participation"]): r for r in rows}
    # Hard assertions: same-process determinism and byte accounting only.
    # Full participation: the single-selected-member family is bit-exact
    # with the static plan, so BOTH runs of this very process must agree
    # on every field.
    for fld in ("modes", "hq_frac", "fid", "cum_wire_mb"):
        assert by[("adaptive", 1.0)][fld] == by[("static", 1.0)][fld], \
            (fld, by[("adaptive", 1.0)], by[("static", 1.0)])
    # byte-budget invariant (structural, deterministic): every round's
    # fleet-average bytes fit B times the two_phase collective multiplier
    # — each member's payload <= its effective budget B*M/n by
    # construction, so (n/M)*multiplier*payload <= multiplier*B
    ad, st_full = by[("adaptive", 0.5)], by[("static", 1.0)]
    st_half = by[("static", 0.5)]
    bound_mb = (MIX_ADAPTIVE["comm_budget_mb"] * 2 * (M - 1) / M
                * steps * (1 << 20) / 1e6)
    for r in rows:
        assert r["cum_wire_mb"] <= bound_mb * 1.01, (r, bound_mb)
    # Convergence comparisons are NOT hard-gated (mixture-GAN metrics are
    # jax-version sensitive — same policy as check_sched_regression);
    # record the outcomes in the artifact and warn loudly on a miss.
    acceptance = {
        # the equal-bytes frontier point: adaptive@0.5's cumulative bytes
        # track static@1.0's (both are prefix cuts near B per round —
        # exact today, granularity-dependent after a resize)
        "equal_bytes_ok": bool(
            ad["cum_wire_mb"] <= st_full["cum_wire_mb"] * 1.02),
        # adaptive@0.5 matches-or-beats static@1.0 at equal bytes
        "equal_bytes_fid_ok": bool(ad["fid"] <= st_full["fid"] * 1.10),
        "equal_bytes_modes_ok": bool(ad["modes"] >= st_full["modes"] - 1),
        # same participation: the re-invested budget must not hurt
        "same_participation_fid_ok": bool(ad["fid"] <= st_half["fid"] * 1.10),
    }
    for name, ok in acceptance.items():
        if not ok:
            print(f"WARNING: comm_adaptive acceptance check {name} "
                  f"failed: adaptive@0.5={ad} static@1.0={st_full} "
                  f"static@0.5={st_half}", flush=True)

    out = {"M": M, "steps": steps, "sizing": MIX_ADAPTIVE,
           "acceptance": acceptance,
           "model_rows": comm_adaptive_model_rows(), "rows": rows}
    with open("experiments/comm_adaptive.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


# --------------------------------------------------------------------------- #
# continuous-batching serving (repro.serve)
# --------------------------------------------------------------------------- #
def _serve_model_rows():
    """Lazy import shim so bench_sched can embed the serve model rows
    without paying the repro.serve import on non-serve sections."""
    from benchmarks.serve_load import serve_model_rows
    return serve_model_rows()


def bench_serve(quick: bool):
    """Measured serving benchmark on the reduced gemma-2b:

    1. closed loop — the continuous-batching engine vs the sequential
       batch-1 baseline on the same warm request set; the engine's
       tokens/s must strictly win (the whole point of batching decode),
       and its decode step must have compiled exactly once across all
       request churn.
    2. open loop — seeded Poisson arrivals swept over offered QPS on a
       virtual clock (measured step walls, exact arrival times):
       latency p50/p99, tokens/s, KV-block occupancy per QPS.

    Writes experiments/serve.json with the measured rows plus the
    deterministic `serve_model_rows()` the --check-against gate compares
    (the same rows bench_sched embeds under "serve")."""
    import repro.configs as cfgs
    from benchmarks.serve_load import (gen_requests, run_open_loop,
                                       serve_model_rows)

    from repro.models import model as lm
    from repro.serve import Engine, Request, SequentialGenerator, ServeConfig

    cfg = cfgs.get("gemma-2b").reduced()
    params = lm.init(jax.random.key(0), cfg, 0)
    scfg = ServeConfig(max_batch=4 if quick else 8, block_size=8,
                       num_blocks=64 if quick else 128,
                       max_blocks_per_seq=8,
                       prompt_buckets=(8, 16, 32))
    n_req, gen = (8, 6) if quick else (24, 12)
    max_prompt = 24

    import numpy as np
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size,
                                 int(rng.integers(3, max_prompt))))
               for _ in range(n_req)]

    # -- closed loop: engine vs sequential on identical requests ----------- #
    eng = Engine(cfg, scfg, params)
    warm = [Request(rid=10_000 + i, prompt=list(p), max_new=gen)
            for i, p in enumerate(prompts)]
    eng.run(warm)                                     # compile + correctness
    reqs = [Request(rid=i, prompt=list(p), max_new=gen)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    out = eng.run(reqs)
    dt_eng = time.perf_counter() - t0
    toks = sum(len(out[r.rid]) for r in reqs)
    tps_eng = toks / dt_eng

    seq = SequentialGenerator(cfg, scfg, params)
    seq.generate(list(prompts[0]), gen, rid=20_000)   # compile
    t0 = time.perf_counter()
    seq_out = {i: seq.generate(list(p), gen, rid=i)
               for i, p in enumerate(prompts)}
    dt_seq = time.perf_counter() - t0
    tps_seq = sum(len(v) for v in seq_out.values()) / dt_seq

    assert seq_out == {r.rid: out[r.rid] for r in reqs}, \
        "engine and sequential baseline disagree on greedy tokens"
    assert len(eng.decode_traces) == 1, \
        f"decode step retraced: {len(eng.decode_traces)} compiles"
    assert tps_eng > tps_seq, \
        (f"continuous batching must beat sequential decode at batch "
         f">= 4: engine {tps_eng:.1f} tok/s vs sequential {tps_seq:.1f}")
    row("serve/closed_loop/engine", dt_eng / max(eng.scfg.max_batch, 1) * 1e6,
        f"tokens_per_s={tps_eng:.1f} batch={scfg.max_batch} "
        f"traces={len(eng.decode_traces)}")
    row("serve/closed_loop/sequential", dt_seq / n_req * 1e6,
        f"tokens_per_s={tps_seq:.1f}")

    # -- open loop: offered-QPS sweep on the warm engine -------------------- #
    sweep = []
    for j, qps in enumerate((4.0, 16.0) if quick else (2.0, 8.0, 32.0)):
        load = gen_requests(n_req, qps, seed=j + 1,
                            vocab=cfg.vocab_size, max_prompt=max_prompt,
                            max_new=gen)
        r = run_open_loop(eng, load, rid_base=1000 * (j + 1))
        r["qps"] = qps
        sweep.append(r)
        row(f"serve/qps={qps}", r["mean_step_s"] * 1e6,
            f"p50={r['latency_p50_s']}s p99={r['latency_p99_s']}s "
            f"tok/s={r['tokens_per_s']} "
            f"kv_peak={r['kv_occupancy_peak']}")
    assert len(eng.decode_traces) == 1, \
        f"decode step retraced during QPS sweep: {len(eng.decode_traces)}"

    out_doc = {
        "arch": cfg.name,
        "serve_config": {"max_batch": scfg.max_batch,
                         "block_size": scfg.block_size,
                         "num_blocks": scfg.num_blocks,
                         "max_blocks_per_seq": scfg.max_blocks_per_seq,
                         "prompt_buckets": list(scfg.prompt_buckets)},
        "closed_loop": {"tokens_per_s_engine": round(tps_eng, 1),
                        "tokens_per_s_sequential": round(tps_seq, 1),
                        "speedup": round(tps_eng / tps_seq, 2),
                        "decode_traces": len(eng.decode_traces)},
        "qps_sweep": sweep,
        "model": serve_model_rows(),
    }
    with open("experiments/serve.json", "w") as f:
        json.dump(out_doc, f, indent=1)
    return out_doc


# --------------------------------------------------------------------------- #
# benchmark-regression gate (CI)
# --------------------------------------------------------------------------- #
_GATED_FIELDS = ("mean_step_s", "wire_mb")   # wall-clock model + wire bytes


def check_sched_regression(current: dict, baseline: dict,
                           tol: float = 0.10) -> list:
    """Compare a bench_sched result dict against a committed baseline.
    Returns a list of human-readable failures: any row present in both
    whose modeled seconds/step or wire bytes grew by more than `tol`
    (improvements and new rows pass; convergence metrics are not gated —
    they are host-independent but jax-version sensitive).

    Rows are matched by the STRUCTURAL identity of their strategy — the
    `strategy.short_hash()` recorded per row — not by schedule/compressor
    label, so a sweep whose "local_k" silently changed meaning (different
    K, different exchange, ...) is a new row, never a bogus comparison;
    a baseline predating the hashes is refused outright."""
    fails = []

    def gate(cur_rows, base_rows, key_fields, human_fields, label):
        if base_rows and not all("strategy" in r for r in base_rows):
            fails.append(
                f"{label}: baseline rows carry no strategy hash "
                f"(pre-strategy format) — regenerate the baseline with "
                f"`python -m benchmarks.run --quick --only sched`")
            return
        base_by_key = {tuple(r[k] for k in key_fields): r for r in base_rows}
        matched = 0
        for r in cur_rows:
            b = base_by_key.get(tuple(r[k] for k in key_fields))
            if b is None:
                continue
            matched += 1
            for f in _GATED_FIELDS:
                if f not in r or not b.get(f):
                    continue
                if r[f] > b[f] * (1 + tol):
                    who = ", ".join(f"{k}={r[k]}" for k in human_fields)
                    fails.append(
                        f"{label}[{who} @{r['strategy']}] "
                        f"{f}: {r[f]:.6g} vs baseline {b[f]:.6g} "
                        f"(+{(r[f] / b[f] - 1) * 100:.1f}% > {tol * 100:.0f}%)")
        if base_rows and cur_rows and matched == 0:
            # a sweep/schema change shifted EVERY hash: that is a stale
            # baseline, not a clean bill of health
            fails.append(
                f"{label}: no current row matches any baseline row by "
                f"strategy hash — the sweep or strategy schema changed; "
                f"regenerate the baseline")

    gate(current.get("rows", []), baseline.get("rows", []),
         ("strategy", "M"), ("schedule", "compressor", "M"), "sched")
    gate(current.get("tau_frontier", []), baseline.get("tau_frontier", []),
         ("strategy",), ("tau",), "tau_frontier")
    gate(current.get("comm_adaptive", []),
         baseline.get("comm_adaptive", []),
         ("strategy",), ("mode", "participation"), "comm_adaptive")
    gate(current.get("serve", []), baseline.get("serve", []),
         ("strategy",), ("qps",), "serve")
    return fails


# --------------------------------------------------------------------------- #
def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes/steps (CI mode)")
    ap.add_argument("--only", default="",
                    help="comma list: convergence,speedup,compression,"
                         "kernels,comm,comm_adaptive,overlap,sched,"
                         "serve,roofline,fsdp")
    ap.add_argument("--check-against", default="",
                    help="baseline JSON to gate against: the sched section "
                         "(committed experiments/baselines/sched_quick.json) "
                         "or the fsdp section (fsdp_quick.json) — >10% "
                         "regression in the modeled numbers fails the run")
    ap.add_argument("--obs-sink", default="", metavar="PATH",
                    help="also write every row as a repro.obs bench_row "
                         "event (JSONL) for `python -m repro.obs report`")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if args.check_against and (only is None or not only & {"sched", "fsdp"}):
        ap.error("--check-against gates the sched or fsdp section; "
                 "add --only sched or --only fsdp")
    if args.check_against and only and {"sched", "fsdp"} <= only:
        ap.error("--check-against takes one baseline file; gate sched and "
                 "fsdp in separate runs")
    global _SINK
    if args.obs_sink:
        from repro import obs as obs_api
        _SINK = obs_api.make_sink(args.obs_sink)
    print("name,us_per_call,derived")
    os.makedirs("experiments", exist_ok=True)
    if not only or "compression" in only:
        bench_compression(args.quick)
    if not only or "comm" in only:
        bench_comm(args.quick)
    if only and "comm_adaptive" in only:
        # opt-in: trains the mixture GAN over 8 (forced) host devices —
        # not part of the default single-device sweep
        bench_comm_adaptive(args.quick)
    if only and "overlap" in only:
        # opt-in for the same reason: times the jitted step over 8
        # (forced) host devices, overlap on vs off; run it BEFORE a full
        # sched/speedup regen so those artifacts embed the measured rows
        bench_overlap(args.quick)
    if not only or "kernels" in only:
        bench_kernels(args.quick)
    if not only or "sched" in only:
        model_inputs = None
        baseline = None
        if args.check_against:
            with open(args.check_against) as f:
                baseline = json.load(f)
            # replay the model on the baseline's machine constants so the
            # comparison is model-vs-model, not runner-vs-runner
            model_inputs = (baseline["t_compute_us"] / 1e6, baseline["d"])
            print(f"# sched: gating against {args.check_against} "
                  f"(t_compute={baseline['t_compute_us']:.0f}us "
                  f"d={baseline['d']})", flush=True)
            if SCHED_MODEL_STEPS[args.quick] != baseline.get("steps"):
                print(f"ERROR: tier mismatch — this run would simulate "
                      f"steps={SCHED_MODEL_STEPS[args.quick]} but the "
                      f"baseline was generated with "
                      f"steps={baseline.get('steps')}; run the gate with "
                      f"the baseline's tier (--quick for sched_quick.json)"
                      f", or regenerate the baseline", flush=True)
                sys.exit(2)
        current = bench_sched(
            args.quick, model_inputs=model_inputs,
            convergence=baseline is None,
            # keep the gate's replayed-constants output apart from a real
            # benchmark result (it would otherwise clobber a full-tier
            # experiments/sched.json generated on this machine)
            out_path=("experiments/sched_gate.json" if baseline is not None
                      else "experiments/sched.json"))
        if baseline is not None:
            fails = check_sched_regression(current, baseline)
            for f_msg in fails:
                print(f"REGRESSION: {f_msg}", flush=True)
            if fails:
                sys.exit(1)
            print("# sched: regression gate passed", flush=True)
    if only and "fsdp" in only:
        current = bench_fsdp(args.quick)
        if args.check_against:
            with open(args.check_against) as f:
                baseline = json.load(f)
            fails = check_fsdp_regression(current, baseline)
            for f_msg in fails:
                print(f"REGRESSION: {f_msg}", flush=True)
            if fails:
                sys.exit(1)
            print("# fsdp: regression gate passed", flush=True)
    if not only or "serve" in only:
        bench_serve(args.quick)
    if not only or "roofline" in only:
        bench_roofline(args.quick)
    if not only or "speedup" in only:
        bench_speedup(args.quick)
    if not only or "convergence" in only:
        bench_convergence(args.quick)
    if _SINK is not None:
        _SINK.close()
        _SINK = None


if __name__ == "__main__":
    main()
