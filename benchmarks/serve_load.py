"""Serving load generator + open-loop driver for `benchmarks.run --only
serve`.

Two layers, mirroring the sched section's measured/modeled split:

* `gen_requests` / `run_open_loop` — a seeded Poisson arrival stream
  driven against a real `repro.serve.Engine` on a *virtual clock*: the
  clock advances by each decode step's measured wall time and jumps to
  the next arrival when the engine idles, so offered QPS is exact and
  reproducible regardless of host speed. Per-request latency is
  (virtual finish − virtual arrival).

* `serve_model_rows` — a pure-python discrete-event model of the same
  engine semantics (floor-bucket prefill + tail decode, FIFO head-of-line
  admission, block-granular KV) under a fixed cost model. No jax, no
  timers: bit-identical on every host, which is what the benchmark
  regression gate keys on (rows carry a config hash under "strategy",
  matching check_sched_regression's row identity).
"""
from __future__ import annotations

import hashlib
import json
import random
import time
from typing import Dict, List

from repro.serve import Request, ServeConfig, floor_bucket, plan_request


def percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile (no numpy needed for the model rows)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    i = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
    return s[i]


def gen_requests(n: int, qps: float, *, seed: int, vocab: int,
                 max_prompt: int, max_new: int,
                 min_prompt: int = 2) -> List[Request]:
    """Seeded open-loop workload: exponential interarrivals at `qps`,
    uniform prompt lengths in [min_prompt, max_prompt], fixed max_new."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(qps)
        plen = rng.randint(min_prompt, max_prompt)
        prompt = [rng.randrange(1, vocab) for _ in range(plen)]
        out.append(Request(rid=i, prompt=prompt, max_new=max_new,
                           arrival=t))
    return out


def run_open_loop(engine, requests, *, rid_base: int = 0,
                  time_fn=time.perf_counter) -> Dict:
    """Drive `engine` with arrival-timed requests on a virtual clock.

    Steps run for real (measured wall feeds the clock); arrivals are
    virtual. `rid_base` offsets request ids so one warm engine can serve
    several sweeps without rid collisions. Returns latency percentiles,
    throughput, and KV-block occupancy stats."""
    pending = sorted(requests, key=lambda r: r.arrival)
    arrivals = {}
    for r in pending:
        r.rid += rid_base
        arrivals[r.rid] = r.arrival
    i = 0
    clock = 0.0
    seen = set(engine.completed)
    finish: Dict[int, float] = {}
    step_walls: List[float] = []
    occupancy: List[float] = []
    while i < len(pending) or not engine.idle:
        while i < len(pending) and pending[i].arrival <= clock + 1e-12:
            engine.submit(pending[i])
            i += 1
        if engine.idle and i < len(pending):
            clock = max(clock, pending[i].arrival)
            continue
        t0 = time_fn()
        engine.step()
        step_walls.append(time_fn() - t0)
        clock += step_walls[-1]
        occupancy.append(engine.alloc.occupancy())
        for rid in engine.completed - seen:
            finish[rid] = clock
            seen.add(rid)
    lats = [finish[rid] - arrivals[rid] for rid in finish]
    toks = sum(len(engine.outputs[rid]) for rid in finish)
    return {
        "n_requests": len(pending),
        "generated_tokens": toks,
        "clock_s": round(clock, 4),
        "tokens_per_s": round(toks / max(clock, 1e-9), 2),
        "latency_p50_s": round(percentile(lats, 50), 4),
        "latency_p99_s": round(percentile(lats, 99), 4),
        "mean_step_s": round(sum(step_walls) / max(len(step_walls), 1), 6),
        "steps": len(step_walls),
        "kv_occupancy_mean": round(
            sum(occupancy) / max(len(occupancy), 1), 4),
        "kv_occupancy_peak": round(max(occupancy, default=0.0), 4),
    }


# --------------------------------------------------------------------------- #
# deterministic engine model (the gated rows)
# --------------------------------------------------------------------------- #
SERVE_MODEL = {
    # engine shapes (mirrors a small-production ServeConfig)
    "max_batch": 8, "block_size": 16, "num_blocks": 96,
    "max_blocks_per_seq": 8, "prompt_buckets": (16, 32, 64),
    # cost model: step wall = t_step + t_token * live_slots; prefill wall
    # amortized into the admitting step
    "t_step_s": 2e-3, "t_token_s": 1e-4, "t_prefill_s": 4e-3,
    # workload
    "n_requests": 64, "max_prompt": 56, "max_new": 24, "seed": 0,
}
SERVE_MODEL_QPS = (5.0, 20.0, 80.0)


def _model_hash(qps: float) -> str:
    blob = json.dumps({"model": {k: list(v) if isinstance(v, tuple) else v
                                 for k, v in SERVE_MODEL.items()},
                       "qps": qps}, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def serve_model_rows() -> List[Dict]:
    """Simulate the engine's admission/decode schedule under the fixed
    cost model at each offered QPS. Pure python + seeded random: the
    regression gate compares these rows across hosts (gated field:
    mean_step_s; latency/throughput ride along for the artifact)."""
    m = SERVE_MODEL
    scfg = ServeConfig(max_batch=m["max_batch"], block_size=m["block_size"],
                       num_blocks=m["num_blocks"],
                       max_blocks_per_seq=m["max_blocks_per_seq"],
                       prompt_buckets=tuple(m["prompt_buckets"]))
    rows = []
    for qps in SERVE_MODEL_QPS:
        rng = random.Random(m["seed"])
        t = 0.0
        reqs = []
        for i in range(m["n_requests"]):
            t += rng.expovariate(qps)
            plen = rng.randint(2, m["max_prompt"])
            reqs.append((i, t, plen))
        # each request costs (P - F) + (max_new - 1) decode steps and
        # ceil((P + max_new - 1)/bs) blocks — exactly plan_request
        queue = list(reqs)
        slots = [None] * scfg.max_batch          # (rid, steps_left)
        free_blocks = scfg.num_blocks - 1
        held: Dict[int, int] = {}
        clock = 0.0
        finish: Dict[int, float] = {}
        step_walls: List[float] = []
        occ: List[float] = []
        qi = 0
        while qi < len(queue) or any(s is not None for s in slots):
            # admit FIFO head-of-line among arrived requests
            admitted_prefill = 0
            while qi < len(queue) and queue[qi][1] <= clock + 1e-12:
                rid, _, plen = queue[qi]
                bucket, n_blocks = plan_request(plen, m["max_new"], scfg)
                idx = next((k for k, s in enumerate(slots) if s is None),
                           None)
                if idx is None or n_blocks > free_blocks:
                    break
                free_blocks -= n_blocks
                held[rid] = n_blocks
                steps = (plen - bucket) + (m["max_new"] - 1)
                slots[idx] = (rid, steps)
                if bucket:
                    admitted_prefill += 1
                qi += 1
            live = sum(1 for s in slots if s is not None)
            if live == 0:
                if qi < len(queue):
                    clock = max(clock, queue[qi][1])
                    continue
                break
            dt = (m["t_step_s"] + m["t_token_s"] * live
                  + m["t_prefill_s"] * admitted_prefill)
            clock += dt
            step_walls.append(dt)
            used = scfg.num_blocks - 1 - free_blocks
            occ.append(used / (scfg.num_blocks - 1))
            for k, s in enumerate(slots):
                if s is None:
                    continue
                rid, left = s
                left -= 1
                if left <= 0:
                    finish[rid] = clock
                    free_blocks += held.pop(rid)
                    slots[k] = None
                else:
                    slots[k] = (rid, left)
        lats = [finish[rid] - arr for rid, arr, _ in reqs]
        toks = m["max_new"] * len(reqs)
        rows.append({
            "qps": qps,
            "strategy": _model_hash(qps),
            "mean_step_s": round(
                sum(step_walls) / max(len(step_walls), 1), 8),
            "tokens_per_s": round(toks / max(clock, 1e-9), 2),
            "latency_p50_s": round(percentile(lats, 50), 6),
            "latency_p99_s": round(percentile(lats, 99), 6),
            "kv_occupancy_peak": round(max(occ, default=0.0), 4),
            "steps": len(step_walls),
        })
    return rows


__all__ = ["gen_requests", "run_open_loop", "serve_model_rows",
           "percentile", "floor_bucket", "SERVE_MODEL", "SERVE_MODEL_QPS"]
