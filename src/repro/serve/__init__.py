"""repro.serve — continuous-batching inference runtime (DESIGN.md §14).

Paged KV cache (kv_cache), the batching engine (engine), a sequential
batch-1 oracle/baseline (baseline), and strategy-driven load-time weight
quantization (quantized_weights).
"""
from .baseline import SequentialGenerator
from .engine import Engine, Request, sample_token
from .kv_cache import (
    BlockAllocator,
    CacheStats,
    SCRATCH_BLOCK,
    ServeConfig,
    ServeError,
    cdiv,
    check_model_servable,
    dense_cache_len,
    floor_bucket,
    init_paged_cache,
    plan_request,
    required_tokens,
)
from .quantized_weights import (
    WeightQuantMeta,
    dequantize_weights,
    quantize_weights,
)

__all__ = [
    "BlockAllocator", "CacheStats", "Engine", "Request", "SCRATCH_BLOCK",
    "SequentialGenerator", "ServeConfig", "ServeError", "WeightQuantMeta",
    "cdiv", "check_model_servable", "dense_cache_len", "dequantize_weights",
    "floor_bucket", "init_paged_cache", "plan_request", "quantize_weights",
    "required_tokens", "sample_token",
]
