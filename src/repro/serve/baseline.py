"""Sequential (batch-1) generation — the engine's correctness oracle and
throughput baseline.

Runs the same floor-bucket prefill + tail-decode schedule and the same
`sample_token` draw as the continuous-batching engine, over the model's
*dense* decode cache sized once to `ServeConfig.max_context` (the same
gathered length the paged decode reduces over, so engine-vs-baseline
token equality is bit-exact, not approximate). Compiled functions are
hoisted and cached per prompt bucket — this class is also the fix for
the old launcher's per-call re-jit (`trace` counters pin it in tests).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.models import model as lm

from .engine import sample_token
from .kv_cache import (
    ServeConfig,
    check_model_servable,
    dense_cache_len,
    plan_request,
)
from .quantized_weights import dequantize_weights, quantize_weights


class SequentialGenerator:
    """One request at a time over a dense cache; same tokens as Engine."""

    def __init__(self, cfg, serve_cfg: ServeConfig, params, *,
                 compression=None, seed: int = 0, interpret: bool = True):
        check_model_servable(cfg)
        self.cfg = cfg
        self.scfg = serve_cfg
        self.weight_meta = None
        if compression is not None:
            self.weight_meta, self._weights = quantize_weights(
                params, compression, seed=seed, interpret=interpret)
        else:
            self._weights = params
        self._base_key = jax.random.key(seed)
        self.decode_traces: List[int] = []
        self.prefill_traces: Dict[int, int] = {}
        self.steps = 0
        self._decode = jax.jit(self._decode_impl)
        self._prefills: Dict[int, object] = {}

    def _dequant(self, weights):
        if self.weight_meta is None:
            return weights
        return dequantize_weights(self.weight_meta, weights)

    def _decode_impl(self, weights, tokens, caches):
        self.decode_traces.append(1)
        params = self._dequant(weights)
        return lm.decode_step(params, self.cfg, tokens, caches)

    def _prefill_for(self, bucket: int):
        if bucket not in self._prefills:
            max_len = dense_cache_len(self.scfg)

            def fn(weights, tokens):
                self.prefill_traces[bucket] = \
                    self.prefill_traces.get(bucket, 0) + 1
                params = self._dequant(weights)
                return lm.prefill(params, self.cfg, tokens, None,
                                  max_len=max_len)
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def generate(self, prompt: List[int], max_new: int, *, rid: int = 0,
                 temperature: float = 0.0,
                 stop_token: Optional[int] = None) -> List[int]:
        bucket, _ = plan_request(len(prompt), max_new, self.scfg)
        out: List[int] = []

        if bucket > 0:
            logits, caches = self._prefill_for(bucket)(
                self._weights, np.asarray([prompt[:bucket]], np.int32))
        else:
            logits = None
            caches = lm.init_cache(self.cfg, 1, dense_cache_len(self.scfg))
        to_feed = list(prompt[bucket:])

        last = 0
        if not to_feed:                      # bucket == len(prompt)
            tok = sample_token(logits[0], temperature, rid, 0,
                               self._base_key)
            out.append(tok)
            if max_new == 1 or tok == stop_token:
                return out
            last = tok

        while True:
            inp = to_feed[0] if to_feed else last
            logits, caches = self._decode(
                self._weights, np.asarray([[inp]], np.int32), caches)
            self.steps += 1
            if to_feed:
                to_feed.pop(0)
                if to_feed:
                    continue                 # still consuming the prompt
            tok = sample_token(logits[0], temperature, rid, len(out),
                               self._base_key)
            out.append(tok)
            if len(out) >= max_new or tok == stop_token:
                return out
            last = tok

    def stats(self) -> dict:
        return {
            "decode_traces": len(self.decode_traces),
            "prefill_traces": dict(self.prefill_traces),
            "steps": self.steps,
            "weights": (self.weight_meta.describe()
                        if self.weight_meta else "f32"),
        }
