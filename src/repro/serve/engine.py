"""Continuous-batching serving engine (DESIGN.md §14).

One fixed-shape jitted decode step serves `max_batch` slots; requests
join and leave at decode-step granularity without retracing (the trace
counters are part of the public stats, and CI asserts exactly one decode
trace across churn). Prefill runs per request at a *floor* bucket — the
largest configured bucket that fits inside the prompt — and the
remaining prompt tail is fed through the shared decode step one token
per step (chunked prefill). No pad token ever enters the model, which is
what keeps recurrent mixers (RG-LRU / SSD) exact: their prefill state is
the state of the true prompt, not of a right-padded one.

Token accounting per request (prompt length P, floor bucket F ≤ P,
max_new G): prefill covers positions 0..F-1; decode steps consume
prompt[F..P-1] then the sampled tokens, writing positions F..P+G-2; the
step that consumes prompt[P-1] (or the prefill itself when F == P)
yields generated token 0, so a request costs (P-F) + (G-1) decode steps
and P+G-1 KV positions. The sequential baseline (baseline.py) runs the
identical graphs at batch 1, which is what makes engine-vs-sequential
token equality exact rather than approximate.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models import model as lm

from .kv_cache import (
    BlockAllocator,
    ServeConfig,
    ServeError,
    check_model_servable,
    init_paged_cache,
    plan_request,
)
from .quantized_weights import dequantize_weights, quantize_weights


# --------------------------------------------------------------------------- #
# requests
# --------------------------------------------------------------------------- #
@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    stop_token: Optional[int] = None
    arrival: float = 0.0            # virtual seconds (load generator clock)


def sample_token(logits_row, temperature: float, rid: int, index: int,
                 base_key) -> int:
    """Shared by the engine and the sequential baseline so both draw the
    same token from the same logits: greedy argmax at temperature <= 0,
    else categorical under a (seed, rid, token-index) key — independent of
    scheduling order, so continuous batching cannot perturb sampling."""
    if temperature <= 0.0:
        return int(jnp.argmax(logits_row))
    k = jax.random.fold_in(jax.random.fold_in(base_key, rid), index)
    return int(jax.random.categorical(
        k, logits_row.astype(jnp.float32) / temperature))


# --------------------------------------------------------------------------- #
# cache tree surgery (all shapes static per bucket — each walk jits once)
# --------------------------------------------------------------------------- #
def _walk(paged, pre, attn_fn, state_fn, stacked):
    """Parallel walk of the paged cache (template) and a prefill cache;
    attn leaves are dicts with a "table", everything else recurses down to
    recurrent-state arrays."""
    if isinstance(paged, dict):
        if "table" in paged:
            return attn_fn(paged, pre, stacked)
        return {k: _walk(paged[k], None if pre is None else pre[k],
                         attn_fn, state_fn, stacked) for k in paged}
    if isinstance(paged, list):
        return [_walk(a, None if pre is None else b, attn_fn, state_fn,
                      stacked)
                for a, b in zip(paged, pre if pre is not None else paged)]
    return state_fn(paged, pre, stacked)


def _map_cache(cache, attn_fn, state_fn, pre=None):
    out = {"scan": None, "tail": []}
    if cache.get("scan") is not None:
        out["scan"] = _walk(cache["scan"],
                            None if pre is None else pre["scan"],
                            attn_fn, state_fn, stacked=True)
    for i, leaf in enumerate(cache.get("tail", [])):
        out["tail"].append(_walk(
            leaf, None if pre is None else pre["tail"][i],
            attn_fn, state_fn, stacked=False))
    return out


def _park_tables(cache, active):
    """Point every inactive slot's table row at the scratch block, so the
    fixed-shape decode's writes for vacated slots can never land in a
    block the allocator has handed to a live request."""
    def attn(leaf, _, stacked):
        mask = active[None, :, None] if stacked else active[:, None]
        return dict(leaf, table=jnp.where(mask, leaf["table"], 0))

    return _map_cache(cache, attn, lambda s, _, st: s)


def _insert_prefill(cache, pre, slot, block_ids, row, block_size):
    """Scatter a batch-1 prefill cache into `slot`: K/V into the request's
    pool blocks (whole blocks — buckets are block-aligned), the block map
    into the slot's table row, recurrent states into the slot's lane."""
    nb = block_ids.shape[0]

    def attn(leaf, p, stacked):
        k, v = p["k"], p["v"]
        if stacked:
            ns = k.shape[0]
            kb = k[:, 0].reshape(ns, nb, block_size, *k.shape[3:])
            vb = v[:, 0].reshape(ns, nb, block_size, *v.shape[3:])
            return {"k": leaf["k"].at[:, block_ids].set(kb),
                    "v": leaf["v"].at[:, block_ids].set(vb),
                    "table": leaf["table"].at[:, slot].set(row)}
        kb = k[0].reshape(nb, block_size, *k.shape[2:])
        vb = v[0].reshape(nb, block_size, *v.shape[2:])
        return {"k": leaf["k"].at[block_ids].set(kb),
                "v": leaf["v"].at[block_ids].set(vb),
                "table": leaf["table"].at[slot].set(row)}

    def state(leaf, p, stacked):
        if stacked:
            return leaf.at[:, slot].set(p[:, 0])
        return leaf.at[slot].set(p[0])

    return _map_cache(cache, attn, state, pre=pre)


def _claim_slot(cache, slot, row):
    """Admission without prefill (prompt shorter than every bucket): write
    the block map and zero the slot's recurrent state lanes (the previous
    occupant's state must not leak into a fresh request)."""
    def attn(leaf, _, stacked):
        if stacked:
            return dict(leaf, table=leaf["table"].at[:, slot].set(row))
        return dict(leaf, table=leaf["table"].at[slot].set(row))

    def state(leaf, _, stacked):
        if stacked:
            return leaf.at[:, slot].set(jnp.zeros_like(leaf[:, slot]))
        return leaf.at[slot].set(jnp.zeros_like(leaf[slot]))

    return _map_cache(cache, attn, state)


# --------------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------------- #
@dataclass
class _Slot:
    rid: int
    to_feed: List[int]              # prompt tokens not yet consumed
    blocks: List[int]
    max_new: int
    temperature: float
    stop_token: Optional[int]
    last_tok: int = 0
    emitted: int = 0


class Engine:
    """Continuous-batching decode over a paged KV cache.

    submit() enqueues; step() admits whatever fits (FIFO — the head blocks
    the queue until slots AND blocks are free, a deliberate no-starvation
    policy), runs ONE fixed-shape decode for all live slots, samples, and
    releases finished requests' blocks back to the free list. All compiled
    functions are built once: `decode_traces` must stay at 1 forever.
    """

    def __init__(self, cfg, serve_cfg: ServeConfig, params, *,
                 compression=None, seed: int = 0, attn_impl: str = "gather",
                 interpret: bool = True):
        check_model_servable(cfg)
        if attn_impl not in ("gather", "pallas"):
            raise ServeError(f"attn_impl must be gather|pallas, "
                             f"got {attn_impl!r}")
        self.cfg = cfg
        self.scfg = serve_cfg
        self.attn_impl = attn_impl
        self.weight_meta = None
        if compression is not None:
            self.weight_meta, self._weights = quantize_weights(
                params, compression, seed=seed, interpret=interpret)
        else:
            self._weights = params

        self.cache = init_paged_cache(cfg, serve_cfg)
        self.alloc = BlockAllocator(serve_cfg.num_blocks)
        self.slots: List[Optional[_Slot]] = [None] * serve_cfg.max_batch
        self._lengths = [0] * serve_cfg.max_batch
        self.queue: deque = deque()
        self.outputs: Dict[int, List[int]] = {}
        self.completed = set()
        self._base_key = jax.random.key(seed)

        self.decode_traces: List[int] = []
        self.prefill_traces: Dict[int, int] = {}
        self.steps = 0
        self.peak_occupancy = 0.0

        self._decode = jax.jit(self._decode_impl)
        self._prefills: Dict[int, object] = {}
        self._inserts: Dict[int, object] = {}
        self._claim = jax.jit(self._claim_impl)

    # -- compiled pieces --------------------------------------------------- #
    def _dequant(self, weights):
        if self.weight_meta is None:
            return weights
        return dequantize_weights(self.weight_meta, weights)

    def _decode_impl(self, weights, cache, tokens, lengths, active):
        self.decode_traces.append(1)
        prev = layers.set_paged_attn_impl(self.attn_impl)
        try:
            params = self._dequant(weights)
            cache = _park_tables(cache, active)
            logits, cache = lm.decode_step_paged(params, self.cfg, tokens,
                                                 cache, lengths)
        finally:
            layers.set_paged_attn_impl(prev)
        return logits, cache

    def _claim_impl(self, cache, slot, row):
        return _claim_slot(cache, slot, row)

    def _prefill_for(self, bucket: int):
        if bucket not in self._prefills:
            def fn(weights, tokens):
                self.prefill_traces[bucket] = \
                    self.prefill_traces.get(bucket, 0) + 1
                params = self._dequant(weights)
                return lm.prefill(params, self.cfg, tokens)
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _insert_for(self, bucket: int):
        if bucket not in self._inserts:
            bs = self.scfg.block_size
            self._inserts[bucket] = jax.jit(
                lambda cache, pre, slot, block_ids, row:
                _insert_prefill(cache, pre, slot, block_ids, row, bs))
        return self._inserts[bucket]

    # -- request lifecycle ------------------------------------------------- #
    def submit(self, req: Request) -> None:
        if req.rid in self.outputs:
            raise ServeError(f"duplicate request id {req.rid}")
        bucket, n_blocks = plan_request(len(req.prompt), req.max_new,
                                        self.scfg)
        self.outputs[req.rid] = []
        self.queue.append((req, bucket, n_blocks))

    def _sample(self, logits_row, s: _Slot) -> int:
        return sample_token(logits_row, s.temperature, s.rid, s.emitted,
                            self._base_key)

    def _finish(self, idx: int, s: _Slot) -> None:
        self.alloc.free(s.blocks)
        self.slots[idx] = None
        self._lengths[idx] = 0
        self.completed.add(s.rid)

    def _try_admit(self) -> None:
        while self.queue:
            req, bucket, n_blocks = self.queue[0]
            P = len(req.prompt)
            if bucket == P and req.max_new == 1:
                # generated token 0 falls out of the prefill logits: the
                # request completes without ever occupying a decode slot
                self.queue.popleft()
                logits, _ = self._prefill_for(bucket)(
                    self._weights,
                    np.asarray([req.prompt], np.int32))
                tok = sample_token(logits[0], req.temperature, req.rid, 0,
                                   self._base_key)
                self.outputs[req.rid].append(tok)
                self.completed.add(req.rid)
                continue
            idx = next((i for i, s in enumerate(self.slots) if s is None),
                       None)
            if idx is None or n_blocks > self.alloc.free_blocks:
                return                        # FIFO: head blocks the queue
            self.queue.popleft()
            self._admit(req, bucket, n_blocks, idx)

    def _admit(self, req: Request, bucket: int, n_blocks: int,
               idx: int) -> None:
        blocks = self.alloc.alloc(n_blocks)
        row = np.zeros(self.scfg.max_blocks_per_seq, np.int32)
        row[:n_blocks] = blocks
        s = _Slot(rid=req.rid, to_feed=list(req.prompt[bucket:]),
                  blocks=blocks, max_new=req.max_new,
                  temperature=req.temperature, stop_token=req.stop_token)
        if bucket > 0:
            logits, pre = self._prefill_for(bucket)(
                self._weights, np.asarray([req.prompt[:bucket]], np.int32))
            nb_prefill = bucket // self.scfg.block_size
            self.cache = self._insert_for(bucket)(
                self.cache, pre, np.int32(idx),
                np.asarray(blocks[:nb_prefill], np.int32), row)
            if not s.to_feed:               # bucket == P: token 0 is here
                tok = self._sample(logits[0], s)
                self.outputs[s.rid].append(tok)
                s.emitted = 1
                if tok == s.stop_token:     # max_new == 1 handled pre-slot
                    self.alloc.free(blocks)
                    self.completed.add(s.rid)
                    return
                s.last_tok = tok
        else:
            self.cache = self._claim(self.cache, np.int32(idx), row)
        self._lengths[idx] = bucket
        self.slots[idx] = s
        self.peak_occupancy = max(self.peak_occupancy,
                                  self.alloc.occupancy())

    def step(self) -> bool:
        """Admit + one batched decode + sample/release. Returns False when
        there was nothing to do (no live slots after admission)."""
        self._try_admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return False
        B = self.scfg.max_batch
        tokens = np.zeros((B, 1), np.int32)
        active = np.zeros((B,), bool)
        for i in live:
            s = self.slots[i]
            tokens[i, 0] = s.to_feed[0] if s.to_feed else s.last_tok
            active[i] = True
        lengths = np.asarray(self._lengths, np.int32)
        logits, self.cache = self._decode(self._weights, self.cache, tokens,
                                          lengths, active)
        self.steps += 1
        for i in live:
            s = self.slots[i]
            self._lengths[i] += 1
            if s.to_feed:
                s.to_feed.pop(0)
                if s.to_feed:
                    continue                 # still consuming the prompt
            tok = self._sample(logits[i], s)
            self.outputs[s.rid].append(tok)
            s.emitted += 1
            if s.emitted >= s.max_new or tok == s.stop_token:
                self._finish(i, s)
            else:
                s.last_tok = tok
        return True

    # -- driving ----------------------------------------------------------- #
    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def run(self, requests) -> Dict[int, List[int]]:
        """Drain a batch of requests (arrival times ignored — closed loop);
        returns {rid: generated tokens}."""
        for r in sorted(requests, key=lambda r: r.arrival):
            self.submit(r)
        while not self.idle:
            if not self.step() and self.queue:
                raise ServeError(
                    "admission deadlock: queue non-empty but nothing "
                    "admitted with all slots free")
        return self.outputs

    def stats(self) -> dict:
        live_tokens = sum(self._lengths)
        return {
            "decode_traces": len(self.decode_traces),
            "prefill_traces": dict(self.prefill_traces),
            "steps": self.steps,
            "occupancy": self.alloc.occupancy(),
            "peak_occupancy": self.peak_occupancy,
            "live_tokens": live_tokens,
            "weights": (self.weight_meta.describe()
                        if self.weight_meta else "f32"),
        }
