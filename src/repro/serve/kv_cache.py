"""Paged KV-cache for the serving engine (DESIGN.md §14.2).

Attention K/V live in fixed-size blocks inside per-layer pools of shape
(num_blocks, block_size, kv_heads, head_dim); each batch slot owns a row
of a block table mapping logical block index -> pool block id. Memory
then scales with *live tokens* (blocks actually allocated) instead of
max_seq x max_batch dense buffers, and a finished request's blocks go
straight back on the free list for the next admission.

Block id 0 is a reserved scratch block: the engine parks the table rows
of inactive slots there, so the garbage decode writes those slots still
perform (the decode step has a fixed shape — every slot computes every
step) can never land in a block owned by a live request.

This module is also the single owner of cache *sizing*: the sequential
baseline and the engine both size their context through
``plan_request`` / ``max_context``, replacing the per-call
``S + gen_steps + 1`` arithmetic the old launcher re-derived (and got
subtly wrong) on every ``generate()`` call. Prefill uses *floor* buckets
(largest bucket <= prompt length; the prompt tail feeds through decode
steps) — see ``floor_bucket``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax.numpy as jnp

from repro.models import model as lm

SCRATCH_BLOCK = 0   # never allocated; parked (inactive) slots write here


class ServeError(Exception):
    """Invalid serving configuration or request (sizing, admission)."""


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class ServeConfig:
    """Static serving shapes. Frozen/hashable: safe to close over in the
    engine's jitted step (any change is a new engine, a new compile)."""

    max_batch: int = 8              # decode slots (fixed jitted batch)
    block_size: int = 16            # tokens per KV block
    num_blocks: int = 256           # pool blocks per attention layer
    max_blocks_per_seq: int = 16    # block-table width (rows per slot)
    prompt_buckets: Tuple[int, ...] = (32, 64, 128)  # floor prefill shapes

    def __post_init__(self):
        if self.max_batch < 1:
            raise ServeError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.block_size < 1:
            raise ServeError(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks < 2:
            raise ServeError(
                f"num_blocks must be >= 2 (block 0 is the reserved scratch "
                f"block), got {self.num_blocks}")
        if not self.prompt_buckets or \
                tuple(sorted(self.prompt_buckets)) != tuple(self.prompt_buckets):
            raise ServeError(
                f"prompt_buckets must be a non-empty ascending tuple, got "
                f"{self.prompt_buckets}")
        for b in self.prompt_buckets:
            if b % self.block_size:
                raise ServeError(
                    f"prompt bucket {b} is not a multiple of block_size="
                    f"{self.block_size} (prefill K/V scatter fills whole "
                    f"blocks)")
            if b > self.max_context:
                raise ServeError(
                    f"prompt bucket {b} exceeds max_context="
                    f"{self.max_context} (= block_size x max_blocks_per_seq)")

    @property
    def max_context(self) -> int:
        """Largest context (prompt + generated) a slot can hold."""
        return self.block_size * self.max_blocks_per_seq


# --------------------------------------------------------------------------- #
# sizing (the one place context arithmetic lives)
# --------------------------------------------------------------------------- #
def floor_bucket(prompt_len: int, cfg: ServeConfig) -> int:
    """Largest prefill bucket that fits *inside* the prompt (0 = skip
    prefill; the whole prompt feeds through decode steps). Floor instead
    of ceiling so prefill never sees a pad token — which is what keeps
    recurrent mixers (RG-LRU / SSD) exact: a right-padded prefill would
    bake the pad positions into their final state."""
    best = 0
    for b in cfg.prompt_buckets:
        if b <= prompt_len:
            best = b
    return best


def required_tokens(prompt_len: int, gen_steps: int, cfg: ServeConfig) -> int:
    """Context positions a request touches: prompt_len + gen_steps - 1
    (generated token 0 comes from the logits of the last prompt token, so
    it costs no extra KV position)."""
    del cfg
    if gen_steps < 1:
        raise ServeError(f"gen_steps must be >= 1, got {gen_steps}")
    if prompt_len < 1:
        raise ServeError(f"empty prompt (prompt_len={prompt_len})")
    return prompt_len + gen_steps - 1


def plan_request(prompt_len: int, gen_steps: int,
                 cfg: ServeConfig) -> Tuple[int, int]:
    """Check a (prompt_len, gen_steps) request fits the block budget;
    returns (prefill_bucket, total_blocks_needed). Raises ServeError with
    the violated limit spelled out instead of letting the decode step
    silently write past the table — this replaces the per-call
    ``S + gen_steps + 1`` arithmetic the old launcher re-derived (and got
    subtly wrong) on every ``generate()`` call."""
    tokens = required_tokens(prompt_len, gen_steps, cfg)
    if tokens > cfg.max_context:
        raise ServeError(
            f"request needs {tokens} context tokens (prompt={prompt_len}, "
            f"gen={gen_steps}) but the block table holds only "
            f"max_context={cfg.max_context} (= block_size={cfg.block_size} "
            f"x max_blocks_per_seq={cfg.max_blocks_per_seq}); raise "
            f"max_blocks_per_seq or lower the generation length")
    n_blocks = cdiv(tokens, cfg.block_size)
    if n_blocks > cfg.num_blocks - 1:
        raise ServeError(
            f"request needs {n_blocks} KV blocks but the pool only has "
            f"{cfg.num_blocks - 1} allocatable blocks; raise "
            f"ServeConfig.num_blocks")
    return floor_bucket(prompt_len, cfg), n_blocks


def dense_cache_len(cfg: ServeConfig) -> int:
    """Context length for the *dense* sequential baseline — identical to
    the paged engine's gathered length, so engine-vs-baseline decode runs
    the same-shape reductions (the bit-exact equivalence tests rely on
    this)."""
    return cfg.max_context


# --------------------------------------------------------------------------- #
# free-list block allocator (host side)
# --------------------------------------------------------------------------- #
class BlockAllocator:
    """LIFO free-list over block ids 1..num_blocks-1 (0 is scratch)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ServeError(f"need >= 2 blocks, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._used = set()

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._used)

    def occupancy(self) -> float:
        return self.used_blocks / self.capacity

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise ServeError(
                f"out of KV blocks: requested {n}, {len(self._free)} free of "
                f"{self.capacity} (raise ServeConfig.num_blocks or admit "
                f"fewer concurrent requests)")
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, ids) -> None:
        for b in ids:
            if b not in self._used:
                raise ServeError(f"double free of block {b}")
            self._used.remove(b)
            self._free.append(b)


# --------------------------------------------------------------------------- #
# paged cache construction
# --------------------------------------------------------------------------- #
def check_model_servable(cfg) -> None:
    """The paged engine serves decoder LMs with global attention and/or
    recurrent mixers. Fail fast with the reason otherwise."""
    if getattr(cfg, "is_encdec", False):
        raise ServeError(
            f"{cfg.name}: encoder-decoder models are not supported by the "
            f"paged serving engine (cross-attention caches are not paged)")
    kinds = set(lm.pattern_kinds(cfg))
    if "attn" in kinds and cfg.attention_window > 0:
        raise ServeError(
            f"{cfg.name}: sliding-window attention (attention_window="
            f"{cfg.attention_window}) is not supported by the paged KV "
            f"cache; the rolling dense cache already bounds its memory")


def _paged_attn_leaf(cfg, scfg: ServeConfig, dtype):
    pool = (scfg.num_blocks, scfg.block_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(pool, dtype),
        "v": jnp.zeros(pool, dtype),
        "table": jnp.full((scfg.max_batch, scfg.max_blocks_per_seq),
                          SCRATCH_BLOCK, jnp.int32),
    }


def init_paged_cache(cfg, scfg: ServeConfig, dtype=None):
    """Cache pytree with the same {"scan": {...}, "tail": [...]} structure
    as model.init_cache, but attention leaves are paged
    {"k": pool, "v": pool, "table": (max_batch, max_blocks_per_seq)} and
    recurrent leaves are (max_batch, ...) states."""
    import jax

    check_model_servable(cfg)
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    kinds = lm.pattern_kinds(cfg)
    period = len(cfg.layer_pattern)
    n_scan = cfg.num_layers // period if cfg.scan_layers else 0

    def one(kind):
        if kind == "attn":
            return _paged_attn_leaf(cfg, scfg, dtype)
        return lm.block_cache_init(cfg, kind, scfg.max_batch, 0, dtype)

    caches = {"scan": None, "tail": []}
    if n_scan:
        period_cache = {f"b{i}": one(cfg.layer_pattern[i])
                        for i in range(period)}
        caches["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            period_cache,
        )
    for kind in kinds[n_scan * period:]:
        caches["tail"].append(one(kind))
    return caches


@dataclass
class CacheStats:
    """Occupancy snapshot for telemetry / bench rows."""
    used_blocks: int
    capacity: int
    live_tokens: int
    occupancy: float = field(init=False)

    def __post_init__(self):
        self.occupancy = self.used_blocks / max(self.capacity, 1)
