"""Load-time weight quantization for serving (DESIGN.md §14.4).

Training and inference share one quantization story: the same
`repro.strategy.Compression` component that describes wire compression
describes serving-time weight precision, the same `repro.comm` bucket
layout carves the parameter tree into lane-aligned flat buckets, the same
`plan_comm` planner assigns a per-bucket bit-width (uniform /
size_tiered / delta_budget — per-layer bits via the existing descent),
and the same Pallas `quantize_ef_flat` kernel produces the int8 codes
(run once at load with a zero residual: plain stochastic rounding).

Honored `Compression` fields (the serving contract, DESIGN.md §14.4):
  compressor — must be a linf `StochasticQuant` (any per_block); sets the
               base bit-width. Scale granularity is the kernel's 1024-row
               tiling regardless of per_block (the bucket-native layout).
  plan       — "none"→uniform, else the planner policy verbatim.
  bucket_mb  — f32 MiB per weight bucket.
  budget_mb  — delta_budget target, interpreted as payload MiB of weights.
Ignored: error_feedback / ef_dtype (one-shot quantization carries no
residual stream) and adaptive (no participation axis at serve time).
Buckets the plan leaves at "identity" (size_tiered's small-tensor tier)
stay raw f32.

Quantization is seeded: same params + component + seed → bit-identical
codes, so an engine restart decodes bit-identically (pinned in tests).
Dequantization happens inside the jitted prefill/decode steps
(dequant-on-read): the payload is the traced argument, weights rebuild
per step from int8 codes + f32 scales.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.comm.buckets import BucketLayout, layout_for_params, unpack_into
from repro.comm.planner import CommPlan, plan_comm
from repro.core import compressors as C
from repro.kernels.quantize import bucket_tile_shape, quantize_ef_flat

from .kv_cache import ServeError


@dataclass(frozen=True)
class WeightQuantMeta:
    """Static recipe (jit-safe closure state) for dequantizing a payload."""
    layout: BucketLayout
    plan: CommPlan
    treedef: Any
    leaf_structs: Tuple[Any, ...]       # ShapeDtypeStruct per leaf
    levels: Tuple[int, ...]             # per bucket; 0 = raw f32 bucket
    bits: Tuple[int, ...]               # per bucket; 32 = raw

    @property
    def payload_bytes(self) -> int:
        total = 0
        for b in self.layout.buckets:
            if self.levels[b.bid]:
                rows, _, _ = bucket_tile_shape(b.size)
                total += b.size + 4 * rows          # int8 codes + f32 scales
            else:
                total += 4 * b.size
        return total

    @property
    def f32_bytes(self) -> int:
        return 4 * sum(b.size for b in self.layout.buckets)

    def describe(self) -> str:
        mix: Dict[int, int] = {}
        for bt in self.bits:
            mix[bt] = mix.get(bt, 0) + 1
        bits = " ".join(f"{b}bx{n}" for b, n in sorted(mix.items()))
        return (f"weights[{len(self.layout.buckets)} buckets {bits}] "
                f"{self.payload_bytes / 2**20:.2f} MiB "
                f"({self.payload_bytes / max(self.f32_bytes, 1):.2%} of f32)")


def _resolve_plan(params, compression) -> Tuple[BucketLayout, CommPlan]:
    base = C.get(compression.compressor)
    if not (isinstance(base, C.StochasticQuant) and base.norm == "linf"):
        raise ServeError(
            f"weight quantization needs a linf StochasticQuant compressor "
            f"(int8-codes + scales payload); got "
            f"{compression.compressor!r}. l2/sign/topk compressors have no "
            f"weight-precision meaning here")
    layout = layout_for_params(
        params, bucket_bytes=int(compression.bucket_mb * 2**20))
    policy = compression.plan
    if policy == "none":
        policy = "uniform"
    plan = plan_comm(layout, compression.compressor, policy,
                     budget_bytes=int(compression.budget_mb * 2**20))
    return layout, plan


def _bucket_levels(plan: CommPlan, layout: BucketLayout) -> Tuple[int, ...]:
    """Per-bucket level count; 0 marks a raw (identity) bucket."""
    levels = []
    for b in layout.buckets:
        comp = C.get(plan.compressor_for(b.bid))
        if isinstance(comp, C.StochasticQuant) and comp.norm == "linf":
            levels.append(comp.levels)
        elif comp.name == "identity":
            levels.append(0)
        else:
            raise ServeError(
                f"weight plan assigned non-linf compressor {comp.name!r} "
                f"to bucket {b.bid}; only linf quant rungs and identity "
                f"are valid serving weight precisions")
    return tuple(levels)


def quantize_weights(params, compression, *, seed: int = 0,
                     interpret: bool = True):
    """One-shot load-time quantization.

    Returns (meta, payload): payload is a pytree of device arrays
    ({"b<bid>": {"codes", "scales"} | {"flat"}}) passed as the traced
    weights argument of the serving jits; meta is the static recipe
    `dequantize_weights` closes over.
    """
    leaves, treedef = jax.tree.flatten(params)
    layout, plan = _resolve_plan(params, compression)
    levels = _bucket_levels(plan, layout)
    bits = tuple(
        32 if lv == 0 else C.get(plan.compressor_for(b.bid)).bits
        for lv, b in zip(levels, layout.buckets))
    meta = WeightQuantMeta(
        layout=layout, plan=plan, treedef=treedef,
        leaf_structs=tuple(jax.ShapeDtypeStruct(l.shape, l.dtype)
                           for l in leaves),
        levels=levels, bits=bits)

    key = jax.random.key(seed)

    @jax.jit
    def encode(leaves):
        from repro.comm.buckets import pack
        flats = pack(layout, leaves)                     # f32, padded
        payload = {}
        for b in layout.buckets:
            flat = flats[b.bid]
            if levels[b.bid] == 0:
                payload[f"b{b.bid}"] = {"flat": flat}
                continue
            rand = jax.random.uniform(jax.random.fold_in(key, b.bid),
                                      flat.shape)
            codes, scales, _ = quantize_ef_flat(
                flat, jnp.zeros_like(flat), rand,
                levels=levels[b.bid], interpret=interpret)
            payload[f"b{b.bid}"] = {"codes": codes, "scales": scales}
        return payload

    return meta, encode(leaves)


def dequantize_weights(meta: WeightQuantMeta, payload):
    """Rebuild the parameter pytree from a payload (runs under jit — the
    dequant-on-read half of the contract)."""
    flats = []
    for b in meta.layout.buckets:
        entry = payload[f"b{b.bid}"]
        if meta.levels[b.bid] == 0:
            flats.append(entry["flat"])
            continue
        codes, scales = entry["codes"], entry["scales"]
        rows, cols, _ = bucket_tile_shape(b.size)
        deq = codes.astype(jnp.float32).reshape(rows, cols) * (
            scales[:, None] / meta.levels[b.bid])
        flats.append(deq.reshape(b.size))
    leaves = unpack_into(meta.layout, flats, list(meta.leaf_structs))
    return jax.tree.unflatten(meta.treedef, leaves)
