"""Gradient-exchange strategies: the parameter-server averaging of Algorithm 2
mapped onto TPU collectives (DESIGN.md §2).

All functions here run INSIDE a `jax.shard_map` that is manual over the
DQGAN worker axes (the paper's M machines) and auto over the tensor-model
axis. `p` is the per-worker message (η·g + e in the paper), and the return
value is (q̂, new_ef_state) where q̂ = (1/M) Σ_m Q(p^m) — exactly the
server-side average.

Strategies
----------
exact      : q̂ = pmean(p). No compression (CPOAdam baseline).
sim        : q̂ = pmean(Q(p)). Bit-exact paper semantics; float on the wire.
allgather  : int8 codes + scales all-gathered, dequantized, averaged.
             PS-uplink-faithful wire format; receive cost grows with M.
two_phase  : compressed "reduce-scatter + all-gather": quantize → all-to-all
             (int8) → chunk owner dequantizes + averages → re-quantize with
             owner-side EF → all-gather (int8). O(d·bits/8) per worker in
             BOTH phases — the TPU-native scalable scheme (beyond paper).

two_phase needs an axis of the tensor that is (a) divisible by the worker
count and (b) not sharded over a mesh axis (so the reshape is local). We
pick it statically from the tensor shape + PartitionSpec; tensors with no
such axis fall back to `sim` (recorded by `plan_for_tree`).

Bucketed fast path (repro.comm, DESIGN.md §3): when DQConfig.comm_plan is
a planner policy, core.dqgan packs unsharded leaves into flat buckets
whose padded length is always divisible by the worker count, and calls
`exchange_leaf` with `plan_bucket` plans (chunk axis 0) — one collective
per bucket instead of one per tensor, and no two_phase→sim fallbacks.
Wire cost per strategy is accounted by comm.ledger.CommLedger.

Split-phase contract (DESIGN.md §13): every strategy is expressed as
``start_exchange(...) -> ExchangeHandle`` followed by
``finish_exchange(handle) -> (q̂, new_ef_state)``. The *start* phase emits
everything up to and including the wire collectives (compress, EF update,
pmean / all-gather / all-to-all); the *finish* phase emits only local
post-processing (decompress, mean, reshape). Starting round-*s*'s handle
before the round-*s* field compute and finishing it at consumption time
is what lets XLA's latency-hiding scheduler overlap wire time with
generator/discriminator compute for `Schedule.delayed(τ)`. The blocking
`exchange_leaf` is a deprecation shim equal to start+immediate-finish,
so every_step/local_k graphs are bit-identical to the pre-split API.

The typed front-end for choosing among these is
`repro.strategy.ExchangePlan` (DESIGN.md §9): `ExchangePlan.leaf_plans`
→ `plan_for_tree`, `ExchangePlan.bucket_plan` → `plan_bucket`,
`ExchangePlan.start/finish` → `start_exchange`/`finish_exchange`, with
the kind validated against `STRATEGIES` at construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compressors as C
from .error_feedback import compress_with_ef

STRATEGIES = ("exact", "sim", "allgather", "two_phase")


# --------------------------------------------------------------------------- #
# static planning
# --------------------------------------------------------------------------- #
def pick_chunk_axis(shape, spec: Optional[P], n_workers: int) -> Optional[int]:
    """Largest axis divisible by n_workers whose PartitionSpec entry is None."""
    best = None
    for ax, size in enumerate(shape):
        sharded = spec is not None and ax < len(spec) and spec[ax] is not None
        if sharded or size % n_workers:
            continue
        if best is None or size > shape[best]:
            best = ax
    return best


def plan_leaf(strategy: str, shape, spec, n_workers: int) -> dict:
    """Resolve the effective strategy + chunk axis for one tensor."""
    if strategy == "two_phase":
        ax = pick_chunk_axis(shape, spec, n_workers)
        if ax is None:
            return {"strategy": "sim", "chunk_axis": None, "fallback": True}
        return {"strategy": "two_phase", "chunk_axis": ax, "fallback": False}
    return {"strategy": strategy, "chunk_axis": None, "fallback": False}


def plan_bucket(strategy: str, size: int, n_workers: int) -> dict:
    """Plan for a flat comm bucket. Bucket sizes are padded to a multiple
    of n_workers (buckets.build_layout), so two_phase always chunks on
    axis 0 and never falls back."""
    if strategy == "two_phase":
        assert size % max(n_workers, 1) == 0, (size, n_workers)
        return {"strategy": "two_phase", "chunk_axis": 0, "fallback": False}
    return {"strategy": strategy, "chunk_axis": None, "fallback": False}


def plan_has_owner_ef(plan: dict) -> bool:
    """True when `plan` carries owner-side (e2) error feedback — today
    only two_phase. The one place that knowledge lives: callers
    (core.dqgan, strategy.ExchangePlan.owner_ef) ask this instead of
    string-matching on the strategy name."""
    return plan["strategy"] == "two_phase"


def plan_for_tree(strategy, shapes_tree, specs_tree, n_workers):
    return jax.tree.map(
        lambda sh, sp: plan_leaf(strategy, sh, sp, n_workers),
        shapes_tree,
        specs_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x),
    )


# --------------------------------------------------------------------------- #
# EF state
# --------------------------------------------------------------------------- #
def ef_state_zeros(plan: dict, shape, dtype, n_workers: int, use_ef: bool):
    """Per-leaf EF state. e1 = worker-side error (full shape); e2 = chunk-owner
    error for two_phase (1/W of the tensor, sharded over workers naturally)."""
    state = {}
    if use_ef:
        state["e1"] = jnp.zeros(shape, dtype)
    if plan["strategy"] == "two_phase":
        ax = plan["chunk_axis"]
        chunk_shape = list(shape)
        chunk_shape[ax] //= n_workers
        state["e2"] = jnp.zeros(tuple(chunk_shape), dtype)
    return state


# --------------------------------------------------------------------------- #
# collectives (with a legacy-jax emulation path)
# --------------------------------------------------------------------------- #
_HAS_MODERN_SHARD_MAP = hasattr(jax, "shard_map")


def _mean_axes(x, axes):
    return jax.lax.pmean(x, axes)


def _all_gather(x, axes, W, widx):
    """all_gather over the worker axes. On old jax (experimental shard_map
    with partial-auto), the real all-gather trips an XLA partitioner CHECK;
    when a worker index is provided we emulate it as psum(onehot ⊗ x) —
    W× the traffic, correctness-only (the CI/CPU regime)."""
    if _HAS_MODERN_SHARD_MAP or widx is None:
        return jax.lax.all_gather(x, axes)
    onehot = (jnp.arange(W) == widx).astype(x.dtype)
    return jax.lax.psum(onehot.reshape((W,) + (1,) * x.ndim) * x[None], axes)


def _all_to_all(c, axes, W, widx):
    """all_to_all with leading source-worker dim (split/concat axis 0).
    Legacy emulation: gather everyone's chunks, keep own column."""
    if _HAS_MODERN_SHARD_MAP or widx is None:
        return jax.lax.all_to_all(c, axes, split_axis=0, concat_axis=0,
                                  tiled=False)
    gathered = _all_gather(c, axes, W, widx)  # (src, chunk, ...)
    return jax.lax.dynamic_index_in_dim(gathered, widx, axis=1,
                                        keepdims=False)


# --------------------------------------------------------------------------- #
# split-phase API
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ExchangeHandle:
    """In-flight exchange for one tensor (DESIGN.md §13).

    Produced by `start_exchange` after the wire collectives have been
    *issued* into the trace; `finish_exchange` emits the local
    post-processing and returns (q̂, new_ef_state). The handle is a
    trace-time object (it closes over traced arrays), valid only within
    the jitted step that created it — it is NOT a pytree and must not
    cross a `jit` boundary or be stored in carried state. For
    `delayed(τ)` the pending ring keeps carrying the *message* arrays;
    the handle's lifetime is one trace: started before the round's field
    compute, finished when the τ-stale result is consumed.
    """
    strategy: str
    _finish: Callable[[], Tuple[Any, dict]]

    def finish(self):
        return self._finish()


def _resolved(strategy, q, new_state) -> ExchangeHandle:
    return ExchangeHandle(strategy, lambda: (q, new_state))


def start_exchange(
    compressor: C.Compressor,
    plan: dict,
    p,
    ef_state: dict,
    key,
    axes: Tuple[str, ...],
    n_workers: int,
    use_ef: bool,
    widx=None,
) -> ExchangeHandle:
    """Issue the wire collectives for one tensor; return a handle whose
    `finish_exchange` yields (q̂, new_ef_state). Runs under
    shard_map(axes). ``widx`` (this worker's index over `axes`) enables
    the legacy-jax collective emulation; optional on modern jax.

    Split points per strategy (start | finish):
      exact     : pmean(p)                              | identity
      sim       : compress+EF, pmean(p̂)                 | identity
      allgather : compress+EF, all_gather(codes)        | decompress+mean
      two_phase : phase 1+2 through all_gather(codes2)  | decompress+unchunk
    EF-state updates are start-side (they depend only on local compress
    results), so staleness semantics are unchanged by the split.
    """
    strategy = plan["strategy"]
    new_state = dict(ef_state)

    if strategy == "exact":
        return _resolved(strategy, _mean_axes(p, axes), new_state)

    if strategy == "sim":
        e1 = ef_state.get("e1", jnp.zeros_like(p))
        payload, p_hat, e_new = compress_with_ef(compressor, p, e1, key, use_ef=use_ef)
        del payload
        if use_ef:
            new_state["e1"] = e_new
        return _resolved(strategy, _mean_axes(p_hat, axes), new_state)

    if strategy == "allgather":
        e1 = ef_state.get("e1", jnp.zeros_like(p))
        payload, p_hat, e_new = compress_with_ef(compressor, p, e1, key, use_ef=use_ef)
        if use_ef:
            new_state["e1"] = e_new
        gathered = jax.tree.map(
            lambda x: _all_gather(x, axes, n_workers, widx), payload)

        def _finish_allgather():
            deq = jax.vmap(
                lambda pl: compressor.decompress(pl, p.shape, jnp.float32)
            )(gathered)
            return jnp.mean(deq, axis=0).astype(p.dtype), new_state

        return ExchangeHandle(strategy, _finish_allgather)

    if strategy == "two_phase":
        return _start_two_phase(compressor, plan, p, ef_state, new_state, key,
                                axes, n_workers, use_ef, widx)

    raise ValueError(f"unknown strategy {strategy!r}")


def finish_exchange(handle: ExchangeHandle):
    """Emit the local post-processing of a started exchange and return
    (q̂, new_ef_state)."""
    return handle.finish()


def exchange_leaf(
    compressor: C.Compressor,
    plan: dict,
    p,
    ef_state: dict,
    key,
    axes: Tuple[str, ...],
    n_workers: int,
    use_ef: bool,
    widx=None,
):
    """Blocking shim: start + immediate finish (deprecated spelling).

    Kept so external callers of the pre-split API keep working and so
    the overlap=False lowering is bit-identical to the historical graphs
    (same per-leaf op emission order). New code should go through
    `ExchangePlan.start`/`ExchangePlan.finish` (repro.strategy) or the
    module-level `start_exchange`/`finish_exchange` pair.
    """
    return finish_exchange(start_exchange(
        compressor, plan, p, ef_state, key, axes, n_workers, use_ef,
        widx=widx))


def _start_two_phase(compressor, plan, p, ef_state, new_state, key, axes, W,
                     use_ef, widx=None) -> ExchangeHandle:
    ax = plan["chunk_axis"]
    orig_shape = p.shape
    # ---- phase 1: worker-side compress + all-to-all ------------------------ #
    e1 = ef_state.get("e1", jnp.zeros_like(p))
    m = p + e1.astype(p.dtype) if use_ef else p
    # split the chunk axis: (..., ax, ...) -> (W, ..., ax/W, ...)
    x = jnp.moveaxis(m, ax, 0).reshape((W, orig_shape[ax] // W) + _rest(orig_shape, ax))
    keys = jax.random.split(key, W + 1)
    payload = jax.vmap(compressor.compress)(x, keys[:W])
    x_hat = jax.vmap(lambda pl: compressor.decompress(pl, x.shape[1:], x.dtype))(payload)
    if use_ef:
        e_new = (x - x_hat).reshape((orig_shape[ax],) + _rest(orig_shape, ax))
        new_state["e1"] = jnp.moveaxis(e_new, 0, ax).astype(e1.dtype)
    # all-to-all: leading dim becomes the source-worker index, int8 on the wire
    moved = jax.tree.map(lambda c: _all_to_all(c, axes, W, widx), payload)
    contrib = jax.vmap(
        lambda pl: compressor.decompress(pl, x.shape[1:], jnp.float32)
    )(moved)
    chunk_mean = jnp.mean(contrib, axis=0)  # this worker's chunk of q̂
    # ---- phase 2: owner-side compress (+ owner EF) + all-gather ------------ #
    e2 = ef_state["e2"].reshape(chunk_mean.shape)
    payload2, chunk_hat, e2_new = compress_with_ef(
        compressor, chunk_mean, e2, keys[W], use_ef=True
    )
    del chunk_hat
    new_state["e2"] = e2_new.reshape(ef_state["e2"].shape).astype(ef_state["e2"].dtype)
    gathered = jax.tree.map(lambda c: _all_gather(c, axes, W, widx), payload2)

    def _finish_two_phase():
        chunks = jax.vmap(
            lambda pl: compressor.decompress(pl, chunk_mean.shape, jnp.float32)
        )(gathered)
        q = jnp.moveaxis(
            chunks.reshape((orig_shape[ax],) + _rest(orig_shape, ax)), 0, ax
        )
        return q.astype(p.dtype), new_state

    return ExchangeHandle("two_phase", _finish_two_phase)


def _rest(shape, ax):
    return tuple(s for i, s in enumerate(shape) if i != ax)


# --------------------------------------------------------------------------- #
# fsdp split-phase primitives (DESIGN.md §15)
# --------------------------------------------------------------------------- #
def _psum_scatter(x, axes, W, widx):
    """True reduce-scatter over the worker axes: (d,) -> (d/W,), worker w
    receiving sum_m x_m[w·d/W:(w+1)·d/W]. Legacy-jax emulation: full psum
    + slice at the worker's own chunk (W× the traffic, correctness-only —
    the same CI/CPU regime as `_all_gather`'s emulation)."""
    if _HAS_MODERN_SHARD_MAP or widx is None:
        return jax.lax.psum_scatter(x, axes, scatter_dimension=0,
                                    tiled=True)
    s = jax.lax.psum(x, axes)
    chunk = x.shape[0] // W
    return jax.lax.dynamic_slice_in_dim(s, widx * chunk, chunk)


def start_reduce_scatter(
    compressor: C.Compressor,
    kind: str,
    p,
    ef_state: dict,
    key,
    axes: Tuple[str, ...],
    n_workers: int,
    use_ef: bool,
    widx=None,
) -> ExchangeHandle:
    """The fsdp gradient leg: (compressed) reduce-scatter of one flat,
    worker-divisible bucket (DESIGN.md §15.2). ``p`` is (d,) with
    d % W == 0; the handle finishes to (q_shard, new_ef_state), q_shard
    being this worker's (d/W,) chunk of the mean message.

    Split points (start | finish):
      exact     : psum_scatter(p)/W                        | identity
      two_phase : compress+EF per chunk, all_to_all(int8)  | dequant+mean

    The compressed form is exactly phase 1 of `two_phase` — worker-side
    e1 error feedback, int8 on the wire — without phase 2's owner
    requantization: the shard owner consumes q_shard directly (optimizer
    update), and what returns to the replicas is the separately
    compressed moments leg (`start_all_gather_shard`)."""
    W = max(n_workers, 1)
    new_state = dict(ef_state)
    if W <= 1 or not axes:
        # single-worker degenerate: the shard IS the bucket; keep the
        # compressor roundtrip so W=1 matches the W>1 math per worker
        if kind == "exact":
            return _resolved(kind, p, new_state)
        e1 = ef_state.get("e1", jnp.zeros_like(p))
        payload, p_hat, e_new = compress_with_ef(
            compressor, p, e1, key, use_ef=use_ef)
        del payload
        if use_ef:
            new_state["e1"] = e_new.astype(e1.dtype)
        return _resolved(kind, p_hat.astype(p.dtype), new_state)
    if kind == "exact":
        q = _psum_scatter(p, axes, W, widx) / W
        return _resolved(kind, q.astype(p.dtype), new_state)
    if kind != "two_phase":
        raise ValueError(
            f"fsdp reduce-scatter: kind must be 'exact' or 'two_phase', "
            f"got {kind!r}")
    chunk = p.shape[0] // W
    e1 = ef_state.get("e1", jnp.zeros_like(p))
    m = p + e1.astype(p.dtype) if use_ef else p
    x = m.reshape(W, chunk)
    keys = jax.random.split(key, W)
    payload = jax.vmap(compressor.compress)(x, keys)
    if use_ef:
        x_hat = jax.vmap(
            lambda pl: compressor.decompress(pl, (chunk,), x.dtype)
        )(payload)
        new_state["e1"] = (x - x_hat).reshape(-1).astype(e1.dtype)
    # int8 codes on the wire; leading dim becomes the source-worker index
    moved = jax.tree.map(lambda c: _all_to_all(c, axes, W, widx), payload)

    def _finish_rs():
        contrib = jax.vmap(
            lambda pl: compressor.decompress(pl, (chunk,), jnp.float32)
        )(moved)
        return jnp.mean(contrib, axis=0).astype(p.dtype), new_state

    return ExchangeHandle(kind, _finish_rs)


def start_all_gather_shard(
    compressor: C.Compressor,
    shard,
    ag_ef,
    key,
    axes: Tuple[str, ...],
    n_workers: int,
    use_ef: bool,
    widx=None,
) -> ExchangeHandle:
    """The fsdp return leg: (compressed) all-gather of one owner shard —
    the quantized optimizer-state/parameter exchange of arXiv 2004.14180
    (DESIGN.md §15.3). The owner quantizes (shard + residual) and keeps
    e_new = (shard + e) − Q(shard + e); every worker decompresses the same
    W payloads, so the gathered flat bucket is identical on all replicas.
    Finishes to (full (W·chunk,) flat bucket, new owner residual)."""
    W = max(n_workers, 1)
    payload, c_hat, e_new = compress_with_ef(
        compressor, shard, ag_ef, key, use_ef=use_ef)
    new_ef = e_new if use_ef else ag_ef
    if W <= 1 or not axes:
        def _finish_local():
            return c_hat.astype(shard.dtype), new_ef
        return ExchangeHandle("allgather_shard", _finish_local)
    del c_hat
    gathered = jax.tree.map(lambda c: _all_gather(c, axes, W, widx),
                            payload)

    def _finish_ag():
        chunks = jax.vmap(
            lambda pl: compressor.decompress(pl, shard.shape, jnp.float32)
        )(gathered)
        return chunks.reshape(-1).astype(shard.dtype), new_ef

    return ExchangeHandle("allgather_shard", _finish_ag)


# --------------------------------------------------------------------------- #
# modeled wire bytes (for the speedup benchmark + roofline cross-check)
# --------------------------------------------------------------------------- #
def transport_factor(n_workers: int) -> float:
    """Ring-transport multiplier 2·(W−1)/W: per-worker wire bytes of a
    ring all-reduce (reduce-scatter + all-gather) relative to payload
    size. The single spelling shared by `modeled_wire_bytes`, the
    strategy component (`ExchangePlan.transport_factor`), and the
    compiled-HLO byte gap (`obs.hlo.byte_gap`)."""
    return 2 * (n_workers - 1) / max(n_workers, 1)


def modeled_wire_bytes(strategy, compressor, shape, n_workers):
    """Per-worker bytes moved for one tensor, by strategy (send+receive)."""
    d = math.prod(shape)
    full = 4 * d
    cb = compressor.wire_bytes(shape, n_workers)
    if strategy == "exact" or strategy == "sim":
        # ring all-reduce: 2·(W-1)/W · d · 4  ≈ 8d
        return transport_factor(n_workers) * full
    if strategy == "allgather":
        return cb + (n_workers - 1) * cb  # send own + receive all others
    if strategy == "two_phase":
        return transport_factor(n_workers) * cb  # A2A + AG, compressed
    raise ValueError(strategy)


def modeled_fsdp_wire_bytes(kind, compressor, moment_compressor, shape,
                            n_workers):
    """Per-worker bytes of one fsdp round for one bucket: the gradient
    reduce-scatter ((W−1)/W · payload sent) plus the moments/param
    all-gather ((W−1)/W · payload). With kind='exact' and identity
    moments this equals `modeled_wire_bytes('exact', ...)` — fsdp's
    RS+AG *is* the ring all-reduce, split around the optimizer."""
    d = math.prod(shape)
    W = max(n_workers, 1)
    f = (W - 1) / W
    rs = 4 * d if kind == "exact" else compressor.wire_bytes(shape, W)
    ag = moment_compressor.wire_bytes(shape, W)
    return f * (rs + ag)
