"""Error feedback (paper Algorithm 2, lines 6–8; Lemma 1).

The EF contract: the worker sends Q(m) where m = message + e_prev, and keeps
e_new = m - Q(m). Lemma 1 guarantees E||e||² ≤ 8η²(1-δ)(G²+σ²/B)/δ² so the
residual never accumulates unboundedly (validated in tests/test_error_feedback.py).

These helpers are per-leaf; `core.exchange` composes them with the
collective strategies, and `core.dqgan` lifts them over parameter pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import compressors as C


def ef_zeros_like(v, dtype=None):
    return jnp.zeros(v.shape, dtype or v.dtype)


FUSED_BLOCK = 1024  # kernel row width; must match kernels.quantize tiling


def fused_compatible(compressor, message) -> bool:
    """True when the Pallas fused EF+quantize kernel realizes exactly this
    compressor on this operand: linf quantization with one scale per
    FUSED_BLOCK elements, over a flat lane-aligned array (comm buckets are
    always shaped like this by construction). The level count is plumbed
    into the kernel, so the 8/4/2-bit block-1024 rungs of an adaptive
    PlanFamily — and `TracedQuant`, whose levels are a traced gather from
    the family's stacked table — all take the same fused path."""
    shaped = (getattr(message, "ndim", 0) == 1
              and message.shape[0] % FUSED_BLOCK == 0)
    if isinstance(compressor, C.TracedQuant):
        return compressor.per_block == FUSED_BLOCK and shaped
    return (isinstance(compressor, C.StochasticQuant)
            and compressor.norm == "linf"
            and compressor.per_block == FUSED_BLOCK
            and shaped)


def compress_with_ef(
    compressor: C.Compressor,
    message,
    e_prev,
    key,
    *,
    use_ef: bool = True,
    allow_fused: bool = True,
):
    """Compress (message + e_prev); return (payload, local dequant, e_new).

    With use_ef=False this is the CPOAdam-GQ baseline: the compression error
    is simply dropped (and, for biased compressors, convergence degrades —
    exactly the failure mode the paper's EF repairs).

    When the compressor/operand pair matches the fused Pallas kernel
    (fused_compatible — e.g. ``qsgd8_block1024`` over a comm bucket), the
    EF add, scale, stochastic round and residual write run in one
    VMEM-resident pass instead of ~4 jnp kernels. The payload format is
    identical; only the stochastic draws differ (same distribution).
    ``allow_fused=False`` opts out (e.g. under vmapped workers, where the
    interpret-mode pallas_call must not be batched).
    """
    if use_ef and allow_fused and fused_compatible(compressor, message):
        return fused_quantize_ef(message, e_prev, key,
                                 levels=compressor.levels)
    m = message + e_prev.astype(message.dtype) if use_ef else message
    payload = compressor.compress(m, key)
    m_hat = compressor.decompress(payload, m.shape, m.dtype)
    if use_ef:
        e_new = (m - m_hat).astype(e_prev.dtype)
    else:
        e_new = e_prev  # stays zero
    return payload, m_hat, e_new


def fused_quantize_ef(message_flat, e_prev, key, *, levels=127,
                      interpret: bool = True):
    """Single-HBM-pass EF + int8 quantization for a flat comm bucket via the
    Pallas kernel (kernels.quantize.quantize_ef_flat) — the fused equivalent
    of compress_with_ef(StochasticQuant(bits=8, norm="linf",
    per_block=FUSED_BLOCK), ...). Bucket sizes from comm.buckets are always
    lane-aligned, so no padding logic is needed here.

    Returns (payload {"codes","scale"}, m_hat, e_new) with the same contract
    as compress_with_ef; the payload is laid out exactly like the blocked
    StochasticQuant payload (codes (R, B) int8, scale (R, 1) f32), so
    ``StochasticQuant.decompress`` and the exchange collectives consume it
    unchanged.
    """
    from repro.kernels.quantize import quantize_ef_flat

    m32 = message_flat.astype(jnp.float32)
    rand = jax.random.uniform(key, m32.shape)
    codes, scales, e_new = quantize_ef_flat(
        m32, e_prev.astype(jnp.float32), rand,
        levels=levels, interpret=interpret)
    R = scales.shape[0]
    m_hat = (codes.reshape(R, -1).astype(jnp.float32)
             * (scales[:, None] / levels)).reshape(message_flat.shape)
    return ({"codes": codes.reshape(R, -1), "scale": scales.reshape(R, 1)},
            m_hat.astype(message_flat.dtype), e_new.astype(e_prev.dtype))


def lemma1_bound(eta, delta, G, sigma, B):
    """RHS of Lemma 1: 8η²(1-δ)(G² + σ²/B)/δ²."""
    return 8.0 * eta**2 * (1.0 - delta) * (G**2 + sigma**2 / B) / delta**2


def global_error_norm(e_tree):
    """||(1/M)Σ e^m||² proxy for a single worker's pytree: Σ_leaf ||e||²."""
    leaves = jax.tree.leaves(e_tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
