"""Error feedback (paper Algorithm 2, lines 6–8; Lemma 1).

The EF contract: the worker sends Q(m) where m = message + e_prev, and keeps
e_new = m - Q(m). Lemma 1 guarantees E||e||² ≤ 8η²(1-δ)(G²+σ²/B)/δ² so the
residual never accumulates unboundedly (validated in tests/test_error_feedback.py).

These helpers are per-leaf; `core.exchange` composes them with the
collective strategies, and `core.dqgan` lifts them over parameter pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import compressors as C


def ef_zeros_like(v, dtype=None):
    return jnp.zeros(v.shape, dtype or v.dtype)


def compress_with_ef(
    compressor: C.Compressor,
    message,
    e_prev,
    key,
    *,
    use_ef: bool = True,
):
    """Compress (message + e_prev); return (payload, local dequant, e_new).

    With use_ef=False this is the CPOAdam-GQ baseline: the compression error
    is simply dropped (and, for biased compressors, convergence degrades —
    exactly the failure mode the paper's EF repairs).
    """
    m = message + e_prev.astype(message.dtype) if use_ef else message
    payload = compressor.compress(m, key)
    m_hat = compressor.decompress(payload, m.shape, m.dtype)
    if use_ef:
        e_new = (m - m_hat).astype(e_prev.dtype)
    else:
        e_new = e_prev  # stays zero
    return payload, m_hat, e_new


def lemma1_bound(eta, delta, G, sigma, B):
    """RHS of Lemma 1: 8η²(1-δ)(G² + σ²/B)/δ²."""
    return 8.0 * eta**2 * (1.0 - delta) * (G**2 + sigma**2 / B) / delta**2


def global_error_norm(e_tree):
    """||(1/M)Σ e^m||² proxy for a single worker's pytree: Σ_leaf ||e||²."""
    leaves = jax.tree.leaves(e_tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
