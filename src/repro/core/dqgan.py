"""DQGAN (paper Algorithm 2) as a composable distributed train-step builder.

The builder turns any "field" function F (gradient oracle — for GANs the
concatenated field [∇θ L_G, ∇φ L_D], for plain minimization just grad(loss))
into a jit-compilable SPMD step:

    worker m:  w_{t-1/2}^m = w_{t-1} - [η F(w_{t-3/2}^m; ξ_{t-1}^m) + e_{t-1}^m]
               g_t^m       = F(w_{t-1/2}^m; ξ_t^m)
               p_t^m       = η g_t^m + e_{t-1}^m
               p̂_t^m      = Q(p_t^m);   e_t^m = p_t^m - p̂_t^m
    server:    q̂_t = (1/M) Σ_m p̂_t^m          (core.exchange strategies)
    workers:   w_t = w_{t-1} - q̂_t

SPMD mapping: one `jax.shard_map`, manual over DQConfig.worker_axes (the
paper's M machines), auto over everything else ('model' tensor parallelism,
and — when worker_axes == ('pod',) — FSDP over 'data' inside each pod).
Per-worker state (prev grad, EF residuals) is carried with a leading
worker axis sharded over the worker mesh axes.

Baselines from the paper fall out as configurations:
    CPOAdam      = optimizer='oadam', compressor='identity'
    CPOAdam-GQ   = optimizer='oadam', compressor=..., error_feedback=False
    DQGAN        = optimizer='omd',   compressor=..., error_feedback=True

`extrapolation='global'` replaces the paper's per-worker lookahead
η F(w^m_prev) + e^m with the previous *applied* update q̂_{t-1} (identical
across workers, hence FSDP-safe at 100B scale) — a deliberate beyond-paper
variant, see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DQConfig
from repro.strategy import Strategy
from repro import obs as OBS
from . import compressors as C
from . import exchange as X


class DQState(NamedTuple):
    """Full optimizer state. Per-worker leaves have a leading axis of size
    M (the worker count) sharded over the worker mesh axes; replicated
    leaves (params, moments) have no worker axis."""
    step: jax.Array
    params: Any
    prev_grad: Any       # per-worker F(w^m_{t-3/2}; ξ_{t-1}) (omd/local) | None
    prev_update: Any     # q̂_{t-1} (global extrapolation) or Adam prev dir | None
    ef: Any              # per-worker exchange EF state dicts | None
    m: Any               # Adam first moment | None
    v: Any               # Adam second moment | None
    # repro.sched per-worker buffers (DESIGN.md §5, §8) | None for every_step:
    #   {"accum": tree}   local_k — message accumulated since last round
    #   {"pending": tree, "versions": (W,) int32}   delayed(τ) —
    #       pending: the in-flight message(s) awaiting exchange. τ=1 keeps
    #       PR 2's single-slot layout (leaf (W, *shape)); τ>1 is a ring
    #       buffer (leaf (W, τ, *shape), index 0 = oldest = next on the
    #       wire). versions: per-worker step index of the last message
    #       this worker had applied at the server (the parameter-server
    #       push/pull version vector; staleness at step t = t − version).
    sched: Any = None
    # fsdp (exchange.parallelism='fsdp', DESIGN.md §15) per-bucket shard
    # state, {str(bid): {...}} with every leaf (W, bucket_size/W) f32
    # sharded over the worker axes — worker m's row is its owned flat
    # shard. Slots: "m"/"v" Adam moments (adam/oadam), "dir" previous
    # Adam direction (oadam), "w" the authoritative parameter shard
    # (zero_stage=3), "age" the owner-side all-gather EF residual
    # (arXiv 2004.14180). None outside fsdp mode; replaces the
    # replicated m/v/prev_update slots, which stay None.
    fsdp: Any = None


class StepOutput(NamedTuple):
    state: DQState
    metrics: Any


def _tree_zeros(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def _is_plan(x):
    return isinstance(x, dict) and "strategy" in x


def _is_shape(x):
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


@dataclasses.dataclass(frozen=True)
class DQGAN:
    """Builder. Construct once per (model, mesh, Strategy/DQConfig); then
    use `.init(params)` and `.step` (jit the latter).

    The blessed spelling passes a `repro.strategy.Strategy` (optimizer
    knobs via `dq=DQConfig.from_strategy(...)` when they matter); the
    legacy flat `dq=DQConfig(...)` flag bag keeps working through the
    shim. Either way `self.strategy` is the single validated dispatch
    surface both SPMD paths consume."""

    field_fn: Callable  # (params, batch, rng) -> (grad_tree, metrics_dict)
    dq: Optional[DQConfig] = None
    mesh: Any = None                      # jax.sharding.Mesh | None (single proc)
    param_specs: Any = None               # pytree of PartitionSpec (model axes only)
    batch_spec: Any = None                # PartitionSpec for batch leaves
    strategy: Optional[Strategy] = None   # distribution strategy (DESIGN.md §9)
    # (layout, plan) memo keyed by leaf shapes — _comm is hit several times
    # per trace (plans, EF init, exchange) and is pure host-side planning.
    _comm_cache: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    def __post_init__(self):
        if self.dq is None:
            dq = DQConfig.from_strategy(self.strategy or Strategy())
            object.__setattr__(self, "dq", dq)
        elif self.strategy is not None and self.strategy != self.dq.strategy:
            raise ValueError(
                "DQGAN: dq and strategy disagree:\n  "
                + "\n  ".join(self.dq.strategy.diff(self.strategy)))
        object.__setattr__(self, "strategy", self.dq.strategy)

    # ------------------------------------------------------------------ #
    @property
    def n_workers(self) -> int:
        if not self.strategy.exchange.worker_axes or self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a]
                         for a in self.strategy.exchange.worker_axes)

    @property
    def compressor(self) -> C.Compressor:
        return self.strategy.compression.get()

    @property
    def uses_adam(self) -> bool:
        return self.dq.optimizer in ("adam", "oadam")

    @property
    def bucketed(self) -> bool:
        """True when the repro.comm flat-bucket exchange path is active."""
        return self.strategy.compression.bucketing

    @property
    def fsdp(self) -> bool:
        """True when exchange.parallelism='fsdp': optimizer state shards
        across the workers, gradients ride a (compressed) reduce-scatter
        and updates/params a quantized all-gather (DESIGN.md §15)."""
        return self.strategy.exchange.fsdp

    @property
    def adaptive(self) -> bool:
        """True when a round-adaptive PlanFamily drives the bucket
        compressors (DESIGN.md §10)."""
        return self.strategy.compression.adaptive

    def _comm_full(self, tree):
        """(BucketLayout, CommPlan, PlanFamily | None) — static, derived
        from leaf shapes. For an adaptive strategy the CommPlan is the
        family's full-participation member, so every consumer of the
        static plan (EF init, ledger, skipped-leaf bookkeeping) sees the
        same layout whether the family is in play or not."""
        shapes = jax.tree.map(lambda x: tuple(x.shape), tree)
        cache_key = (jax.tree.structure(shapes, is_leaf=_is_shape),
                     tuple(jax.tree.leaves(shapes, is_leaf=_is_shape)))
        hit = self._comm_cache.get(cache_key)
        if hit is not None:
            return hit
        # mesh axis sizes let the layout see degenerate (size-1) mesh
        # axes as replication instead of sharding, so e.g. a model_n=1
        # mesh doesn't push 'model'-spec'd leaves off the bucket path
        axis_sizes = (dict(self.mesh.shape) if self.mesh is not None
                      else None)
        if self.adaptive:
            layout, family = self.strategy.compression.build_family(
                shapes, self.param_specs, self.n_workers)
            entry = (layout, family.full, family)
        else:
            layout, plan = self.strategy.compression.build(
                shapes, self.param_specs, self.n_workers,
                axis_sizes=axis_sizes)
            entry = (layout, plan, None)
        self._comm_cache[cache_key] = entry
        return entry

    def _comm(self, tree):
        """(BucketLayout, CommPlan) — the static (full-participation)
        view."""
        layout, plan, _ = self._comm_full(tree)
        return layout, plan

    def _family(self, tree):
        """The PlanFamily, or None for non-adaptive strategies."""
        return self._comm_full(tree)[2]

    # ------------------------------------------------------------------ #
    # repro.obs wiring (DESIGN.md §11) — all jit-static
    # ------------------------------------------------------------------ #
    @property
    def obs_spec(self):
        """The resolved `repro.obs.MetricSpec` for this trainer."""
        return self.strategy.observability.spec()

    @property
    def _obs_spans(self) -> bool:
        return self.strategy.observability.spans

    def _obs_bins(self) -> int:
        """Staleness-histogram bins: 0..τ plus one overflow bin (partial
        participation lets a sitting worker's staleness exceed τ)."""
        return self.strategy.schedule.staleness + 2

    def _obs_n_buckets(self, tree) -> int:
        return len(self._comm(tree)[0].buckets) if self.bucketed else 0

    def _obs_collector(self, tree):
        """A live `Collector` when metrics are on, else the no-op
        `NullCollector` (whose record calls leave the trace untouched —
        the metrics="off" bit-exactness contract)."""
        spec = self.obs_spec
        if not spec.on:
            return OBS.NullCollector()
        return OBS.Collector(spec, self._obs_n_buckets(tree))

    def comm_ledger(self, params) -> "Any":
        """CommLedger describing this trainer's per-step wire cost (used by
        launch.train logs and benchmarks.run)."""
        from repro.comm import CommLedger

        strat = self.strategy
        shapes = jax.tree.map(lambda x: tuple(x.shape), params)
        if self.bucketed:
            layout, cplan, family = self._comm_full(params)
            flat_plans = jax.tree.leaves(self._plans(params), is_leaf=_is_plan)
            leaf_plans = [flat_plans[s.index] for s in layout.skipped]
            budget = (int(strat.compression.budget_mb * (1 << 20))
                      if strat.compression.plan == "delta_budget" else 0)
            return CommLedger.from_plan(
                layout, cplan, strat.exchange.kind, self.n_workers,
                strat.compression.compressor, leaf_plans=leaf_plans,
                family=family, budget_bytes=budget,
                moment_compressor=(strat.moments.compressor
                                   if self.fsdp else None))
        return CommLedger.from_tree(
            strat.exchange.kind, strat.compression.compressor, shapes,
            self.param_specs, self.n_workers)

    def _plans(self, params):
        shapes = jax.tree.map(lambda x: tuple(x.shape), params)
        specs = self.param_specs
        if specs is None:
            specs = jax.tree.map(lambda x: P(), params)
        plans = self.strategy.exchange.leaf_plans(shapes, specs,
                                                  self.n_workers)
        if not self.bucketed:
            return plans
        # bucketed leaves leave the per-tensor machinery entirely; only the
        # skipped (sharded) leaves keep their per-tensor plan (which may
        # still legitimately fall back to sim).
        layout, _ = self._comm(params)
        in_bucket = {s.index for b in layout.buckets for s in b.slots}
        flat, treedef = jax.tree.flatten(plans, is_leaf=_is_plan)
        flat = [
            {"strategy": "bucketed", "chunk_axis": None, "fallback": False}
            if i in in_bucket else p
            for i, p in enumerate(flat)
        ]
        return jax.tree.unflatten(treedef, flat)

    def _scale_groups(self, tree):
        """Apply DQConfig.lr_mults by top-level pytree key (TTUR)."""
        if not self.dq.lr_mults:
            return tree
        mults = dict(self.dq.lr_mults)

        def one(path, leaf):
            key = getattr(path[0], "key", None) if path else None
            return leaf * mults.get(str(key), 1.0)

        return jax.tree_util.tree_map_with_path(one, tree)

    # ------------------------------------------------------------------ #
    # state construction
    # ------------------------------------------------------------------ #
    def init(self, params) -> DQState:
        """Concrete zero state (small-scale runs/tests)."""
        sched_c = self.strategy.schedule
        st = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            self.init_abstract(params),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )._replace(params=params, step=jnp.zeros((), jnp.int32))
        if sched_c.kind == "delayed":
            # nothing applied yet: version −τ makes the staleness metric
            # (step − version) read exactly τ from the first exchange on
            st = st._replace(sched={
                **st.sched,
                "versions": jnp.full((max(self.n_workers, 1),),
                                     -sched_c.tau, jnp.int32),
            })
        if self.fsdp and self.strategy.exchange.zero_stage == 3:
            # zero-3: the shard owner's parameter copy is authoritative —
            # seed it from the packed initial params so round 0's
            # all-gather reconstructs exactly w_0 under an exact
            # compressor (and EF-corrects otherwise).
            from repro.comm import buckets as B

            layout, _ = self._comm(params)
            flats = B.pack(layout, [l.astype(jnp.float32)
                                    for l in jax.tree.leaves(params)])
            W = max(self.n_workers, 1)
            fb = {k: dict(v) for k, v in st.fsdp.items()}
            for b in layout.buckets:
                fb[str(b.bid)]["w"] = flats[b.bid].reshape(W, b.size // W)
            st = st._replace(fsdp=fb)
        return st

    def _validate_lr_mults(self, params):
        """DQConfig.lr_mults names top-level param groups (TTUR); a typo'd
        group (e.g. "disc_" for "disc") was silently ignored — fail fast
        against the actual tree instead."""
        if not self.dq.lr_mults:
            return
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        groups = {str(p[0].key) for p, _ in flat
                  if p and hasattr(p[0], "key")}
        unknown = sorted(k for k, _ in self.dq.lr_mults if k not in groups)
        if unknown:
            raise ValueError(
                f"lr_mults group(s) {unknown} not found in the top-level "
                f"param groups {sorted(groups)}")

    def init_abstract(self, params) -> DQState:
        """ShapeDtypeStruct state with correct shardings (dry-run path).

        Strategy composition is validated at DQConfig/Strategy
        construction, so no flag checks remain here."""
        W = self.n_workers
        dq = self.dq
        strat = self.strategy
        self._validate_lr_mults(params)
        tv = strat.schedule.tau_vector
        if tv and len(tv) != max(W, 1):
            raise ValueError(
                f"schedule.tau_vector has {len(tv)} entries but this mesh "
                f"runs {max(W, 1)} workers — one τ_m per worker")
        plans = self._plans(params)
        ef_dtype = jnp.dtype(strat.compression.ef_dtype)

        def sds(shape, dtype, spec):
            sharding = (
                NamedSharding(self.mesh, spec) if self.mesh is not None else None
            )
            return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

        def pspec(x):
            # params' own sharding if it is an array/SDS with sharding
            sh = getattr(x, "sharding", None)
            if isinstance(sh, NamedSharding):
                return sh.spec
            return P()

        axes = strat.exchange.worker_axes

        def worker_spec(spec):
            return P(axes, *spec)

        def param_like(x):
            return sds(x.shape, x.dtype, pspec(x))

        def per_worker_like(x, dtype=None):
            return sds((W,) + tuple(x.shape), dtype or x.dtype,
                       worker_spec(pspec(x)))

        params_s = jax.tree.map(param_like, params)

        prev_grad = None
        if dq.optimizer == "omd" and dq.extrapolation == "local":
            prev_grad = jax.tree.map(per_worker_like, params)

        prev_update = None
        if ((dq.optimizer == "omd" and dq.extrapolation == "global")
                or dq.optimizer == "oadam") and not self.fsdp:
            # fsdp: oadam's previous direction shards into the per-bucket
            # "dir" slot; omd 'global' extrapolation is rejected below
            # (the applied-update tree never materializes at any worker).
            prev_update = jax.tree.map(param_like, params)

        def ef_leaf(x, plan):
            st = {}
            if dq.error_feedback:
                st["e1"] = sds((W,) + tuple(x.shape), ef_dtype,
                               worker_spec(pspec(x)))
            if X.plan_has_owner_ef(plan):
                ax = plan["chunk_axis"]
                cs = list(x.shape)
                cs[ax] //= W
                spec = pspec(x)
                st["e2"] = sds((W,) + tuple(cs), ef_dtype, worker_spec(spec))
            return st if st else None

        ef = jax.tree.map(ef_leaf, params, plans)
        if self.bucketed:
            # bucket-level state rides beside the per-leaf residuals: e1
            # stays per-tensor (the local-extrapolation lookahead needs leaf
            # views of it), phase-2 owner error is per-bucket.
            layout, _ = self._comm(params)
            bucket_ef = {}
            # fsdp has no phase-2 owner requantization — the return leg's
            # owner residual is the per-bucket "age" slot instead of e2.
            if strat.exchange.owner_ef and not self.fsdp:
                for b in layout.buckets:
                    bucket_ef[str(b.bid)] = {
                        "e2": sds((W, b.size // max(W, 1)), ef_dtype,
                                  worker_spec(P()))
                    }
            ef = {"leaf": ef, "bucket": bucket_ef}

        fsdp = None
        if self.fsdp:
            layout, _ = self._comm(params)
            if layout.skipped:
                skipped_ix = sorted(s.index for s in layout.skipped)
                raise ValueError(
                    "exchange.parallelism='fsdp' needs every leaf in a "
                    "flat bucket, but the comm planner skipped leaf "
                    f"index(es) {skipped_ix} (sharded over axes outside "
                    "the fsdp worker axes). Shard those leaves over the "
                    "fsdp axis (shard-aware bucketing, DESIGN.md §15.1), "
                    "unshard them, or use parallelism='replicated'.")
            if dq.lr_mults:
                raise ValueError(
                    "lr_mults groups params by top-level key, which is "
                    "undefined on fsdp's flat shard buckets — drop "
                    "lr_mults or use parallelism='replicated'")
            if dq.optimizer == "omd" and dq.extrapolation == "global":
                raise ValueError(
                    "extrapolation='global' needs the full applied-update "
                    "tree, which fsdp never materializes at a single "
                    "worker — use extrapolation='local' or "
                    "parallelism='replicated'")
            fsdp = {}
            for b in layout.buckets:
                c = b.size // max(W, 1)

                def shard_like():
                    return sds((W, c), jnp.float32, worker_spec(P()))

                ent = {"age": shard_like()}
                if self.uses_adam:
                    ent["m"] = shard_like()
                    ent["v"] = shard_like()
                if dq.optimizer == "oadam":
                    ent["dir"] = shard_like()
                if strat.exchange.zero_stage == 3:
                    ent["w"] = shard_like()
                fsdp[str(b.bid)] = ent

        m = v = None
        if self.uses_adam and not self.fsdp:
            m = jax.tree.map(param_like, params)
            v = jax.tree.map(param_like, params)

        # repro.sched buffers carry the (float32) exchange message, one per
        # worker, same sharding discipline as the EF residuals. The
        # schedule component owns WHICH slots exist (accum / pending ring /
        # versions); the closures own shape+sharding.
        sched = strat.schedule.init_slots(
            params,
            worker_like=lambda x: per_worker_like(x, jnp.float32),
            # (W, τ, *shape): τ in-flight messages per worker, oldest
            # first. τ=1 keeps PR 2's (W, *shape) single-slot layout
            # (and its compiled graph) bit-exactly.
            ring_like=lambda x: sds(
                (W, strat.schedule.tau) + tuple(x.shape), jnp.float32,
                P(axes, None, *pspec(x))),
            versions_like=lambda: sds((W,), jnp.int32, P(axes)),
        )

        return DQState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            params=params_s,
            prev_grad=prev_grad,
            prev_update=prev_update,
            ef=ef,
            m=m,
            v=v,
            sched=sched,
            fsdp=fsdp,
        )

    def state_specs(self, params) -> DQState:
        """PartitionSpec tree matching init_abstract (for jit in_shardings)."""
        abstract = self.init_abstract(params)

        def spec_of(x):
            sh = getattr(x, "sharding", None)
            if isinstance(sh, NamedSharding):
                return sh.spec
            return P()

        return jax.tree.map(spec_of, abstract,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    # ------------------------------------------------------------------ #
    # the step
    # ------------------------------------------------------------------ #
    def step(self, state: DQState, batch, key,
             do_exchange: bool = True) -> StepOutput:
        """One Algorithm-2 iteration. jit me (donate state for in-place).

        ``do_exchange`` is only consulted by the ``local_k`` schedule; it
        must be a static Python bool (jit it via ``static_argnums=(3,)``)
        — the host decides the cadence with
        ``sched.ExchangeSchedule.is_exchange_step(step)``. ``every_step``
        and ``delayed`` run their collective every call and ignore it.
        """
        dq = self.dq
        strat = self.strategy
        if strat.schedule.kind == "local_k":
            if not isinstance(do_exchange, bool):
                raise TypeError(
                    "schedule='local_k' needs a static Python bool "
                    "do_exchange (jit with static_argnums=(3,)); got "
                    f"{type(do_exchange).__name__}")
        else:
            do_exchange = True
        plans = self._plans(state.params)
        axes = tuple(strat.exchange.worker_axes)
        W = self.n_workers

        if not axes or self.mesh is None or W == 1:
            # single worker: per-worker leaves still carry their leading
            # worker axis (of size 1), so squeeze stays on.
            return self._worker_body(
                state, batch, key, None, plans, axes=(), squeeze=True,
                do_exchange=do_exchange,
            )

        if strat.exchange.spmd == "vmap":
            return self._step_vmap(state, batch, key, W,
                                   do_exchange=do_exchange)

        body = partial(self._worker_body, plans=plans, axes=axes,
                       squeeze=True, do_exchange=do_exchange)

        # ---- build shard_map specs (manual axes only) -------------------- #
        rep = P()
        wlead = P(axes)

        def st_spec(name):
            sub = getattr(state, name)
            if sub is None:
                return None
            lead = (wlead if name in ("prev_grad", "ef", "sched", "fsdp")
                    else rep)
            return jax.tree.map(lambda _: lead, sub)

        state_specs = DQState(
            step=rep,
            params=jax.tree.map(lambda _: rep, state.params),
            prev_grad=st_spec("prev_grad"),
            prev_update=st_spec("prev_update"),
            ef=st_spec("ef"),
            m=st_spec("m"),
            v=st_spec("v"),
            sched=st_spec("sched"),
            fsdp=st_spec("fsdp"),
        )
        bspec = self.batch_spec
        if bspec is None:
            bspec = P(axes)
        batch_specs = jax.tree.map(lambda _: bspec, batch)

        metric_specs = {"loss": rep, "grad_norm": rep, "error_norm": rep,
                        "staleness_max": rep, "staleness_mean": rep}
        obs_spec = self.obs_spec
        if obs_spec.on:
            # obs metrics ride out replicated; the key set is the static
            # `metric_keys` contract shared with metrics.finalize
            metric_specs["obs"] = {
                k: rep for k in OBS.metric_keys(
                    obs_spec, self._obs_n_buckets(state.params))}
        out_specs = StepOutput(state=state_specs, metrics=metric_specs)
        from repro.parallel.compat import key_across_boundary, shard_map

        key, converted = key_across_boundary(key)
        if converted:
            inner = body

            def body(state, batch, kd, widx_arr):
                return inner(state, batch, jax.random.wrap_key_data(kd),
                             widx_arr)

        # worker index as a sharded input: equivalent to lax.axis_index but
        # also usable on legacy jax, whose partial-auto shard_map cannot
        # lower PartitionId (see parallel.compat).
        widx_arr = jnp.arange(W, dtype=jnp.int32)
        fn = shard_map(
            body,
            mesh=self.mesh,
            in_specs=(state_specs, batch_specs, rep, wlead),
            out_specs=out_specs,
            axis_names=axes,
        )
        return fn(state, batch, key, widx_arr)

    # ------------------------------------------------------------------ #
    def _step_vmap(self, state, batch, key, W, do_exchange=True):
        """Workers as a vmapped leading axis (paper semantics of Algorithm 2,
        exchange = mean over the worker axis, compression via per-worker
        roundtrip — the 'sim' strategy). Pure auto-sharding: the worker axis
        is sharded over dq.worker_axes, everything inside (FSDP 'data',
        tensor 'model') is compiler-managed. Used for the 100B-scale FSDP
        layout where shard_map-over-pod hits an XLA partitioner CHECK.

        Schedule dataflow (repro.sched) mirrors `_worker_body`: local_k
        accumulates the message and only compresses at round ends; delayed
        compresses the previous step's message with the staleness
        correction folded into the OMD lookahead; partial participation
        masks messages/residuals and rescales the mean."""
        from .error_feedback import compress_with_ef

        dq = self.dq
        sched_c = self.strategy.schedule
        comp = self.compressor
        eta = dq.lr
        schedule = sched_c.kind

        batch_w = jax.tree.map(
            lambda x: x.reshape((W, x.shape[0] // W) + x.shape[1:]), batch
        )
        widx = jnp.arange(W)
        part_setup = self.strategy.participation.round_setup(
            key, state.step, W, sched_c.period)
        has_part = part_setup is not None
        mask_vec = part_setup[0] if has_part else jnp.ones((W,), jnp.float32)
        n_part = part_setup[1] if has_part else W
        exchanging = not (schedule == "local_k" and not do_exchange)
        obs_spec = self.obs_spec
        spans = self._obs_spans
        # vmap forbids bucketing (Strategy validation), so the collector
        # runs aggregate-only; its per-worker sums ride out of the vmap
        # stacked and are summed over axis 0 below.
        col = (OBS.Collector(obs_spec, 0) if obs_spec.on
               else OBS.NullCollector())

        def worker(prev_g, ef, sw, b, i, mask):
            kw = jax.random.fold_in(jax.random.fold_in(key, i), state.step)
            kf, kq = jax.random.split(kw)
            pending_buf, pending = sched_c.wire_head(sw, i)
            stale = sched_c.staleness_correction(pending_buf, dq.message,
                                                 eta, i)
            if dq.optimizer == "omd" and dq.extrapolation == "local":
                def extrap(w, g_prev, e, s):
                    upd = eta * g_prev
                    if e is not None:
                        upd = upd + e["e1"].astype(upd.dtype)
                    if s is not None:
                        upd = upd + s.astype(upd.dtype)
                    return w - upd.astype(w.dtype)
                leaves_p, tdp = jax.tree.flatten(state.params)
                gl = tdp.flatten_up_to(prev_g)
                el = (tdp.flatten_up_to(ef) if dq.error_feedback and ef
                      is not None else [None] * len(leaves_p))
                sl = (tdp.flatten_up_to(stale) if stale is not None
                      else [None] * len(leaves_p))
                w_half = jax.tree.unflatten(
                    tdp, [extrap(w, g, e, s)
                          for w, g, e, s in zip(leaves_p, gl, el, sl)])
            elif dq.optimizer == "omd":
                upd_tree = state.prev_update
                if stale is not None:
                    upd_tree = jax.tree.map(
                        lambda u, s: u + s.astype(u.dtype), upd_tree, stale)
                w_half = jax.tree.map(lambda w, u: w - u.astype(w.dtype),
                                      state.params, upd_tree)
            else:
                w_half = state.params
            with OBS.device_span("field", spans):
                grads, metrics = self.field_fn(w_half, b, kf)
            if dq.message == "update" and dq.optimizer == "omd":
                msg = jax.tree.map(lambda g: (eta * g).astype(jnp.float32),
                                   grads)
            else:
                msg = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

            # schedule dataflow — one component method shared with the
            # shard_map path (accumulate / ring-shift / version advance)
            exch, new_sw = sched_c.fold(sw, msg, pending, do_exchange,
                                        state.step, mask, _tree_zeros, i)

            phat = enew = None
            if exch is not None:
                leaves, treedef = jax.tree.flatten(exch)
                ef_leaves = (treedef.flatten_up_to(ef) if ef is not None
                             else [None] * len(leaves))
                phats, enews = [], []
                for j, (m, e) in enumerate(zip(leaves, ef_leaves)):
                    e1 = (e["e1"] if e
                          else jnp.zeros_like(m)).astype(jnp.float32)
                    m_in = m * mask if has_part else m
                    e_in = e1 * mask if has_part else e1
                    _, p_hat, e_new = compress_with_ef(
                        comp, m_in, e_in, jax.random.fold_in(kq, j),
                        use_ef=dq.error_feedback, allow_fused=False)  # vmapped
                    if col.enabled:
                        # the wire stream (masked under participation, as
                        # in the shard_map path) and the pre-merge
                        # residual: exactly m_in + e_in − Q(·)
                        col.leaf(m_in, m_in + e_in, e_new)
                    if has_part and dq.error_feedback:
                        e_new = mask * e_new + (1.0 - mask) * (e1 + m)
                    phats.append(p_hat)
                    enews.append({"e1": e_new.astype(jnp.dtype(dq.ef_dtype))}
                                 if dq.error_feedback else None)
                phat = jax.tree.unflatten(treedef, phats)
                enew = (jax.tree.unflatten(treedef, enews)
                        if dq.error_feedback else None)
            return (phat, enew, new_sw, grads,
                    metrics.get("loss", jnp.zeros(())), col.sums())

        prev_g = state.prev_grad
        ef = state.ef if dq.error_feedback else None
        phat_w, ef_w, sched_w, grads_w, loss_w, obs_sums_w = jax.vmap(
            worker,
            in_axes=(0, 0 if ef is not None else None, 0, 0, 0, 0),
        )(prev_g, ef, state.sched, batch_w, widx, mask_vec)

        new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
        new_ef = state.ef
        if exchanging:
            with OBS.device_span("exchange", spans):
                qhat = jax.tree.map(lambda x: jnp.mean(x, axis=0), phat_w)
                if has_part:
                    scale = W / n_part
                    qhat = jax.tree.map(
                        lambda q: (q * scale).astype(q.dtype), qhat)
            with OBS.device_span("apply", spans):
                new_params, new_m, new_v, new_prev_update = (
                    self._server_update(state, qhat))
            if dq.error_feedback and ef_w is not None:
                new_ef = jax.tree.map(
                    lambda o, n: n.astype(o.dtype), state.ef, ef_w)
        else:
            new_params = state.params

        new_prev_grad = state.prev_grad
        if state.prev_grad is not None:
            new_prev_grad = jax.tree.map(lambda o, g: g.astype(o.dtype),
                                         state.prev_grad, grads_w)
        new_sched = state.sched
        if sched_w is not None:
            new_sched = jax.tree.map(lambda o, n: n.astype(o.dtype),
                                     state.sched, sched_w)

        new_state = DQState(
            step=state.step + 1, params=new_params, prev_grad=new_prev_grad,
            prev_update=new_prev_update, ef=new_ef, m=new_m, v=new_v,
            sched=new_sched)
        gn = _global_norm(grads_w)
        en = _global_norm(new_ef) if new_ef is not None else jnp.zeros(())
        if schedule == "delayed":
            st_now = sched_c.staleness_now(state.step, new_sched)
            st_max, st_mean = jnp.max(st_now), jnp.mean(st_now)
        else:
            st_max = st_mean = jnp.zeros(())
        out_metrics = {"loss": jnp.mean(loss_w),
                       "grad_norm": gn, "error_norm": en,
                       "staleness_max": st_max,
                       "staleness_mean": st_mean}
        if obs_spec.on:
            # per-worker sums come out of the vmap stacked — the axis-0
            # sum is the fleet reduction (the shard_map path's psum)
            sums = jax.tree.map(lambda x: jnp.sum(x, axis=0), obs_sums_w)
            if obs_spec.ef_norms:
                sums["e1_sq"], sums["e2_sq"] = OBS.ef_norms_sq(new_ef)
            if obs_spec.staleness:
                st_vec = (sched_c.staleness_now(state.step, new_sched)
                          if schedule == "delayed"
                          else jnp.zeros((W,), jnp.float32))
                sums["staleness_hist"] = OBS.staleness_hist(
                    st_vec, self._obs_bins())
            out_metrics["obs"] = OBS.finalize(obs_spec, sums, col.counts(),
                                              W, 0)
        return StepOutput(state=new_state, metrics=out_metrics)

    # ------------------------------------------------------------------ #
    def _worker_body(self, state, batch, key, widx_arr, plans, axes, squeeze,
                     do_exchange=True):
        """Per-worker computation. When `squeeze`, per-worker leaves arrive
        with a leading axis of local size 1 (their worker shard).
        `widx_arr` is the (local size 1) slice of arange(W) sharded over
        the worker axes, or None outside shard_map."""
        dq = self.dq
        sched_c = self.strategy.schedule
        W = self.n_workers
        eta = dq.lr
        schedule = sched_c.kind

        def takew(tree):
            if tree is None or not squeeze:
                return tree
            return jax.tree.map(lambda x: x[0], tree)

        def putw(tree):
            if tree is None or not squeeze:
                return tree
            return jax.tree.map(lambda x: x[None], tree)

        # participation mask from the shared (pre-worker-fold) key so every
        # worker draws the same round permutation.
        part_setup = self.strategy.participation.round_setup(
            key, state.step, W, sched_c.period)

        widx = None
        if axes:
            widx = (widx_arr[0] if widx_arr is not None
                    else jax.lax.axis_index(axes))
            key = jax.random.fold_in(key, widx)
        kfield, kq = jax.random.split(jax.random.fold_in(key, state.step))

        params = state.params
        prev_grad = takew(state.prev_grad)
        ef = takew(state.ef)
        sched_st = takew(state.sched)
        fsdp_st = takew(state.fsdp)
        # pending_buf: the raw delayed-schedule buffer (ring for τ>1);
        # pending: the message on the wire THIS step (its oldest slot, or
        # this worker's τ_m pull slot under a heterogeneous tau_vector)
        pending_buf, pending = sched_c.wire_head(sched_st, widx)
        part = None
        plan_sel = None
        if part_setup is not None and widx is not None:
            part = (part_setup[0][widx], part_setup[1])
            if self.adaptive:
                # the round's participant count, as DATA: the PlanFamily
                # member is a gather on this index, so a different round
                # size is a different table row, never a retrace.
                from repro.sched.participation import round_count
                plan_sel = round_count(part_setup[0]) - 1

        # ---------- overlapped exchange start (delayed × overlap) --------- #
        # The delayed wire head is pure carried state (ring slot, EF
        # residuals, kq, participation mask) — none of it depends on this
        # round's field output — so with exchange.overlap the compress +
        # wire collectives are ISSUED here, before the field compute, and
        # only their local post-processing is emitted at consumption time
        # below. XLA's latency-hiding scheduler can then run the wire ops
        # concurrently with generator/discriminator work (DESIGN.md §13).
        # Identical per-op operands → numerically bit-exact with the
        # blocking (overlap=False) lowering.
        col = self._obs_collector(state.params)
        finish_xchg = None
        if (self.strategy.exchange.overlap and sched_c.overlappable
                and pending is not None):
            with OBS.device_span("exchange", self._obs_spans):
                if self.fsdp:
                    # fsdp overlap: only the gradient reduce-scatter is
                    # issued here — the optimizer + all-gather + unpack
                    # depend on the reduced shard and wait in the thunk.
                    finish_xchg = self._start_fsdp(
                        pending, ef, fsdp_st, params, state.step, kq,
                        axes, widx=widx, col=col)
                else:
                    finish_xchg = self._start_exchange_tree(
                        pending, ef, plans, kq, axes, widx=widx, part=part,
                        plan_sel=plan_sel, col=col, eager=False)

        # ---------- extrapolation to w_{t-1/2} ---------------------------- #
        # delayed schedule: w_{t-1} is τ applied updates stale, so the OMD
        # lookahead additionally subtracts the SUM of the worker's pending
        # (in-flight) messages as the staleness-correction proxy for the
        # τ outstanding q̂'s (DESIGN.md §8).
        stale = sched_c.staleness_correction(pending_buf, dq.message, eta,
                                             widx)
        ef_leaf_tree = ef["leaf"] if (self.bucketed and ef is not None) else ef
        if dq.optimizer == "omd":
            if dq.extrapolation == "local":
                e_term = ef_leaf_tree if dq.error_feedback else None

                def extrap(w, g_prev, e_leaf, s):
                    upd = eta * g_prev
                    if e_leaf is not None and "e1" in e_leaf:
                        upd = upd + e_leaf["e1"].astype(w.dtype)
                    if s is not None:
                        upd = upd + s.astype(w.dtype)
                    return w - upd.astype(w.dtype)

                leaves_p, tdp = jax.tree.flatten(params)
                gl = tdp.flatten_up_to(prev_grad)
                el = (tdp.flatten_up_to(e_term) if e_term is not None
                      else [None] * len(leaves_p))
                sl = (tdp.flatten_up_to(stale) if stale is not None
                      else [None] * len(leaves_p))
                w_half = jax.tree.unflatten(
                    tdp, [extrap(w, g, e, s)
                          for w, g, e, s in zip(leaves_p, gl, el, sl)])
            else:  # global: lookahead with the previously applied update
                upd_tree = state.prev_update
                if stale is not None:
                    upd_tree = jax.tree.map(lambda u, s: u + s.astype(u.dtype),
                                            upd_tree, stale)
                w_half = jax.tree.map(
                    lambda w, u: w - u.astype(w.dtype),
                    params, upd_tree,
                )
        else:
            w_half = params  # adam/oadam/sgd evaluate at current params

        # ---------- local stochastic field -------------------------------- #
        with OBS.device_span("field", self._obs_spans):
            grads, metrics = self.field_fn(w_half, batch, kfield)

        # ---------- message + schedule dataflow --------------------------- #
        if dq.message == "update" and dq.optimizer == "omd":
            message = jax.tree.map(lambda g: (eta * g).astype(jnp.float32), grads)
        else:
            message = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        # schedule dataflow — one component method shared with the vmap
        # path: accumulate (local_k), ring-shift + version advance
        # (delayed), or pass the fresh message through (every_step).
        exch_msg, new_sched = sched_c.fold(
            sched_st, message, pending, do_exchange, state.step,
            part[0] if part is not None else None, _tree_zeros, widx)

        # ---------- exchange + server-side update ------------------------- #
        new_fsdp = fsdp_st
        if exch_msg is not None and self.fsdp:
            # fsdp fuses exchange and apply: reduce-scatter → shard-owner
            # optimizer → all-gather, one pass per bucket (DESIGN.md §15)
            with OBS.device_span("exchange", self._obs_spans):
                fin = (finish_xchg if finish_xchg is not None
                       else self._start_fsdp(exch_msg, ef, fsdp_st, params,
                                             state.step, kq, axes,
                                             widx=widx, col=col))
            with OBS.device_span("apply", self._obs_spans):
                new_params, new_ef, new_fsdp = fin()
            new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
        elif exch_msg is not None:
            with OBS.device_span("exchange", self._obs_spans):
                if finish_xchg is not None:
                    # overlap: for delayed, fold returns the wire head the
                    # start above already put on the wire — consume it.
                    qhat, new_ef = finish_xchg()
                else:
                    qhat, new_ef = self._exchange_tree(
                        exch_msg, ef, plans, kq, axes, widx=widx, part=part,
                        plan_sel=plan_sel, col=col)
            with OBS.device_span("apply", self._obs_spans):
                new_params, new_m, new_v, new_prev_update = (
                    self._server_update(state, qhat))
        else:
            new_params = params
            new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
            new_ef = ef

        new_prev_grad = None
        if state.prev_grad is not None:
            new_prev_grad = jax.tree.map(
                lambda o, g: g.astype(o.dtype), prev_grad, grads
            )

        # ---------- metrics ------------------------------------------------ #
        gn = _global_norm(grads)
        en = _global_norm(new_ef) if new_ef is not None else jnp.zeros(())
        loss = metrics.get("loss", jnp.zeros(()))
        st_now = sched_c.staleness_now(state.step, new_sched)
        st_max = st_mean = st_now
        if axes:
            loss = jax.lax.pmean(loss, axes)
            gn = jax.lax.pmean(gn, axes)
            en = jax.lax.pmean(en, axes)
            st_max = jax.lax.pmax(st_now, axes)
            st_mean = jax.lax.pmean(st_now, axes)

        obs_spec = self.obs_spec
        obs_out = None
        if obs_spec.on:
            # fleet reduction: sums (not means) across workers, so the
            # δ̂ ratio and moment denominators weigh every worker's
            # elements once and masked participation rounds drop out
            sums = col.sums()
            if obs_spec.ef_norms:
                sums["e1_sq"], sums["e2_sq"] = OBS.ef_norms_sq(new_ef)
            if obs_spec.staleness:
                sums["staleness_hist"] = OBS.staleness_hist(
                    st_now, self._obs_bins())
            if axes:
                sums = jax.tree.map(lambda x: jax.lax.psum(x, axes), sums)
            obs_out = OBS.finalize(obs_spec, sums, col.counts(), W,
                                   col.n_buckets if col.enabled else 0)

        new_state = DQState(
            step=state.step + 1,
            params=new_params,
            prev_grad=putw(new_prev_grad),
            prev_update=new_prev_update,
            ef=putw(new_ef),
            m=new_m,
            v=new_v,
            sched=putw(new_sched),
            fsdp=putw(new_fsdp),
        )
        out_metrics = {"loss": loss, "grad_norm": gn, "error_norm": en,
                       "staleness_max": st_max, "staleness_mean": st_mean}
        if obs_out is not None:
            out_metrics["obs"] = obs_out
        return StepOutput(state=new_state, metrics=out_metrics)

    # ------------------------------------------------------------------ #
    # (the schedule/participation dataflow helpers live on the strategy
    # components — Schedule.wire_head/fold/staleness_correction and
    # Participation.round_setup — shared by both SPMD paths.)
    # ------------------------------------------------------------------ #
    def _server_update(self, state, qhat):
        """Apply the averaged message q̂ on (replicated) server state.
        Shared by the shard_map and vmap paths."""
        dq = self.dq
        eta = dq.lr
        params = state.params
        new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
        if dq.optimizer == "omd":
            if dq.message == "update":
                update = qhat
            else:
                update = jax.tree.map(lambda q: eta * q, qhat)
            new_params = jax.tree.map(
                lambda w, u: w - u.astype(w.dtype), params, update
            )
            if dq.extrapolation == "global":
                new_prev_update = update
        elif dq.optimizer in ("adam", "oadam"):
            # bias correction counts applied updates, not raw steps — with
            # local_k this runs only at round ends ((step+1) % K == 0).
            t = ((state.step + 1)
                 // self.strategy.schedule.period).astype(jnp.float32)
            b1, b2 = dq.beta1, dq.beta2
            new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, qhat)
            new_v = jax.tree.map(
                lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.v, qhat
            )
            bc1 = 1.0 - b1**t
            bc2 = 1.0 - b2**t
            direction = jax.tree.map(
                lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + dq.eps),
                new_m, new_v,
            )
            direction = self._scale_groups(direction)
            if dq.optimizer == "oadam":
                # optimistic Adam: w ← w − η (2 d_t − d_{t−1})
                new_params = jax.tree.map(
                    lambda w, d, dp: w
                    - (eta * (2.0 * d - dp)).astype(w.dtype),
                    params, direction, state.prev_update,
                )
                new_prev_update = direction
            else:
                new_params = jax.tree.map(
                    lambda w, d: w - (eta * d).astype(w.dtype), params, direction
                )
        elif dq.optimizer == "sgd":
            new_params = jax.tree.map(
                lambda w, q: w - (eta * q).astype(w.dtype), params, qhat
            )
        else:
            raise ValueError(dq.optimizer)
        return new_params, new_m, new_v, new_prev_update

    # ------------------------------------------------------------------ #
    def _exchange_tree(self, message, ef, plans, key, axes, widx=None,
                       part=None, plan_sel=None, col=None):
        """Blocking exchange: start + immediate finish. The eager start
        keeps per-leaf/per-bucket op emission order identical to the
        pre-split API, so every_step/local_k (and overlap=False delayed)
        compile to bit-identical graphs."""
        return self._start_exchange_tree(
            message, ef, plans, key, axes, widx=widx, part=part,
            plan_sel=plan_sel, col=col, eager=True)()

    def _start_exchange_tree(self, message, ef, plans, key, axes, widx=None,
                             part=None, plan_sel=None, col=None, eager=True):
        """Issue the exchange's compress + wire collectives and return a
        finish thunk yielding (q̂, new_ef) — the tree-level face of the
        split-phase contract (core.exchange.start/finish, DESIGN.md §13).

        ``eager=True``: each leaf/bucket is finished as soon as it is
        started (the blocking graphs). ``eager=False``: every start is
        emitted before the thunk is built, and all local post-processing
        (decompress, unpack, participation rescale + EF merge) waits in
        the thunk — the caller puts field compute between the two so the
        scheduler can hide the wire time. Observability records happen
        at finish time in lazy mode; collector records are pure
        observers, so the round's numbers are unchanged."""
        if col is None:
            col = OBS.NullCollector()
        if part is not None:
            return self._start_with_participation(
                message, ef, plans, key, axes, widx, part, plan_sel, col,
                eager)
        if self.bucketed:
            return self._start_bucketed(message, ef, plans, key, axes,
                                        widx=widx, plan_sel=plan_sel,
                                        col=col, eager=eager)
        dq = self.dq
        comp = self.compressor
        exch_c = self.strategy.exchange
        W = self.n_workers
        leaves, treedef = jax.tree.flatten(message)
        plan_leaves = treedef.flatten_up_to(plans)
        if ef is None:
            ef_leaves = [
                X.ef_state_zeros(pl, l.shape, jnp.dtype(dq.ef_dtype), W, False)
                for pl, l in zip(plan_leaves, leaves)
            ]
        else:
            ef_leaves = treedef.flatten_up_to(ef)
            ef_leaves = [e if e is not None else {} for e in ef_leaves]

        done, handles = [], []
        for i, (p, pl, e) in enumerate(zip(leaves, plan_leaves, ef_leaves)):
            k = jax.random.fold_in(key, i)
            if not axes:  # single worker: exchange degenerates to (EF-)compress
                q1, ne1 = self._single_worker_leaf(comp, pl, p, e, k)
                h = X.ExchangeHandle(pl["strategy"],
                                     lambda q=q1, ne=ne1: (q, ne))
            else:
                h = exch_c.start(comp, pl, p, e, k, W, dq.error_feedback,
                                 widx=widx)
            if eager:
                q, ne = exch_c.finish(h)
                if col.enabled:
                    col.leaf(p, *_obs_op_err(p, e, ne))
                done.append((q, ne))
            else:
                handles.append(h)

        def finish():
            pairs = done if eager else [exch_c.finish(h) for h in handles]
            out, new_ef = [], []
            for (q, ne), p, e in zip(pairs, leaves, ef_leaves):
                if not eager and col.enabled:
                    col.leaf(p, *_obs_op_err(p, e, ne))
                out.append(q)
                new_ef.append(ne if ne else None)
            qhat = jax.tree.unflatten(treedef, out)
            if ef is None and not dq.error_feedback and not exch_c.owner_ef:
                return qhat, None
            return qhat, jax.tree.unflatten(treedef, new_ef)

        return finish

    def _start_with_participation(self, message, ef, plans, key, axes,
                                  widx, part, plan_sel, col, eager):
        """Partial participation (sched.participation, DESIGN.md §5.3):
        this worker's message and worker-side residual are masked to zero
        at START when it sits the round out — every registry compressor
        maps 0 to a zero payload, so masked workers ride through the
        unmodified collectives contributing nothing. At FINISH the
        averaged q̂ is rescaled from 1/W to 1/n_participants (a static
        constant), and non-participants fold the would-have-been message
        into their EF residual instead. ``plan_sel`` (adaptive
        PlanFamily) rides through to the bucketed exchange, which
        re-spends the absent workers' byte budget on finer quantization
        for the reporting ones (DESIGN.md §10).
        """
        mask, n_part = part  # mask: this worker's 0/1 flag; n_part: static
        W = self.n_workers
        leaves, treedef = jax.tree.flatten(message)
        msg_in = jax.tree.unflatten(treedef, [l * mask for l in leaves])

        def mask_e1(tree):
            out = []
            for e in treedef.flatten_up_to(tree):
                if e and "e1" in e:
                    e = dict(e)
                    e["e1"] = e["e1"] * mask.astype(e["e1"].dtype)
                out.append(e)
            return jax.tree.unflatten(treedef, out)

        if ef is None:
            ef_in = None
        elif self.bucketed:
            ef_in = {"leaf": mask_e1(ef["leaf"]), "bucket": ef["bucket"]}
        else:
            ef_in = mask_e1(ef)

        inner = self._start_exchange_tree(msg_in, ef_in, plans, key, axes,
                                          widx=widx, plan_sel=plan_sel,
                                          col=col, eager=eager)

        def finish():
            qhat, new_ef = inner()
            scale = W / n_part
            qhat = jax.tree.map(lambda q: (q * scale).astype(q.dtype), qhat)

            if not self.dq.error_feedback or ef is None:
                return qhat, new_ef
            # EF merge: participants keep the exchange's residual, the
            # rest accumulate the unsent message on top of their old one.
            old_leaf = ef["leaf"] if self.bucketed else ef
            new_leaf = new_ef["leaf"] if self.bucketed else new_ef
            olds = treedef.flatten_up_to(old_leaf)
            news = [dict(n) if n else n
                    for n in treedef.flatten_up_to(new_leaf)]
            for m_leaf, o, n in zip(leaves, olds, news):
                if o and "e1" in o:
                    keep = o["e1"].astype(jnp.float32) + m_leaf
                    n["e1"] = (mask * n["e1"].astype(jnp.float32)
                               + (1.0 - mask) * keep).astype(o["e1"].dtype)
            merged = jax.tree.unflatten(treedef, news)
            if self.bucketed:
                return qhat, {"leaf": merged, "bucket": new_ef["bucket"]}
            return qhat, merged

        return finish

    def _single_worker_leaf(self, comp, plan, p, e, key):
        from .error_feedback import compress_with_ef

        if plan["strategy"] == "exact" or comp.name == "identity":
            return p, dict(e)
        e1 = e.get("e1", jnp.zeros_like(p))
        _, p_hat, e_new = compress_with_ef(
            comp, p, e1, key, use_ef=self.dq.error_feedback
        )
        ne = dict(e)
        if self.dq.error_feedback:
            ne["e1"] = e_new
        return p_hat, ne

    # ------------------------------------------------------------------ #
    # repro.comm flat-bucket fast path (DESIGN.md §3)
    # ------------------------------------------------------------------ #
    def _start_bucketed(self, message, ef, plans, key, axes, widx=None,
                        plan_sel=None, col=None, eager=True):
        """Exchange over bucket views: unsharded leaves are packed into a
        handful of flat, worker-divisible arrays (one collective each, per-
        bucket compressor from the comm planner); sharded leaves keep the
        per-tensor path. EF: e1 is packed/unpacked alongside the message so
        the per-leaf residual tree stays intact; two_phase owner error e2
        lives per-bucket under ef["bucket"].

        Split phase: start = pack + per-bucket compress + wire
        collectives (and the skipped leaves' starts, in lazy mode);
        finish = decompress, unpack_into, EF reassembly.

        ``plan_sel`` (traced, = round participant count − 1) selects the
        adaptive PlanFamily member: every family member shares one payload
        layout, so the per-bucket compressor becomes a `TracedQuant` whose
        level count is a gather from the family's jit-static stacked
        bit-width table — branch-free, and a different round size is new
        data, not a new trace. ``plan_sel=None`` (full participation, or
        a non-adaptive strategy) keeps the static per-bucket compressors,
        which is byte- and bit-identical to the pre-family behavior."""
        from repro.comm import buckets as B

        if col is None:
            col = OBS.NullCollector()
        dq = self.dq
        W = self.n_workers
        exch_c = self.strategy.exchange
        ef_dtype = jnp.dtype(dq.ef_dtype)
        layout, cplan = self._comm(message)
        family = self._family(message)
        levels_tab = None
        if (plan_sel is not None and family is not None
                and family.n_distinct > 1):
            # (M, n_buckets) level counts, stacked once at trace time
            levels_tab = jnp.asarray(family.levels_table(), jnp.float32)
            family_block = C.get(family.base_compressor).per_block
        leaves, treedef = jax.tree.flatten(message)
        plan_leaves = treedef.flatten_up_to(plans)

        leaf_ef = ef["leaf"] if ef is not None else None
        bucket_ef = ef["bucket"] if ef is not None else {}
        if leaf_ef is None:
            ef_leaves = [{}] * len(leaves)
        else:
            ef_leaves = [e if e is not None else {}
                         for e in treedef.flatten_up_to(leaf_ef)]

        # ---- buckets: start = compress + wire collectives ----------------- #
        flats = B.pack(layout, leaves)
        e1_flats = None
        if dq.error_feedback:
            e1_leaves = [
                e.get("e1", jnp.zeros(l.shape, ef_dtype))
                for l, e in zip(leaves, ef_leaves)
            ]
            e1_flats = B.pack(layout, e1_leaves)

        out_flats, new_e1_flats, new_bucket_ef = [], [], {}

        def finish_bucket(b, plan_b, est, h):
            q, ne = exch_c.finish(h)
            if col.enabled:
                col.bucket(b.bid, flats[b.bid],
                           *_obs_op_err(flats[b.bid], est, ne))
            out_flats.append(q)
            if dq.error_feedback:
                new_e1_flats.append(ne.get("e1", est.get("e1")))
            if X.plan_has_owner_ef(plan_b):
                new_bucket_ef[str(b.bid)] = {"e2": ne["e2"].astype(ef_dtype)}

        started = []
        for b, assign in zip(layout.buckets, cplan.assignments):
            if levels_tab is not None:
                comp_b = C.TracedQuant(levels_tab[plan_sel, b.bid],
                                       per_block=family_block)
            else:
                comp_b = C.get(assign.compressor)
            plan_b = exch_c.bucket_plan(b.size, W)
            est = {}
            if dq.error_feedback:
                est["e1"] = e1_flats[b.bid]
            if X.plan_has_owner_ef(plan_b):
                est["e2"] = (bucket_ef[str(b.bid)]["e2"]
                             if str(b.bid) in bucket_ef
                             else jnp.zeros((b.size // max(W, 1),), ef_dtype))
            k = jax.random.fold_in(key, 100_000 + b.bid)
            if not axes:
                q1, ne1 = self._single_worker_leaf(comp_b, plan_b,
                                                   flats[b.bid], est, k)
                h = X.ExchangeHandle(plan_b["strategy"],
                                     lambda q=q1, ne=ne1: (q, ne))
            else:
                h = exch_c.start(comp_b, plan_b, flats[b.bid], est, k, W,
                                 dq.error_feedback, widx=widx)
            if eager:
                finish_bucket(b, plan_b, est, h)
            else:
                started.append((b, plan_b, est, h))

        # ---- skipped (sharded) leaves keep the per-tensor path ------------ #
        base_comp = self.compressor

        def start_skipped(s):
            k = jax.random.fold_in(key, s.index)
            if not axes:
                q1, ne1 = self._single_worker_leaf(
                    base_comp, plan_leaves[s.index], leaves[s.index],
                    ef_leaves[s.index], k)
                return X.ExchangeHandle(plan_leaves[s.index]["strategy"],
                                        lambda q=q1, ne=ne1: (q, ne))
            return exch_c.start(
                base_comp, plan_leaves[s.index], leaves[s.index],
                ef_leaves[s.index], k, W, dq.error_feedback, widx=widx)

        skipped_started = []
        if not eager:
            skipped_started = [(s, start_skipped(s)) for s in layout.skipped]

        def finish():
            for item in started:  # lazy: buckets' local post-processing
                finish_bucket(*item)
            out_leaves = B.unpack_into(layout, out_flats, leaves)
            if dq.error_feedback:
                new_e1_leaves = B.unpack_into(layout, new_e1_flats,
                                              e1_leaves)
            skipped_new = {}
            # eager keeps the historical order: start+finish each skipped
            # leaf AFTER the bucket unpack, one leaf at a time
            pairs = (skipped_started if not eager
                     else ((s, start_skipped(s)) for s in layout.skipped))
            for s, h in pairs:
                q, ne = exch_c.finish(h)
                if col.enabled:
                    col.leaf(leaves[s.index],
                             *_obs_op_err(leaves[s.index],
                                          ef_leaves[s.index], ne))
                out_leaves[s.index] = q
                skipped_new[s.index] = ne if ne else None

            qhat = jax.tree.unflatten(treedef, out_leaves)
            if ef is None and not dq.error_feedback and not exch_c.owner_ef:
                return qhat, None

            in_bucket = {s.index for b in layout.buckets for s in b.slots}
            new_leaf_ef = []
            for i in range(len(leaves)):
                if i in skipped_new:
                    new_leaf_ef.append(skipped_new[i])
                elif i in in_bucket and dq.error_feedback:
                    new_leaf_ef.append({"e1": new_e1_leaves[i]})
                else:
                    new_leaf_ef.append(None)
            return qhat, {"leaf": jax.tree.unflatten(treedef, new_leaf_ef),
                          "bucket": new_bucket_ef}

        return finish

    # ------------------------------------------------------------------ #
    # compressed-gradient FSDP (DESIGN.md §15)
    # ------------------------------------------------------------------ #
    def _start_fsdp(self, message, ef, fb, params, step, key, axes,
                    widx=None, col=None):
        """One fsdp round over the flat buckets: pack → per-bucket
        (compressed) reduce-scatter of the gradient message (worker-side
        e1 EF, per-bucket compressor from the comm planner) → shard-owner
        optimizer update on its (size/W,) flat shard (`_shard_update`) →
        quantized all-gather of the update shard (zero-2) or the updated
        parameter shard (zero-3) under `strategy.moments`' compressor
        with the owner-side "age" residual → unpack into the parameter
        tree.

        Split phase: this call issues the reduce-scatter wire
        collectives; everything downstream of the optimizer (which needs
        the reduced shard) waits in the returned thunk, so under
        exchange.overlap only the gradient leg hides behind compute —
        the return leg is sequential by data dependency. Returns a thunk
        yielding (new_params, new_ef, new_fsdp_state)."""
        from repro.comm import buckets as B

        if col is None:
            col = OBS.NullCollector()
        dq = self.dq
        W = self.n_workers
        exch_c = self.strategy.exchange
        mom_c = self.strategy.moments
        mom_comp = mom_c.get()
        ef_dtype = jnp.dtype(dq.ef_dtype)
        layout, cplan = self._comm(message)
        leaves, treedef = jax.tree.flatten(message)
        param_leaves = treedef.flatten_up_to(params)

        leaf_ef = ef["leaf"] if ef is not None else None
        if leaf_ef is None:
            ef_leaves = [{}] * len(leaves)
        else:
            ef_leaves = [e if e is not None else {}
                         for e in treedef.flatten_up_to(leaf_ef)]

        flats = B.pack(layout, leaves)
        e1_flats = None
        e1_leaves = None
        if dq.error_feedback:
            e1_leaves = [e.get("e1", jnp.zeros(l.shape, ef_dtype))
                         for l, e in zip(leaves, ef_leaves)]
            e1_flats = B.pack(layout, e1_leaves)
        w_flats = B.pack(layout, [p.astype(jnp.float32)
                                  for p in param_leaves])

        started = []
        for b, assign in zip(layout.buckets, cplan.assignments):
            comp_b = C.get(assign.compressor)
            est = {}
            if dq.error_feedback:
                est["e1"] = e1_flats[b.bid]
            k = jax.random.fold_in(key, 100_000 + b.bid)
            h = exch_c.start_reduce_scatter(
                comp_b, flats[b.bid], est, k, W, dq.error_feedback,
                widx=widx)
            started.append((b, est, h, jax.random.fold_in(k, 1)))

        def finish():
            new_w_flats, new_e1_flats, new_fb = [], [], {}
            for b, est, h, kag in started:
                q_shard, ne = exch_c.finish(h)
                if col.enabled:
                    col.bucket(b.bid, flats[b.bid],
                               *_obs_op_err(flats[b.bid], est, ne))
                if dq.error_feedback:
                    new_e1_flats.append(ne.get("e1", est.get("e1")))
                fb_b = fb[str(b.bid)] if fb is not None else {}
                ent, ag_in = self._shard_update(q_shard.astype(jnp.float32),
                                                fb_b, step)
                age = fb_b.get("age")
                if age is None:
                    age = jnp.zeros_like(ag_in)
                h_ag = exch_c.start_all_gather_shard(
                    mom_comp, ag_in, age.astype(jnp.float32), kag, W,
                    mom_c.error_feedback, widx=widx)
                full, new_age = exch_c.finish(h_ag)
                ent["age"] = new_age.astype(jnp.float32)
                new_fb[str(b.bid)] = ent
                if exch_c.zero_stage == 3:
                    new_w_flats.append(full)
                else:
                    new_w_flats.append(w_flats[b.bid] - full)
            out_w = B.unpack_into(layout, new_w_flats, param_leaves)
            new_params = jax.tree.unflatten(treedef, out_w)

            in_bucket = {s.index for b in layout.buckets for s in b.slots}
            new_leaf_ef = []
            if dq.error_feedback:
                new_e1_leaves = B.unpack_into(layout, new_e1_flats,
                                              e1_leaves)
            for i in range(len(leaves)):
                if i in in_bucket and dq.error_feedback:
                    new_leaf_ef.append({"e1": new_e1_leaves[i]})
                else:
                    new_leaf_ef.append(None)
            new_ef = ef
            if ef is not None:
                new_ef = {"leaf": jax.tree.unflatten(treedef, new_leaf_ef),
                          "bucket": {}}
            return new_params, new_ef, new_fb

        return finish

    def _shard_update(self, q_shard, fb_b, step):
        """The optimizer update on this worker's owned flat shard — the
        same elementwise math as `_server_update`, applied by the shard
        owner on its (size/W,) chunk of the reduce-scattered mean
        message. Returns (new shard state dict, the all-gather operand:
        the update shard for zero-2, the updated parameter shard for
        zero-3). Bucket padding stays at zero under every optimizer
        (zero gradient, zero moments ⇒ zero update)."""
        dq = self.dq
        eta = dq.lr
        ent = {}
        if dq.optimizer == "omd":
            update = q_shard if dq.message == "update" else eta * q_shard
        elif dq.optimizer in ("adam", "oadam"):
            t = ((step + 1)
                 // self.strategy.schedule.period).astype(jnp.float32)
            b1, b2 = dq.beta1, dq.beta2
            m = b1 * fb_b["m"] + (1 - b1) * q_shard
            v = b2 * fb_b["v"] + (1 - b2) * jnp.square(q_shard)
            bc1 = 1.0 - b1 ** t
            bc2 = 1.0 - b2 ** t
            direction = (m / bc1) / (jnp.sqrt(v / bc2) + dq.eps)
            ent["m"], ent["v"] = m, v
            if dq.optimizer == "oadam":
                update = eta * (2.0 * direction - fb_b["dir"])
                ent["dir"] = direction
            else:
                update = eta * direction
        elif dq.optimizer == "sgd":
            update = eta * q_shard
        else:
            raise ValueError(dq.optimizer)
        if self.strategy.exchange.zero_stage == 3:
            w = fb_b["w"] - update
            ent["w"] = w
            return ent, w
        return ent, update


def _is_ef_leaf(x):
    return isinstance(x, dict) and ("e1" in x or "e2" in x)


def _never(x):
    return False


def _obs_op_err(p, e, ne):
    """(compression operand, fresh residual) for obs collection: the
    operand is message + e_prev (exactly what the compressor saw, f32),
    the residual the leaf's new e1. Streams that never compress
    (exact/identity) keep their zero residual, so they read δ̂ = 1."""
    e1 = e.get("e1") if e else None
    op = p if e1 is None else p + e1.astype(jnp.float32)
    err = ne.get("e1") if ne else None
    return op, (jnp.zeros_like(p) if err is None else err)


def _global_norm(tree):
    leaves = [
        l for l in jax.tree.leaves(tree) if hasattr(l, "dtype")
    ]
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
