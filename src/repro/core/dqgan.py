"""DQGAN (paper Algorithm 2) as a composable distributed train-step builder.

The builder turns any "field" function F (gradient oracle — for GANs the
concatenated field [∇θ L_G, ∇φ L_D], for plain minimization just grad(loss))
into a jit-compilable SPMD step:

    worker m:  w_{t-1/2}^m = w_{t-1} - [η F(w_{t-3/2}^m; ξ_{t-1}^m) + e_{t-1}^m]
               g_t^m       = F(w_{t-1/2}^m; ξ_t^m)
               p_t^m       = η g_t^m + e_{t-1}^m
               p̂_t^m      = Q(p_t^m);   e_t^m = p_t^m - p̂_t^m
    server:    q̂_t = (1/M) Σ_m p̂_t^m          (core.exchange strategies)
    workers:   w_t = w_{t-1} - q̂_t

SPMD mapping: one `jax.shard_map`, manual over DQConfig.worker_axes (the
paper's M machines), auto over everything else ('model' tensor parallelism,
and — when worker_axes == ('pod',) — FSDP over 'data' inside each pod).
Per-worker state (prev grad, EF residuals) is carried with a leading
worker axis sharded over the worker mesh axes.

Baselines from the paper fall out as configurations:
    CPOAdam      = optimizer='oadam', compressor='identity'
    CPOAdam-GQ   = optimizer='oadam', compressor=..., error_feedback=False
    DQGAN        = optimizer='omd',   compressor=..., error_feedback=True

`extrapolation='global'` replaces the paper's per-worker lookahead
η F(w^m_prev) + e^m with the previous *applied* update q̂_{t-1} (identical
across workers, hence FSDP-safe at 100B scale) — a deliberate beyond-paper
variant, see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DQConfig
from . import compressors as C
from . import exchange as X


class DQState(NamedTuple):
    """Full optimizer state. Per-worker leaves have a leading axis of size
    M (the worker count) sharded over the worker mesh axes; replicated
    leaves (params, moments) have no worker axis."""
    step: jax.Array
    params: Any
    prev_grad: Any       # per-worker F(w^m_{t-3/2}; ξ_{t-1}) (omd/local) | None
    prev_update: Any     # q̂_{t-1} (global extrapolation) or Adam prev dir | None
    ef: Any              # per-worker exchange EF state dicts | None
    m: Any               # Adam first moment | None
    v: Any               # Adam second moment | None


class StepOutput(NamedTuple):
    state: DQState
    metrics: Any


def _tree_zeros(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def _is_plan(x):
    return isinstance(x, dict) and "strategy" in x


def _is_shape(x):
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


@dataclasses.dataclass(frozen=True)
class DQGAN:
    """Builder. Construct once per (model, mesh, DQConfig); then use
    `.init(params)` and `.step` (jit the latter)."""

    field_fn: Callable  # (params, batch, rng) -> (grad_tree, metrics_dict)
    dq: DQConfig
    mesh: Any = None                      # jax.sharding.Mesh | None (single proc)
    param_specs: Any = None               # pytree of PartitionSpec (model axes only)
    batch_spec: Any = None                # PartitionSpec for batch leaves
    # (layout, plan) memo keyed by leaf shapes — _comm is hit several times
    # per trace (plans, EF init, exchange) and is pure host-side planning.
    _comm_cache: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    # ------------------------------------------------------------------ #
    @property
    def n_workers(self) -> int:
        if not self.dq.worker_axes or self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.dq.worker_axes)

    @property
    def compressor(self) -> C.Compressor:
        return C.get(self.dq.compressor)

    @property
    def uses_adam(self) -> bool:
        return self.dq.optimizer in ("adam", "oadam")

    @property
    def bucketed(self) -> bool:
        """True when the repro.comm flat-bucket exchange path is active.
        The vmap SPMD style keeps the paper's per-tensor semantics (its
        wire format is compiler-chosen anyway), so bucketing is a no-op
        there."""
        return self.dq.comm_plan != "none" and self.dq.spmd != "vmap"

    def _comm(self, tree):
        """(BucketLayout, CommPlan) — static, derived from leaf shapes."""
        from repro import comm as RC

        shapes = jax.tree.map(lambda x: tuple(x.shape), tree)
        cache_key = (jax.tree.structure(shapes, is_leaf=_is_shape),
                     tuple(jax.tree.leaves(shapes, is_leaf=_is_shape)))
        hit = self._comm_cache.get(cache_key)
        if hit is not None:
            return hit
        layout = RC.build_layout(
            shapes, self.param_specs, max(self.n_workers, 1),
            bucket_bytes=int(self.dq.bucket_mb * (1 << 20)))
        plan = RC.plan_comm(
            layout, self.dq.compressor, self.dq.comm_plan,
            budget_bytes=int(self.dq.comm_budget_mb * (1 << 20)))
        self._comm_cache[cache_key] = (layout, plan)
        return layout, plan

    def comm_ledger(self, params) -> "Any":
        """CommLedger describing this trainer's per-step wire cost (used by
        launch.train logs and benchmarks.run)."""
        from repro.comm import CommLedger

        shapes = jax.tree.map(lambda x: tuple(x.shape), params)
        if self.bucketed:
            layout, cplan = self._comm(params)
            flat_plans = jax.tree.leaves(self._plans(params), is_leaf=_is_plan)
            leaf_plans = [flat_plans[s.index] for s in layout.skipped]
            return CommLedger.from_plan(
                layout, cplan, self.dq.exchange, self.n_workers,
                self.dq.compressor, leaf_plans=leaf_plans)
        return CommLedger.from_tree(
            self.dq.exchange, self.dq.compressor, shapes,
            self.param_specs, self.n_workers)

    def _plans(self, params):
        shapes = jax.tree.map(lambda x: tuple(x.shape), params)
        specs = self.param_specs
        if specs is None:
            specs = jax.tree.map(lambda x: P(), params)
        plans = jax.tree.map(
            lambda sh, sp: X.plan_leaf(self.dq.exchange, sh, sp, self.n_workers),
            shapes, specs,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, int) for i in x),
        )
        if not self.bucketed:
            return plans
        # bucketed leaves leave the per-tensor machinery entirely; only the
        # skipped (sharded) leaves keep their per-tensor plan (which may
        # still legitimately fall back to sim).
        layout, _ = self._comm(params)
        in_bucket = {s.index for b in layout.buckets for s in b.slots}
        flat, treedef = jax.tree.flatten(plans, is_leaf=_is_plan)
        flat = [
            {"strategy": "bucketed", "chunk_axis": None, "fallback": False}
            if i in in_bucket else p
            for i, p in enumerate(flat)
        ]
        return jax.tree.unflatten(treedef, flat)

    def _scale_groups(self, tree):
        """Apply DQConfig.lr_mults by top-level pytree key (TTUR)."""
        if not self.dq.lr_mults:
            return tree
        mults = dict(self.dq.lr_mults)

        def one(path, leaf):
            key = getattr(path[0], "key", None) if path else None
            return leaf * mults.get(str(key), 1.0)

        return jax.tree_util.tree_map_with_path(one, tree)

    # ------------------------------------------------------------------ #
    # state construction
    # ------------------------------------------------------------------ #
    def init(self, params) -> DQState:
        """Concrete zero state (small-scale runs/tests)."""
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            self.init_abstract(params),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )._replace(params=params, step=jnp.zeros((), jnp.int32))

    def init_abstract(self, params) -> DQState:
        """ShapeDtypeStruct state with correct shardings (dry-run path)."""
        W = self.n_workers
        dq = self.dq
        plans = self._plans(params)
        ef_dtype = jnp.dtype(dq.ef_dtype)

        def sds(shape, dtype, spec):
            sharding = (
                NamedSharding(self.mesh, spec) if self.mesh is not None else None
            )
            return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

        def pspec(x):
            # params' own sharding if it is an array/SDS with sharding
            sh = getattr(x, "sharding", None)
            if isinstance(sh, NamedSharding):
                return sh.spec
            return P()

        def worker_spec(spec):
            return P(dq.worker_axes, *spec)

        def param_like(x):
            return sds(x.shape, x.dtype, pspec(x))

        def per_worker_like(x, dtype=None):
            return sds((W,) + tuple(x.shape), dtype or x.dtype,
                       worker_spec(pspec(x)))

        params_s = jax.tree.map(param_like, params)

        prev_grad = None
        if dq.optimizer == "omd" and dq.extrapolation == "local":
            prev_grad = jax.tree.map(per_worker_like, params)

        prev_update = None
        if (dq.optimizer == "omd" and dq.extrapolation == "global") or (
            dq.optimizer == "oadam"
        ):
            prev_update = jax.tree.map(param_like, params)

        def ef_leaf(x, plan):
            st = {}
            if dq.error_feedback:
                st["e1"] = sds((W,) + tuple(x.shape), ef_dtype,
                               worker_spec(pspec(x)))
            if plan["strategy"] == "two_phase":
                ax = plan["chunk_axis"]
                cs = list(x.shape)
                cs[ax] //= W
                spec = pspec(x)
                st["e2"] = sds((W,) + tuple(cs), ef_dtype, worker_spec(spec))
            return st if st else None

        ef = jax.tree.map(ef_leaf, params, plans)
        if self.bucketed:
            # bucket-level state rides beside the per-leaf residuals: e1
            # stays per-tensor (the local-extrapolation lookahead needs leaf
            # views of it), phase-2 owner error is per-bucket.
            layout, _ = self._comm(params)
            bucket_ef = {}
            if dq.exchange == "two_phase":
                for b in layout.buckets:
                    bucket_ef[str(b.bid)] = {
                        "e2": sds((W, b.size // max(W, 1)), ef_dtype,
                                  worker_spec(P()))
                    }
            ef = {"leaf": ef, "bucket": bucket_ef}

        m = v = None
        if self.uses_adam:
            m = jax.tree.map(param_like, params)
            v = jax.tree.map(param_like, params)

        return DQState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            params=params_s,
            prev_grad=prev_grad,
            prev_update=prev_update,
            ef=ef,
            m=m,
            v=v,
        )

    def state_specs(self, params) -> DQState:
        """PartitionSpec tree matching init_abstract (for jit in_shardings)."""
        abstract = self.init_abstract(params)

        def spec_of(x):
            sh = getattr(x, "sharding", None)
            if isinstance(sh, NamedSharding):
                return sh.spec
            return P()

        return jax.tree.map(spec_of, abstract,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    # ------------------------------------------------------------------ #
    # the step
    # ------------------------------------------------------------------ #
    def step(self, state: DQState, batch, key) -> StepOutput:
        """One Algorithm-2 iteration. jit me (donate state for in-place)."""
        dq = self.dq
        plans = self._plans(state.params)
        axes = tuple(dq.worker_axes)
        W = self.n_workers

        if not axes or self.mesh is None or W == 1:
            # single worker: per-worker leaves still carry their leading
            # worker axis (of size 1), so squeeze stays on.
            return self._worker_body(
                state, batch, key, None, plans, axes=(), squeeze=True
            )

        if dq.spmd == "vmap":
            return self._step_vmap(state, batch, key, W)

        body = partial(self._worker_body, plans=plans, axes=axes, squeeze=True)

        # ---- build shard_map specs (manual axes only) -------------------- #
        rep = P()
        wlead = P(axes)

        def st_spec(name):
            sub = getattr(state, name)
            if sub is None:
                return None
            lead = wlead if name in ("prev_grad", "ef") else rep
            return jax.tree.map(lambda _: lead, sub)

        state_specs = DQState(
            step=rep,
            params=jax.tree.map(lambda _: rep, state.params),
            prev_grad=st_spec("prev_grad"),
            prev_update=st_spec("prev_update"),
            ef=st_spec("ef"),
            m=st_spec("m"),
            v=st_spec("v"),
        )
        bspec = self.batch_spec
        if bspec is None:
            bspec = P(axes)
        batch_specs = jax.tree.map(lambda _: bspec, batch)

        out_specs = StepOutput(
            state=state_specs,
            metrics={"loss": rep, "grad_norm": rep, "error_norm": rep},
        )
        from repro.parallel.compat import key_across_boundary, shard_map

        key, converted = key_across_boundary(key)
        if converted:
            inner = body

            def body(state, batch, kd, widx_arr):
                return inner(state, batch, jax.random.wrap_key_data(kd),
                             widx_arr)

        # worker index as a sharded input: equivalent to lax.axis_index but
        # also usable on legacy jax, whose partial-auto shard_map cannot
        # lower PartitionId (see parallel.compat).
        widx_arr = jnp.arange(W, dtype=jnp.int32)
        fn = shard_map(
            body,
            mesh=self.mesh,
            in_specs=(state_specs, batch_specs, rep, wlead),
            out_specs=out_specs,
            axis_names=axes,
        )
        return fn(state, batch, key, widx_arr)

    # ------------------------------------------------------------------ #
    def _step_vmap(self, state, batch, key, W):
        """Workers as a vmapped leading axis (paper semantics of Algorithm 2,
        exchange = mean over the worker axis, compression via per-worker
        roundtrip — the 'sim' strategy). Pure auto-sharding: the worker axis
        is sharded over dq.worker_axes, everything inside (FSDP 'data',
        tensor 'model') is compiler-managed. Used for the 100B-scale FSDP
        layout where shard_map-over-pod hits an XLA partitioner CHECK."""
        from .error_feedback import compress_with_ef

        dq = self.dq
        comp = self.compressor
        eta = dq.lr

        batch_w = jax.tree.map(
            lambda x: x.reshape((W, x.shape[0] // W) + x.shape[1:]), batch
        )
        widx = jnp.arange(W)

        def worker(prev_g, ef, b, i):
            kw = jax.random.fold_in(jax.random.fold_in(key, i), state.step)
            kf, kq = jax.random.split(kw)
            if dq.optimizer == "omd" and dq.extrapolation == "local":
                def extrap(w, g_prev, e):
                    upd = eta * g_prev
                    if e is not None:
                        upd = upd + e["e1"].astype(upd.dtype)
                    return w - upd.astype(w.dtype)
                if dq.error_feedback:
                    w_half = jax.tree.map(extrap, state.params, prev_g, ef)
                else:
                    w_half = jax.tree.map(lambda w, g: extrap(w, g, None),
                                          state.params, prev_g)
            elif dq.optimizer == "omd":
                w_half = jax.tree.map(lambda w, u: w - u.astype(w.dtype),
                                      state.params, state.prev_update)
            else:
                w_half = state.params
            grads, metrics = self.field_fn(w_half, b, kf)
            if dq.message == "update" and dq.optimizer == "omd":
                msg = jax.tree.map(lambda g: (eta * g).astype(jnp.float32),
                                   grads)
            else:
                msg = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

            leaves, treedef = jax.tree.flatten(msg)
            ef_leaves = (treedef.flatten_up_to(ef) if ef is not None
                         else [None] * len(leaves))
            phats, enews = [], []
            for j, (m, e) in enumerate(zip(leaves, ef_leaves)):
                e1 = (e["e1"] if e else jnp.zeros_like(m)).astype(jnp.float32)
                _, p_hat, e_new = compress_with_ef(
                    comp, m, e1, jax.random.fold_in(kq, j),
                    use_ef=dq.error_feedback, allow_fused=False)  # vmapped
                phats.append(p_hat)
                enews.append({"e1": e_new.astype(jnp.dtype(dq.ef_dtype))}
                             if dq.error_feedback else None)
            phat = jax.tree.unflatten(treedef, phats)
            enew = (jax.tree.unflatten(treedef, enews)
                    if dq.error_feedback else None)
            return phat, enew, grads, metrics.get("loss", jnp.zeros(()))

        prev_g = state.prev_grad
        ef = state.ef if dq.error_feedback else None
        phat_w, ef_w, grads_w, loss_w = jax.vmap(
            worker, in_axes=(0, 0 if ef is not None else None, 0, 0)
        )(prev_g, ef, batch_w, widx)

        qhat = jax.tree.map(lambda x: jnp.mean(x, axis=0), phat_w)

        new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
        params = state.params
        if dq.optimizer == "omd":
            update = qhat if dq.message == "update" else jax.tree.map(
                lambda q: eta * q, qhat)
            new_params = jax.tree.map(lambda w, u: w - u.astype(w.dtype),
                                      params, update)
            if dq.extrapolation == "global":
                new_prev_update = update
        else:
            t = state.step.astype(jnp.float32) + 1.0
            b1, b2 = dq.beta1, dq.beta2
            new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                                 state.m, qhat)
            new_v = jax.tree.map(
                lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.v, qhat)
            direction = self._scale_groups(jax.tree.map(
                lambda m, v: (m / (1 - b1**t))
                / (jnp.sqrt(v / (1 - b2**t)) + dq.eps), new_m, new_v))
            if dq.optimizer == "oadam":
                new_params = jax.tree.map(
                    lambda w, d, dp: w - (eta * (2.0 * d - dp)).astype(w.dtype),
                    params, direction, state.prev_update)
                new_prev_update = direction
            else:
                new_params = jax.tree.map(
                    lambda w, d: w - (eta * d).astype(w.dtype),
                    params, direction)

        new_prev_grad = state.prev_grad
        if state.prev_grad is not None:
            new_prev_grad = jax.tree.map(lambda o, g: g.astype(o.dtype),
                                         state.prev_grad, grads_w)
        new_ef = state.ef
        if dq.error_feedback and ef_w is not None:
            new_ef = jax.tree.map(
                lambda o, n: n.astype(o.dtype), state.ef, ef_w)

        new_state = DQState(
            step=state.step + 1, params=new_params, prev_grad=new_prev_grad,
            prev_update=new_prev_update, ef=new_ef, m=new_m, v=new_v)
        gn = _global_norm(grads_w)
        en = _global_norm(new_ef) if new_ef is not None else jnp.zeros(())
        return StepOutput(state=new_state,
                          metrics={"loss": jnp.mean(loss_w),
                                   "grad_norm": gn, "error_norm": en})

    # ------------------------------------------------------------------ #
    def _worker_body(self, state, batch, key, widx_arr, plans, axes, squeeze):
        """Per-worker computation. When `squeeze`, per-worker leaves arrive
        with a leading axis of local size 1 (their worker shard).
        `widx_arr` is the (local size 1) slice of arange(W) sharded over
        the worker axes, or None outside shard_map."""
        dq = self.dq
        comp = self.compressor
        W = self.n_workers
        eta = dq.lr

        def takew(tree):
            if tree is None or not squeeze:
                return tree
            return jax.tree.map(lambda x: x[0], tree)

        def putw(tree):
            if tree is None or not squeeze:
                return tree
            return jax.tree.map(lambda x: x[None], tree)

        widx = None
        if axes:
            widx = (widx_arr[0] if widx_arr is not None
                    else jax.lax.axis_index(axes))
            key = jax.random.fold_in(key, widx)
        kfield, kq = jax.random.split(jax.random.fold_in(key, state.step))

        params = state.params
        prev_grad = takew(state.prev_grad)
        ef = takew(state.ef)

        # ---------- extrapolation to w_{t-1/2} ---------------------------- #
        ef_leaf_tree = ef["leaf"] if (self.bucketed and ef is not None) else ef
        if dq.optimizer == "omd":
            if dq.extrapolation == "local":
                e_term = ef_leaf_tree if dq.error_feedback else None

                def extrap(w, g_prev, e_leaf):
                    upd = eta * g_prev
                    if e_leaf is not None and "e1" in e_leaf:
                        upd = upd + e_leaf["e1"].astype(w.dtype)
                    return w - upd.astype(w.dtype)

                if e_term is not None:
                    w_half = jax.tree.map(
                        extrap, params, prev_grad, e_term,
                        is_leaf=lambda x: _is_ef_leaf(x),
                    )
                else:
                    w_half = jax.tree.map(
                        lambda w, g: w - (eta * g).astype(w.dtype),
                        params, prev_grad,
                    )
            else:  # global: lookahead with the previously applied update
                w_half = jax.tree.map(
                    lambda w, u: w - u.astype(w.dtype),
                    params, state.prev_update,
                )
        else:
            w_half = params  # adam/oadam/sgd evaluate at current params

        # ---------- local stochastic field -------------------------------- #
        grads, metrics = self.field_fn(w_half, batch, kfield)

        # ---------- message + exchange ------------------------------------ #
        if dq.message == "update" and dq.optimizer == "omd":
            message = jax.tree.map(lambda g: (eta * g).astype(jnp.float32), grads)
        else:
            message = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        qhat, new_ef = self._exchange_tree(message, ef, plans, kq, axes,
                                           widx=widx)

        # ---------- server-side update ------------------------------------ #
        new_m, new_v, new_prev_update = state.m, state.v, state.prev_update
        if dq.optimizer == "omd":
            if dq.message == "update":
                update = qhat
            else:
                update = jax.tree.map(lambda q: eta * q, qhat)
            new_params = jax.tree.map(
                lambda w, u: w - u.astype(w.dtype), params, update
            )
            if dq.extrapolation == "global":
                new_prev_update = update
        elif dq.optimizer in ("adam", "oadam"):
            t = state.step.astype(jnp.float32) + 1.0
            b1, b2 = dq.beta1, dq.beta2
            new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, qhat)
            new_v = jax.tree.map(
                lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.v, qhat
            )
            bc1 = 1.0 - b1**t
            bc2 = 1.0 - b2**t
            direction = jax.tree.map(
                lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + dq.eps),
                new_m, new_v,
            )
            direction = self._scale_groups(direction)
            if dq.optimizer == "oadam":
                # optimistic Adam: w ← w − η (2 d_t − d_{t−1})
                new_params = jax.tree.map(
                    lambda w, d, dp: w
                    - (eta * (2.0 * d - dp)).astype(w.dtype),
                    params, direction, state.prev_update,
                )
                new_prev_update = direction
            else:
                new_params = jax.tree.map(
                    lambda w, d: w - (eta * d).astype(w.dtype), params, direction
                )
        elif dq.optimizer == "sgd":
            new_params = jax.tree.map(
                lambda w, q: w - (eta * q).astype(w.dtype), params, qhat
            )
        else:
            raise ValueError(dq.optimizer)

        new_prev_grad = None
        if state.prev_grad is not None:
            new_prev_grad = jax.tree.map(
                lambda o, g: g.astype(o.dtype), prev_grad, grads
            )

        # ---------- metrics ------------------------------------------------ #
        gn = _global_norm(grads)
        en = _global_norm(new_ef) if new_ef is not None else jnp.zeros(())
        loss = metrics.get("loss", jnp.zeros(()))
        if axes:
            loss = jax.lax.pmean(loss, axes)
            gn = jax.lax.pmean(gn, axes)
            en = jax.lax.pmean(en, axes)

        new_state = DQState(
            step=state.step + 1,
            params=new_params,
            prev_grad=putw(new_prev_grad),
            prev_update=new_prev_update,
            ef=putw(new_ef),
            m=new_m,
            v=new_v,
        )
        return StepOutput(
            state=new_state,
            metrics={"loss": loss, "grad_norm": gn, "error_norm": en},
        )

    # ------------------------------------------------------------------ #
    def _exchange_tree(self, message, ef, plans, key, axes, widx=None):
        if self.bucketed:
            return self._exchange_bucketed(message, ef, plans, key, axes,
                                           widx=widx)
        dq = self.dq
        comp = self.compressor
        W = self.n_workers
        leaves, treedef = jax.tree.flatten(message)
        plan_leaves = treedef.flatten_up_to(plans)
        if ef is None:
            ef_leaves = [
                X.ef_state_zeros(pl, l.shape, jnp.dtype(dq.ef_dtype), W, False)
                for pl, l in zip(plan_leaves, leaves)
            ]
        else:
            ef_leaves = treedef.flatten_up_to(ef)
            ef_leaves = [e if e is not None else {} for e in ef_leaves]

        out, new_ef = [], []
        for i, (p, pl, e) in enumerate(zip(leaves, plan_leaves, ef_leaves)):
            k = jax.random.fold_in(key, i)
            if not axes:  # single worker: exchange degenerates to (EF-)compress
                q, ne = self._single_worker_leaf(comp, pl, p, e, k)
            else:
                q, ne = X.exchange_leaf(
                    comp, pl, p, e, k, axes, W, dq.error_feedback, widx=widx
                )
            out.append(q)
            new_ef.append(ne if ne else None)
        qhat = jax.tree.unflatten(treedef, out)
        if ef is None and not dq.error_feedback and dq.exchange != "two_phase":
            return qhat, None
        return qhat, jax.tree.unflatten(treedef, new_ef)

    def _single_worker_leaf(self, comp, plan, p, e, key):
        from .error_feedback import compress_with_ef

        if plan["strategy"] == "exact" or comp.name == "identity":
            return p, dict(e)
        e1 = e.get("e1", jnp.zeros_like(p))
        _, p_hat, e_new = compress_with_ef(
            comp, p, e1, key, use_ef=self.dq.error_feedback
        )
        ne = dict(e)
        if self.dq.error_feedback:
            ne["e1"] = e_new
        return p_hat, ne

    # ------------------------------------------------------------------ #
    # repro.comm flat-bucket fast path (DESIGN.md §3)
    # ------------------------------------------------------------------ #
    def _exchange_bucketed(self, message, ef, plans, key, axes, widx=None):
        """Exchange over bucket views: unsharded leaves are packed into a
        handful of flat, worker-divisible arrays (one collective each, per-
        bucket compressor from the comm planner); sharded leaves keep the
        per-tensor path. EF: e1 is packed/unpacked alongside the message so
        the per-leaf residual tree stays intact; two_phase owner error e2
        lives per-bucket under ef["bucket"]."""
        from repro.comm import buckets as B

        dq = self.dq
        W = self.n_workers
        ef_dtype = jnp.dtype(dq.ef_dtype)
        layout, cplan = self._comm(message)
        leaves, treedef = jax.tree.flatten(message)
        plan_leaves = treedef.flatten_up_to(plans)

        leaf_ef = ef["leaf"] if ef is not None else None
        bucket_ef = ef["bucket"] if ef is not None else {}
        if leaf_ef is None:
            ef_leaves = [{}] * len(leaves)
        else:
            ef_leaves = [e if e is not None else {}
                         for e in treedef.flatten_up_to(leaf_ef)]

        # ---- buckets ------------------------------------------------------ #
        flats = B.pack(layout, leaves)
        e1_flats = None
        if dq.error_feedback:
            e1_leaves = [
                e.get("e1", jnp.zeros(l.shape, ef_dtype))
                for l, e in zip(leaves, ef_leaves)
            ]
            e1_flats = B.pack(layout, e1_leaves)

        out_flats, new_e1_flats, new_bucket_ef = [], [], {}
        for b, assign in zip(layout.buckets, cplan.assignments):
            comp_b = C.get(assign.compressor)
            plan_b = X.plan_bucket(dq.exchange, b.size, max(W, 1))
            est = {}
            if dq.error_feedback:
                est["e1"] = e1_flats[b.bid]
            if plan_b["strategy"] == "two_phase":
                est["e2"] = (bucket_ef[str(b.bid)]["e2"]
                             if str(b.bid) in bucket_ef
                             else jnp.zeros((b.size // max(W, 1),), ef_dtype))
            k = jax.random.fold_in(key, 100_000 + b.bid)
            if not axes:
                q, ne = self._single_worker_leaf(comp_b, plan_b,
                                                 flats[b.bid], est, k)
            else:
                q, ne = X.exchange_leaf(comp_b, plan_b, flats[b.bid], est, k,
                                        axes, W, dq.error_feedback, widx=widx)
            out_flats.append(q)
            if dq.error_feedback:
                new_e1_flats.append(ne.get("e1", est.get("e1")))
            if plan_b["strategy"] == "two_phase":
                new_bucket_ef[str(b.bid)] = {"e2": ne["e2"].astype(ef_dtype)}

        out_leaves = B.unpack_into(layout, out_flats, leaves)
        if dq.error_feedback:
            new_e1_leaves = B.unpack_into(layout, new_e1_flats, e1_leaves)

        # ---- skipped (sharded) leaves: per-tensor path -------------------- #
        base_comp = self.compressor
        skipped_new = {}
        for s in layout.skipped:
            k = jax.random.fold_in(key, s.index)
            if not axes:
                q, ne = self._single_worker_leaf(
                    base_comp, plan_leaves[s.index], leaves[s.index],
                    ef_leaves[s.index], k)
            else:
                q, ne = X.exchange_leaf(
                    base_comp, plan_leaves[s.index], leaves[s.index],
                    ef_leaves[s.index], k, axes, W, dq.error_feedback,
                    widx=widx)
            out_leaves[s.index] = q
            skipped_new[s.index] = ne if ne else None

        qhat = jax.tree.unflatten(treedef, out_leaves)
        if ef is None and not dq.error_feedback and dq.exchange != "two_phase":
            return qhat, None

        in_bucket = {s.index for b in layout.buckets for s in b.slots}
        new_leaf_ef = []
        for i in range(len(leaves)):
            if i in skipped_new:
                new_leaf_ef.append(skipped_new[i])
            elif i in in_bucket and dq.error_feedback:
                new_leaf_ef.append({"e1": new_e1_leaves[i]})
            else:
                new_leaf_ef.append(None)
        return qhat, {"leaf": jax.tree.unflatten(treedef, new_leaf_ef),
                      "bucket": new_bucket_ef}


def _is_ef_leaf(x):
    return isinstance(x, dict) and ("e1" in x or "e2" in x)


def _never(x):
    return False


def _global_norm(tree):
    leaves = [
        l for l in jax.tree.leaves(tree) if hasattr(l, "dtype")
    ]
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
