"""δ-approximate gradient compressors (paper §2.4, §3.2, Theorems 1–2).

A compressor ``Q`` is δ-approximate for δ ∈ (0,1] if

    ||Q(v) - v||² ≤ (1 - δ) ||v||²      for all v            (Definition 1)

Every compressor here is a frozen, hashable dataclass (safe as a jit static
argument) with the interface:

    payload = c.compress(v, key)      # pytree of arrays (codes, scales, ...)
    v_hat   = c.decompress(payload, shape, dtype)
    c.wire_bytes(shape, n_workers)    # modeled PS-uplink bytes per worker
    c.delta(d)                        # analytic δ lower bound (or None)

``payload`` is designed so that its arrays can be moved by collectives
directly (int8 codes + small f32 scales) — that is what makes the
``allgather``/``two_phase`` exchange strategies in collectives.py produce
int8 wire traffic in the compiled HLO.

All stochastic compressors take an explicit PRNG key (JAX-functional);
deterministic ones ignore it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-20


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #
def _flat(v):
    return jnp.reshape(v, (-1,))


def _stochastic_round_codes(fb, s, levels, key):
    """Blocked stochastic rounding to signed integer levels — the one
    arithmetic shared by `StochasticQuant` (static levels) and
    `TracedQuant` (traced levels); keeping it in one place makes the
    PlanFamily bit-exactness contract (DESIGN.md §10.2) structural."""
    lv = jnp.abs(fb) / s * levels               # in [0, levels]
    low = jnp.floor(lv)
    p_up = lv - low
    up = jax.random.uniform(key, fb.shape) < p_up
    q = low + up.astype(lv.dtype)               # stochastic level
    return (jnp.sign(fb) * q).astype(jnp.int8)


def _dequantize_codes(payload, shape, dtype, levels):
    d = math.prod(shape)
    deq = payload["codes"].astype(jnp.float32) * (payload["scale"] / levels)
    return jnp.reshape(deq.reshape(-1)[:d], shape).astype(dtype)


@dataclass(frozen=True)
class Compressor:
    name: str = "identity"

    # -- interface ---------------------------------------------------------- #
    def compress(self, v, key):
        del key
        return {"values": v}

    def decompress(self, payload, shape, dtype):
        return payload["values"].astype(dtype)

    def wire_bytes(self, shape, n_workers: int = 1) -> int:
        del n_workers
        return 4 * math.prod(shape)

    def delta(self, d: int) -> Optional[float]:
        return 1.0

    @property
    def unbiased(self) -> bool:
        return True

    # -- convenience -------------------------------------------------------- #
    def roundtrip(self, v, key):
        return self.decompress(self.compress(v, key), v.shape, v.dtype)


@dataclass(frozen=True)
class TopK(Compressor):
    """Keep the k = ceil(frac*d) largest-magnitude entries (Thm 1: δ = k/d).

    Biased; REQUIRES error feedback for convergence (paper §3, [41]).
    Payload: int32 indices + f32/bf16 values (wire = 8 bytes per kept entry).
    """
    name: str = "topk"
    frac: float = 0.01

    def _k(self, d):
        return max(1, int(math.ceil(self.frac * d)))

    def compress(self, v, key):
        del key
        f = _flat(v)
        k = self._k(f.shape[0])
        vals, idx = jax.lax.top_k(jnp.abs(f), k)
        del vals
        return {"indices": idx.astype(jnp.int32), "values": jnp.take(f, idx)}

    def decompress(self, payload, shape, dtype):
        d = math.prod(shape)
        out = jnp.zeros((d,), dtype=payload["values"].dtype)
        out = out.at[payload["indices"]].set(payload["values"])
        return jnp.reshape(out, shape).astype(dtype)

    def wire_bytes(self, shape, n_workers: int = 1) -> int:
        return 8 * self._k(math.prod(shape))

    def delta(self, d):
        return self._k(d) / d

    @property
    def unbiased(self):
        return False


@dataclass(frozen=True)
class RandK(Compressor):
    """Keep k uniformly random coordinates (unscaled rand-k contraction:
    E||v - Q(v)||² = (1 - k/d)||v||², i.e. δ = k/d in expectation)."""
    name: str = "randk"
    frac: float = 0.01

    def _k(self, d):
        return max(1, int(math.ceil(self.frac * d)))

    def compress(self, v, key):
        f = _flat(v)
        d = f.shape[0]
        idx = jax.random.choice(key, d, (self._k(d),), replace=False)
        return {"indices": idx.astype(jnp.int32), "values": jnp.take(f, idx)}

    decompress = TopK.decompress

    def wire_bytes(self, shape, n_workers: int = 1) -> int:
        return 8 * self._k(math.prod(shape))

    def delta(self, d):
        return self._k(d) / d

    @property
    def unbiased(self):
        return False  # unbiased only with (d/k) rescaling; we use contraction form


@dataclass(frozen=True)
class SignMean(Compressor):
    """Q(v) = sign(v) * mean(|v|)  (1-bit + one scale; EF-signSGD [14]).

    δ = ||v||₁² / (d ||v||₂²) ∈ (0, 1], data-dependent (≥ 1/d)."""
    name: str = "sign"

    def compress(self, v, key):
        del key
        f = _flat(v)
        scale = jnp.mean(jnp.abs(f))
        bits = (f >= 0).astype(jnp.int8)  # one byte in payload; 1 bit on wire
        return {"codes": bits, "scale": scale.astype(jnp.float32)}

    def decompress(self, payload, shape, dtype):
        signs = payload["codes"].astype(jnp.float32) * 2.0 - 1.0
        return jnp.reshape(signs * payload["scale"], shape).astype(dtype)

    def wire_bytes(self, shape, n_workers: int = 1) -> int:
        return math.prod(shape) // 8 + 4

    def delta(self, d):
        return None  # data dependent

    @property
    def unbiased(self):
        return False


@dataclass(frozen=True)
class StochasticQuant(Compressor):
    """m-bit stochastic uniform quantization (QSGD [1] / Hou et al. [12]).

    Q(v_i) = s * sign(v_i) * q(v_i, s) with q rounding |v_i|/s stochastically
    to one of 2^{bits-1}-1 uniform levels; s = ||v||₂ or ||v||∞.
    Unbiased (Thm 2) and δ-approximate. Codes are signed integer levels in
    int8 (bits ≤ 8); wire bytes = d * bits / 8 + 4.

    ``per_block > 0`` quantizes in blocks of that many elements with one
    scale each (beyond-paper accuracy knob; tighter scales → larger δ).
    """
    name: str = "qsgd"
    bits: int = 8
    norm: str = "linf"  # "l2" | "linf"
    per_block: int = 0

    def __post_init__(self):
        assert 2 <= self.bits <= 8, "codes are carried as int8"
        assert self.norm in ("l2", "linf")

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1

    def _scale(self, f):
        if self.norm == "l2":
            return jnp.linalg.norm(f, axis=-1, keepdims=True)
        return jnp.max(jnp.abs(f), axis=-1, keepdims=True)

    def _blocked(self, f):
        d = f.shape[0]
        if self.per_block <= 0:
            return f[None, :], d
        b = self.per_block
        pad = (-d) % b
        f = jnp.pad(f, (0, pad))
        return f.reshape(-1, b), d

    def compress(self, v, key):
        f = _flat(v).astype(jnp.float32)
        fb, _ = self._blocked(f)
        s = self._scale(fb) + _EPS
        codes = _stochastic_round_codes(fb, s, self.levels, key)
        return {"codes": codes, "scale": s.astype(jnp.float32)}

    def decompress(self, payload, shape, dtype):
        return _dequantize_codes(payload, shape, dtype, self.levels)

    def wire_bytes(self, shape, n_workers: int = 1) -> int:
        d = math.prod(shape)
        n_scales = 1 if self.per_block <= 0 else -(-d // self.per_block)
        return d * self.bits // 8 + 4 * n_scales

    def delta(self, d):
        # linf: per-element error ≤ (s/levels)²/4 stochastically;
        # worst-case analytic bound is loose — report None (measured in tests).
        return None

    @property
    def unbiased(self):
        return True


class TracedQuant:
    """`StochasticQuant(norm="linf")` with a *traced* ``levels`` operand.

    The adaptive PlanFamily path (comm.planner, DESIGN.md §10) selects a
    per-bucket bit-width each round by gathering from a jit-static table
    indexed by the round's participant count. Every member of such a
    family shares one payload layout (int8 codes + f32 scales, shapes set
    by ``per_block`` alone), so the ONLY thing that varies is the level
    count — carried here as a traced scalar so the selection is data, not
    a retrace. Arithmetic mirrors StochasticQuant element-for-element:
    with a concrete ``levels`` the compiled graph computes the same
    values (XLA sees the same mul/div by a scalar either way).

    Not a registry citizen (not frozen/hashable — it closes over a
    tracer); constructed per-step inside the jitted exchange.
    """

    def __init__(self, levels, per_block: int = 0,
                 name: str = "adaptive_linf"):
        self.levels = levels          # traced scalar (or python int)
        self.per_block = per_block
        self.name = name
        self.norm = "linf"
        self.bits = None              # not statically known

    unbiased = True

    _blocked = StochasticQuant._blocked

    def compress(self, v, key):
        f = _flat(v).astype(jnp.float32)
        fb, _ = self._blocked(f)
        s = jnp.max(jnp.abs(fb), axis=-1, keepdims=True) + _EPS
        codes = _stochastic_round_codes(fb, s, self.levels, key)
        return {"codes": codes, "scale": s.astype(jnp.float32)}

    def decompress(self, payload, shape, dtype):
        return _dequantize_codes(payload, shape, dtype, self.levels)

    def roundtrip(self, v, key):
        return self.decompress(self.compress(v, key), v.shape, v.dtype)

    def delta(self, d):
        return None


# --------------------------------------------------------------------------- #
# registry — names usable in DQConfig.compressor
# --------------------------------------------------------------------------- #
REGISTRY = {
    "identity": Compressor(),
    "topk1": TopK(frac=0.01),
    "topk10": TopK(name="topk10", frac=0.10),
    "randk1": RandK(frac=0.01),
    "sign": SignMean(),
    # NOTE: l2-scaled stochastic quantization is only a contraction with
    # bucketing (QSGD [1] buckets at d=512): globally, E||Q(v)-v||^2 ~
    # (sqrt(d)/levels)||v||^2 which EXCEEDS ||v||^2 for d >~ 16k — the zero-bin
    # case the paper's Thm 2 proof skips (r=0 breaks its Eq. 38/39 step).
    # Measured in benchmarks/run.py and discussed in EXPERIMENTS.md §Repro.
    "qsgd8_l2": StochasticQuant(name="qsgd8_l2", bits=8, norm="l2",
                                per_block=512),
    "qsgd8_l2_global": StochasticQuant(name="qsgd8_l2_global", bits=8,
                                       norm="l2"),
    "qsgd8_linf": StochasticQuant(name="qsgd8_linf", bits=8, norm="linf"),
    "qsgd4_linf": StochasticQuant(name="qsgd4_linf", bits=4, norm="linf"),
    # 2-bit linf: levels = 1, i.e. stochastic ternary {-s, 0, +s} — the
    # floor rung of the same-structure quantizer ladder PlanFamily
    # descends (comm.planner.quant_ladder).
    "qsgd2_linf": StochasticQuant(name="qsgd2_linf", bits=2, norm="linf"),
    "qsgd8_block256": StochasticQuant(
        name="qsgd8_block256", bits=8, norm="linf", per_block=256
    ),
    # one scale per 1024-elem lane-aligned row: the bucket-native quantizer.
    # Over flat comm buckets error_feedback.compress_with_ef realizes it
    # with the fused Pallas quantize+EF kernel (one VMEM pass).
    "qsgd8_block1024": StochasticQuant(
        name="qsgd8_block1024", bits=8, norm="linf", per_block=1024
    ),
    # lower-bit rungs of the block-1024 ladder (identical payload layout:
    # int8 codes + one f32 scale per 1024-row — only `levels` changes, so
    # a PlanFamily over them dispatches by a traced scalar, not a retrace).
    "qsgd4_block1024": StochasticQuant(
        name="qsgd4_block1024", bits=4, norm="linf", per_block=1024
    ),
    "qsgd2_block1024": StochasticQuant(
        name="qsgd2_block1024", bits=2, norm="linf", per_block=1024
    ),
}


def get(name: str) -> Compressor:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(REGISTRY)}")
