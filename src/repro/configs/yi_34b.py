"""yi-34b [dense]: llama-architecture GQA.
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. [arXiv:2403.04652]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    activation="silu",
    norm="rmsnorm",
    use_rope=True,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
