"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,          # unused by ssd mixer
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,               # attention-free, no separate FFN (mamba block only)
    vocab_size=50_280,
    layer_pattern=("ssd",),
    norm="rmsnorm",
    use_rope=False,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    source="arXiv:2405.21060",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
