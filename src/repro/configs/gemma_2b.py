"""gemma-2b [dense]: GeGLU, head_dim=256, MQA (kv=1).
18L d_model=2048 8H d_ff=16384 vocab=256000. [arXiv:2403.08295]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    activation="geglu",
    norm="rmsnorm",
    use_rope=True,
    source="arXiv:2403.08295",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
