"""gemma-2b-swa [dense, beyond-paper variant]: gemma-2b with a 4096-token
sliding attention window so the dense family can serve long_500k
sub-quadratically (rolling KV cache). See DESIGN.md §long_500k."""
import dataclasses

from .gemma_2b import CONFIG as _BASE

CONFIG = dataclasses.replace(_BASE, name="gemma-2b-swa", attention_window=4096)
