"""dcgan32: the paper's own experimental architecture — a DCGAN-style
generator/discriminator pair for 32x32 images (CIFAR10-shaped), trained
with the WGAN loss of Eq. (3). Config lives in models/gan.py; this module
re-exports it for the registry. [arXiv:1511.06434 / the DQGAN paper §4]"""
from repro.models.gan import GANConfig

CONFIG = GANConfig(
    name="dcgan32",
    image_size=32,
    channels=3,
    latent_dim=128,
    base_width=64,
)
