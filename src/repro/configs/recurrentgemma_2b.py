"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 attention:recurrent
pattern. 26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000.
[arXiv:2402.19427 (Griffin / RecurrentGemma)]"""
from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,  # pattern below cycles (rglru, rglru, attn)
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "attn"),
    activation="geglu",
    norm="rmsnorm",
    use_rope=True,
    attention_window=2048,          # local attention -> long_500k capable
    rglru=RGLRUConfig(conv_width=4, expand=1),
    source="arXiv:2402.19427",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
