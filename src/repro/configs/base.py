"""Configuration dataclasses for models, shapes, meshes and the DQGAN run.

Everything is a frozen dataclass so configs are hashable and can be passed
as static arguments to jit. Each assigned architecture gets one module in
this package exporting ``CONFIG`` (the exact assigned spec) — use
``repro.configs.get(name)`` or ``repro.configs.registry()``.
"""
from __future__ import annotations

import dataclasses
import sys
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block settings (qwen3-moe, arctic)."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    # Arctic runs a small dense FFN residually in parallel with the MoE FFN.
    dense_residual_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-2
    router_z_coef: float = 1e-3
    # "global": one capacity pool over all tokens (one-hot cumsum across the
    #   whole batch — simple but serializes across the data axis).
    # "per_row": capacity per batch row; ranks/scatter stay local to each
    #   row so the dispatch parallelizes over 'data' with no cross-device
    #   cumsum (EXPERIMENTS.md §Perf hillclimb 1).
    dispatch: str = "global"

    @property
    def has_dense_residual(self) -> bool:
        return self.dense_residual_d_ff > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings."""
    state_dim: int = 128          # N: per-head state size
    head_dim: int = 64            # P: channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent-block settings."""
    conv_width: int = 4
    expand: int = 1               # rnn width = expand * d_model (RG uses ~1.0x lru_width=2560)
    c_constant: float = 8.0       # the fixed `c` in a = exp(-c * softplus(Λ) * r)


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper) settings. The audio frontend is a stub:
    inputs are precomputed frame embeddings of shape (B, enc_seq, d_model)."""
    enc_layers: int = 4
    enc_seq: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    # Layer pattern, cycled over the depth. Entries: 'attn' | 'rglru' | 'ssd'.
    layer_pattern: Tuple[str, ...] = ("attn",)
    activation: str = "silu"            # silu | geglu | gelu (geglu/silu are gated)
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    use_rope: bool = True
    rope_theta: float = 10_000.0
    attention_window: int = 0           # 0 -> global attention; >0 -> sliding window
    use_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    # Modality stub: inputs are precomputed embeddings, not token ids.
    embedding_inputs: bool = False
    source: str = ""                    # citation for the assigned spec
    # dtype for activations/params at scale ("float32" for small CPU runs)
    param_dtype: str = "float32"
    # scan/remat policy (perf knobs, see EXPERIMENTS.md §Perf)
    scan_layers: bool = True
    remat: str = "full"                 # none | full | dots
    # cross entropy computed in sequence chunks of this many tokens (0 = off)
    xent_chunk: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # ------------------------------------------------------------------ #
    @property
    def supports_long_context(self) -> bool:
        """True if decoding at 500k tokens is sub-quadratic / bounded-state."""
        pattern_ok = all(p != "attn" for p in self.layer_pattern) or (
            self.attention_window > 0
        )
        return pattern_ok

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None

    @property
    def positional(self) -> str:
        """rope | learned | none. SSM-only stacks need no positions; the
        learned table is for absolute-position models (whisper)."""
        if self.use_rope:
            return "rope"
        if self.is_encdec:
            return "learned"
        return "none"

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6ND)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.head_dim
        per_layer = {}
        per_layer["attn"] = (
            d * self.num_heads * hd          # q
            + 2 * d * self.num_kv_heads * hd  # k, v
            + self.num_heads * hd * d         # o
        )
        if self.rglru is not None:
            w = self.rglru.expand * d
            per_layer["rglru"] = 2 * d * w + w * d + 2 * w * w // 1 + w * self.rglru.conv_width
        if self.ssm is not None:
            di = self.ssm.expand * d
            nheads = di // self.ssm.head_dim
            conv_ch = di + 2 * self.ssm.state_dim
            per_layer["ssd"] = (
                d * (2 * di + 2 * self.ssm.state_dim + nheads)  # z,x,B,C,dt
                + conv_ch * (self.ssm.conv_width + 1)            # conv w+b
                + di * d + di                                    # out + norm
                + 3 * nheads                                     # A_log, D, dt_bias
            )
        n_norm = 2 * d
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff_expert * self.moe.num_experts + d * self.moe.num_experts
            if self.moe.has_dense_residual:
                ff += 3 * d * self.moe.dense_residual_d_ff
        else:
            mult = 3 if self.activation in ("silu", "geglu") else 2
            ff = mult * d * self.d_ff
        total_layers = 0
        for i in range(self.num_layers):
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            mixer = per_layer[kind]
            block_ff = ff if (kind != "ssd" or self.d_ff > 0) else 0
            total_layers += mixer + block_ff + n_norm
        total += total_layers + d  # final norm
        if self.encdec is not None:
            # encoder layers: self-attn + ff; decoder adds cross-attn per layer
            enc = self.encdec.enc_layers * (per_layer["attn"] + ff + n_norm)
            cross = self.num_layers * (per_layer["attn"] + d)
            total += enc + cross
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        expert_p = 3 * d * self.moe.d_ff_expert
        inactive = (self.moe.num_experts - self.moe.top_k) * expert_p * self.num_layers
        return int(full - inactive)

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests:
        2 layers, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        while heads % kv:
            kv -= 1
        changes = dict(
            name=self.name + "-smoke",
            num_layers=max(2, len(self.layer_pattern)) if len(self.layer_pattern) > 1 else 2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=max(d // heads, 8),
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            param_dtype="float32",
            xent_chunk=0,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 2 * d),
                dense_residual_d_ff=(2 * d if self.moe.has_dense_residual else 0),
                # ample capacity at smoke scale: keeps token dropping (a
                # legitimate train-vs-decode divergence) out of unit tests
                capacity_factor=8.0,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk_size=32
            )
        if self.encdec is not None:
            changes["encdec"] = dataclasses.replace(
                self.encdec, enc_layers=2, enc_seq=64
            )
        if self.attention_window:
            changes["attention_window"] = min(self.attention_window, 32)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


_STRATEGY_SHIM_DEPTH = 0


def _inside_dataclasses_replace() -> bool:
    """True when the running DQConfig.__init__ was invoked by
    `dataclasses.replace`. A replace() call re-runs __post_init__, but the
    caller is patching an already-constructed (already-warned, possibly
    strategy-built) config — warning again would flag the blessed
    `replace(dq, lr=...)` spelling as deprecated."""
    try:
        # this helper (0) <- __post_init__ (1) <- __init__ (2) <- caller (3)
        f = sys._getframe(3)
    except ValueError:
        return False
    # 3.13+ routes dataclasses.replace/copy.replace through _replace
    return (f.f_code.co_name in ("replace", "_replace")
            and f.f_code.co_filename.endswith("dataclasses.py"))


@contextmanager
def _building_from_strategy():
    """Suppress the legacy-field deprecation warning while `from_strategy`
    mirrors a Strategy into the flat fields."""
    global _STRATEGY_SHIM_DEPTH
    _STRATEGY_SHIM_DEPTH += 1
    try:
        yield
    finally:
        _STRATEGY_SHIM_DEPTH -= 1


@dataclass(frozen=True)
class DQConfig:
    """DQGAN training settings: the optimizer/field knobs plus a thin
    legacy shim over `repro.strategy.Strategy`.

    The distribution axes (compressor, exchange, schedule, participation,
    comm plan, ...) are owned by the typed `Strategy` API (DESIGN.md §9);
    the flat fields below mirror it for backward compatibility and are
    DEPRECATED as an input surface — construct a `Strategy` and use
    ``DQConfig.from_strategy(strategy, optimizer=..., lr=...)``. Every
    DQConfig carries a validated `.strategy` (built at construction, so
    a bad combination raises `StrategyError` here, not at jit time).
    """
    compressor: str = "qsgd8_linf"   # key into core.compressors.REGISTRY
    exchange: str = "sim"            # exact | sim | allgather | two_phase
    error_feedback: bool = True      # False -> CPOAdam-GQ style baseline
    message: str = "update"          # "update" (eta*g + e, paper) | "grad"
    extrapolation: str = "local"     # "local" (paper) | "global" (FSDP-safe)
    optimizer: str = "omd"           # omd | oadam | adam | sgd
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    ef_dtype: str = "float32"        # bf16 halves EF memory at 100B scale
    # mesh axes acting as DQGAN "workers" (the paper's M machines).
    worker_axes: Tuple[str, ...] = ("data",)
    # per-top-level-group learning-rate multipliers, e.g. (("disc", 5.0),)
    # — the TTUR/n_critic analogue, applied after Adam preconditioning
    # (which would otherwise normalize a gradient-level boost away).
    lr_mults: Tuple[Tuple[str, float], ...] = ()
    # SPMD style: "shard_map" (manual worker collectives; int8 on the wire)
    # or "vmap" (workers as a vmapped leading axis, pure auto-sharding —
    # sidesteps an XLA partitioner CHECK with manual-pod + FSDP-auto inside;
    # paper semantics exact, wire format compiler-chosen). See DESIGN.md §2.
    spmd: str = "shard_map"
    # split-phase exchange: start delayed(τ) collectives before the
    # round's field compute so XLA can overlap wire time with compute
    # (DESIGN.md §13). Requires spmd="shard_map" and exchange != "exact".
    overlap: bool = False
    # ---- repro.comm: bucketing + layer-wise planning (DESIGN.md §3) ------ #
    # "none" keeps the seed per-tensor exchange; any planner policy
    # ("uniform" | "size_tiered" | "delta_budget") routes unsharded leaves
    # through flat, worker-divisible, lane-aligned buckets instead.
    comm_plan: str = "none"
    bucket_mb: float = 4.0           # f32 MiB per bucket before closing it
    comm_budget_mb: float = 0.0      # delta_budget: payload MiB/step target
    # round-adaptive PlanFamily: re-run the delta_budget descent per
    # participation count n against the effective budget B·M/n, selected
    # in-step by a branch-free gather on the round's participant count
    # (DESIGN.md §10).
    comm_adaptive: bool = False
    # ---- repro.sched: execution schedule (DESIGN.md §5, §8) -------------- #
    # "every_step" (seed semantics) | "local_k" (exchange every K steps,
    # message accumulates in DQState.sched["accum"]) | "delayed" (bounded-
    # staleness exchange overlapping compute; pending message(s) in
    # DQState.sched["pending"], staleness correction in the OMD lookahead).
    schedule: str = "every_step"
    local_k: int = 1                 # K for schedule="local_k"
    # pipeline depth τ for schedule="delayed": the message exchanged at
    # step t was produced at step t−τ. τ=1 keeps PR 2's single-slot
    # layout bit-exactly; τ>1 carries a (τ, ...) ring buffer plus the
    # per-worker version vector DQState.sched["versions"] (DESIGN.md §8).
    staleness_tau: int = 1
    # heterogeneous per-worker staleness for schedule="delayed": worker m
    # pulls the message it produced τ_m steps ago from the shared
    # depth-max(τ_m) ring (empty = homogeneous; length must match the
    # worker count).
    tau_vector: Tuple[int, ...] = ()
    # fraction of workers sampled per exchange round (count-exact); the
    # workers sitting out fold their message into the EF residual.
    participation: float = 1.0
    # straggler profile name (sched.straggler) — consumed only by the
    # host-side wall-clock model, never by the jitted step.
    straggler_profile: str = "none"
    # ---- parameter/optimizer-state layout (DESIGN.md §15) ---------------- #
    # "replicated" (every worker holds params + moments, DDP) or "fsdp"
    # (moments — and, at zero_stage=3, the authoritative params — shard
    # across the worker axes; gradient exchange lowers onto a compressed
    # reduce-scatter and the update returns via a compressed all-gather).
    parallelism: str = "replicated"
    fsdp_axis: str = "data"          # mesh axis owning the shards
    zero_stage: int = 3              # 2 = moments sharded, 3 = params too
    # compressor + owner-side EF for the fsdp all-gather leg (the
    # optimizer-state exchange of arXiv 2004.14180); "identity" keeps the
    # gather exact.
    moment_compressor: str = "identity"
    moment_ef: bool = True
    # repro.obs telemetry level ("off" | "wire" | "full") and phase-span
    # toggle — jit-static, contractually trajectory-invariant (excluded
    # from Strategy.short_hash(); DESIGN.md §11).
    obs_metrics: str = "off"
    obs_spans: bool = False
    # host-side step profiler (repro.obs.profile, DESIGN.md §12.1) —
    # never read by the jitted step, so profiling off/on is bit-exact.
    obs_profile: bool = False

    # ------------------------------------------------------------------ #
    # the strategy shim (repro.strategy, DESIGN.md §9)
    # ------------------------------------------------------------------ #
    def __post_init__(self):
        from repro.strategy import LEGACY_FIELDS, Strategy

        legacy = {k: getattr(self, k) for k in LEGACY_FIELDS}
        # construction-time validation of the whole distribution lattice:
        # a bad combination is a StrategyError (a ValueError) HERE.
        strat = Strategy.from_legacy(**legacy)
        object.__setattr__(self, "_strategy", strat)
        if (_STRATEGY_SHIM_DEPTH == 0 and strat != Strategy()
                and not _inside_dataclasses_replace()):
            warnings.warn(
                "passing distribution fields (compressor/exchange/"
                "schedule/...) to DQConfig directly is deprecated; build "
                "a repro.strategy.Strategy and use "
                "DQConfig.from_strategy(strategy, ...)",
                DeprecationWarning, stacklevel=3)

    @property
    def strategy(self):
        """The validated `repro.strategy.Strategy` this config denotes."""
        return self._strategy

    @classmethod
    def from_strategy(cls, strategy, **optim_fields) -> "DQConfig":
        """The blessed constructor: a typed `Strategy` for the
        distribution axes plus optimizer-side keywords (optimizer, lr,
        message, extrapolation, lr_mults, betas, eps)."""
        from repro.strategy import LEGACY_FIELDS

        overlap = sorted(set(optim_fields) & set(LEGACY_FIELDS))
        if overlap:
            raise ValueError(
                f"from_strategy: {overlap} are strategy fields — set them "
                f"on the Strategy (e.g. strategy.evolve(...)), not as "
                f"keywords")
        with _building_from_strategy():
            return cls(**strategy.legacy_fields(), **optim_fields)
