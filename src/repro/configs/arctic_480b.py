"""arctic-480b [moe]: 128 experts top-2 + a dense FFN residually in
parallel (dense-MoE hybrid). 35L d_model=7168 56H (GQA kv=8) expert
d_ff=4864 vocab=32000. [hf:Snowflake/snowflake-arctic-base]
The dense-residual FFN width is not in the assignment line; we use
2*d_model=14336 and cite the model card's dense+MoE parallel structure."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    activation="silu",
    norm="rmsnorm",
    use_rope=True,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual_d_ff=14336,
    ),
    source="hf:Snowflake/snowflake-arctic-base",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
