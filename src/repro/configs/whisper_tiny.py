"""whisper-tiny [audio]: encoder-decoder; the mel-spectrogram + conv
frontend is a STUB — input_specs provides precomputed frame embeddings of
shape (B, 1500, d_model) for the encoder. 4L d_model=384 6H d_ff=1536
vocab=51865. [arXiv:2212.04356]"""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,                 # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    activation="gelu",
    norm="layernorm",
    use_rope=False,               # learned absolute positions
    use_bias=True,
    encdec=EncDecConfig(enc_layers=4, enc_seq=1500),
    embedding_inputs=True,        # encoder consumes precomputed embeddings
    tie_embeddings=True,
    source="arXiv:2212.04356",
    param_dtype="bfloat16",
    scan_layers=False,            # 4 layers: unrolled is cheaper to compile
)
