"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8, expert d_ff=768.
48L d_model=2048 32H (GQA kv=4) vocab=151936. [hf:Qwen/Qwen3-30B-A3B]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151_936,
    activation="silu",
    norm="rmsnorm",
    use_rope=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    source="hf:Qwen/Qwen3-30B-A3B",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
