"""Assigned-architecture configs (one module per arch) + lookup helpers."""
from __future__ import annotations

from importlib import import_module

from .base import (  # noqa: F401
    DQConfig,
    EncDecConfig,
    InputShape,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SHAPES,
    SSMConfig,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)

# module name -> arch id (assigned pool + paper's own + beyond-paper variants)
_ARCH_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "gemma-2b": "gemma_2b",
    "yi-34b": "yi_34b",
    "mamba2-1.3b": "mamba2_1_3b",
    "chameleon-34b": "chameleon_34b",
    "command-r-plus-104b": "command_r_plus_104b",
    "whisper-tiny": "whisper_tiny",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "arctic-480b": "arctic_480b",
    "starcoder2-7b": "starcoder2_7b",
    # beyond-paper variant: gemma-2b with a sliding window so long_500k runs
    "gemma-2b-swa": "gemma_2b_swa",
    # the paper's own experimental architecture (DCGAN-backbone GAN)
    "dcgan32": "dcgan32",
}

ASSIGNED = tuple(k for k in _ARCH_MODULES if k not in ("gemma-2b-swa", "dcgan32"))


def get(name: str) -> ModelConfig:
    try:
        mod = import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    return mod.CONFIG


def registry() -> dict:
    return {name: get(name) for name in _ARCH_MODULES}
