"""command-r-plus-104b [dense]: GQA, no biases.
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
[hf:CohereForAI/c4ai-command-r-v01]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256_000,
    activation="silu",
    norm="layernorm",
    use_rope=True,
    use_bias=False,
    tie_embeddings=True,   # cohere ties input/output embeddings
    source="hf:CohereForAI/c4ai-command-r-v01",
    param_dtype="bfloat16",
    xent_chunk=512,
)
