"""starcoder2-7b [dense]: GQA + RoPE, layernorm + non-gated GELU MLP,
biases on. 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
[arXiv:2402.19173]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49_152,
    activation="gelu",
    norm="layernorm",
    use_rope=True,
    use_bias=True,
    source="arXiv:2402.19173",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
