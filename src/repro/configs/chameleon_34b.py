"""chameleon-34b [vlm]: early-fusion mixed-modal; images arrive as discrete
VQ tokens in the shared vocab (the VQ-VAE image tokenizer is the stubbed
modality frontend — input_specs feeds token ids that may be image tokens).
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. [arXiv:2405.09818]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65_536,
    activation="silu",
    norm="rmsnorm",
    use_rope=True,
    source="arXiv:2405.09818",
    param_dtype="bfloat16",
    xent_chunk=1024,
)
