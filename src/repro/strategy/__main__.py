"""Preset-instantiation smoke: ``python -m repro.strategy``.

Constructs every registry preset, asserts the exact JSON round-trip, and
prints one line per preset (name, structural hash, description). The CI
matrix runs this next to ``launch.train --help`` so a broken preset or a
schema/CLI drift fails fast. ``--json NAME`` dumps one preset's JSON;
``--list-presets`` prints name + one-line doc + structural hash.
"""
from __future__ import annotations

import argparse
import sys

from .presets import PRESET_DOCS
from . import PRESETS, Strategy, get_preset


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.strategy")
    ap.add_argument("--json", metavar="NAME", default="",
                    help="print one preset's canonical JSON and exit")
    ap.add_argument("--list-presets", action="store_true",
                    help="print name, one-line description and structural "
                         "hash for every registry preset")
    args = ap.parse_args(argv)
    if args.json:
        print(get_preset(args.json).to_json())
        return 0
    if args.list_presets:
        for name in sorted(PRESETS):
            st = PRESETS[name]
            doc = PRESET_DOCS.get(name, st.describe())
            print(f"{name:24s} {st.short_hash()}  {doc}")
        return 0
    bad = 0
    for name in sorted(PRESETS):
        st = PRESETS[name]
        back = Strategy.from_json(st.to_json())
        ok = back == st and back.to_json() == st.to_json()
        bad += not ok
        print(f"{name:24s} {st.short_hash()} "
              f"{'ok ' if ok else 'ROUND-TRIP MISMATCH '}{st.describe()}")
    print(f"{len(PRESETS)} presets, {bad} failures")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
