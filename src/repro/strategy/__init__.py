"""repro.strategy — the typed, composable distribution-strategy API
(DESIGN.md §9).

A `Strategy` composes five frozen components — `Compression` (what goes
on the wire), `ExchangePlan` (how it moves), `Schedule` (when workers
talk), `Participation` (who talks) and `Observability` (what we measure
while they do) — with cross-field validation at
construction (`StrategyError`), a preset registry (`PRESETS`,
`get_preset`) and an exact canonical-JSON round-trip
(`Strategy.to_json`/`from_json`, hashed by `short_hash()` for the CI
regression gate and the checkpoint resume guard).

`core.dqgan.DQGAN` consumes a `Strategy` (directly, or via the
`configs.base.DQConfig` legacy shim); `strategy.cli` generates
`launch.train`'s flag surface from the component schemas.
"""
from .cli import add_strategy_args, strategy_from_args  # noqa: F401
from .components import (  # noqa: F401
    METRIC_LEVELS,
    SPMD_STYLES,
    Compression,
    ExchangePlan,
    MomentCompression,
    Observability,
    Participation,
    Schedule,
    StrategyError,
)
from .presets import (  # noqa: F401
    PRESET_DOCS,
    PRESETS,
    get_preset,
    register_preset,
)
from .strategy import LEGACY_FIELDS, Strategy  # noqa: F401
