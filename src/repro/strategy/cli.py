"""CLI auto-generation from the component schemas (DESIGN.md §9.4).

`launch.train`'s strategy flags are generated from the dataclass fields
of each component (the ``metadata`` attached in components.py), so the
argparse surface, the typed API and the JSON schema are one definition.
The generated flags keep the legacy spellings (``--compressor``,
``--comm-plan``, ``--schedule``, ``--staleness-tau``, ...), plus:

    --preset NAME          start from a registry preset
    --strategy-json X      start from a JSON file path (or inline JSON)

Explicit flags override the preset/JSON base, which overrides the
defaults. `worker_axes` never has a flag — the launcher derives it from
the actual mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Optional, Tuple

from .components import (
    Compression,
    ExchangePlan,
    MomentCompression,
    Observability,
    Participation,
    Schedule,
)
from .presets import PRESETS, get_preset
from .strategy import Strategy

_COMPONENTS = (Compression, ExchangePlan, Schedule, Participation,
               MomentCompression, Observability)


def _cli_fields():
    """(component class, dataclass field, metadata) for every flag-backed
    field, in declaration order."""
    for cls in _COMPONENTS:
        for f in dataclasses.fields(cls):
            meta = dict(f.metadata) if f.metadata else {}
            if "legacy" in meta:
                yield cls, f, meta


def add_strategy_args(ap: argparse.ArgumentParser) -> None:
    """Add the auto-generated strategy flags to `ap`. All flags default
    to argparse.SUPPRESS so `strategy_from_args` can tell 'explicitly
    passed' from 'left at default'."""
    g = ap.add_argument_group(
        "strategy", "distribution strategy (repro.strategy; flags are "
                    "generated from the component schemas)")
    g.add_argument("--preset", choices=sorted(PRESETS), default=None,
                   help="start from a named strategy preset")
    g.add_argument("--strategy-json", default=None, metavar="PATH|JSON",
                   help="start from a Strategy JSON (file path or inline)")
    for cls, f, meta in _cli_fields():
        flag = meta["flag"]
        choices = meta["choices"]() if meta.get("choices") else None
        kw = dict(default=argparse.SUPPRESS, help=meta["help"],
                  dest="strategy_" + meta["legacy"])
        if f.type in ("bool", bool) or isinstance(f.default, bool):
            # boolean fields get a --x/--no-x pair so a preset/JSON base
            # can be overridden in BOTH directions (the legacy spelling
            # --no-error-feedback is the auto-generated negation)
            kw["action"] = argparse.BooleanOptionalAction
        else:
            kw["type"] = type(f.default)
            if choices:
                kw["choices"] = choices
            else:
                kw["metavar"] = meta["legacy"].upper()
        g.add_argument(flag, **kw)


def strategy_from_args(
        args: argparse.Namespace,
        worker_axes: Optional[Tuple[str, ...]] = None) -> Strategy:
    """Resolve the parsed flags into a validated `Strategy`:
    defaults ← preset/JSON base ← explicit flags ← `worker_axes`."""
    base = Strategy()
    if getattr(args, "preset", None) and getattr(args, "strategy_json",
                                                 None):
        raise SystemExit("--preset and --strategy-json are exclusive")
    if getattr(args, "preset", None):
        base = get_preset(args.preset)
    elif getattr(args, "strategy_json", None):
        spec = args.strategy_json
        if os.path.exists(spec):
            with open(spec) as fh:
                spec = fh.read()
        base = Strategy.from_json(spec)
    overrides = {}
    for _, f, meta in _cli_fields():
        dest = "strategy_" + meta["legacy"]
        if hasattr(args, dest):
            overrides[meta["legacy"]] = getattr(args, dest)
    # switching a kind resets its companion fields unless they were also
    # given explicitly — otherwise a preset's k/tau/budget would survive
    # onto a schedule/plan they are invalid for (e.g.
    # `--preset low_bandwidth --schedule every_step` with the preset's K=4)
    if overrides.get("schedule", base.schedule.kind) != base.schedule.kind:
        overrides.setdefault("local_k", 1)
        overrides.setdefault("staleness_tau", 1)
    new_plan = overrides.get("comm_plan", base.compression.plan)
    if new_plan != base.compression.plan and new_plan != "delta_budget":
        overrides.setdefault("comm_budget_mb", 0.0)
        overrides.setdefault("comm_adaptive", False)
    if worker_axes is not None:
        overrides["worker_axes"] = tuple(worker_axes)
    return base.evolve(**overrides)
