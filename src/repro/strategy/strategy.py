"""`Strategy` — the composed, validated, serializable distribution strategy.

One `Strategy` names a point in the full composition space of the paper's
method: (Compression × ExchangePlan × Schedule × Participation). The
components validate their own fields; this module validates the
*cross-field* lattice (every known-bad combination is a one-line
`StrategyError` at construction), serializes the whole object to
canonical JSON (`to_json`/`from_json`, exact round-trip — used by
checkpoints, `experiments/*.json` and the CI regression gate, which keys
baselines by `short_hash()`), and bridges the legacy flat `DQConfig`
flag-bag spellings (`from_legacy`/`legacy_fields`/`evolve`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

from .components import (
    Compression,
    ExchangePlan,
    MomentCompression,
    Observability,
    Participation,
    Schedule,
    StrategyError,
)

_COMPONENTS: Tuple[Tuple[str, type], ...] = (
    ("compression", Compression),
    ("exchange", ExchangePlan),
    ("schedule", Schedule),
    ("participation", Participation),
    ("moments", MomentCompression),
    ("observability", Observability),
)

# Components that define the strategy's *structural identity* — what
# `short_hash()` digests. Observability is excluded: it is contractually
# trajectory-invariant (metrics="off" is bit-identical, and every level
# must be too — see the bit-exactness tests), so regression baselines
# and checkpoint resume guards keyed by the hash stay valid across
# telemetry settings.
_IDENTITY_COMPONENTS: Tuple[str, ...] = tuple(
    name for name, _ in _COMPONENTS if name != "observability")

# legacy DQConfig field -> (component attribute, component field)
LEGACY_FIELDS: Dict[str, Tuple[str, str]] = {
    "compressor": ("compression", "compressor"),
    "error_feedback": ("compression", "error_feedback"),
    "ef_dtype": ("compression", "ef_dtype"),
    "comm_plan": ("compression", "plan"),
    "bucket_mb": ("compression", "bucket_mb"),
    "comm_budget_mb": ("compression", "budget_mb"),
    "comm_adaptive": ("compression", "adaptive"),
    "exchange": ("exchange", "kind"),
    "spmd": ("exchange", "spmd"),
    "worker_axes": ("exchange", "worker_axes"),
    "overlap": ("exchange", "overlap"),
    "schedule": ("schedule", "kind"),
    "local_k": ("schedule", "k"),
    "staleness_tau": ("schedule", "tau"),
    "tau_vector": ("schedule", "tau_vector"),
    "participation": ("participation", "fraction"),
    "straggler_profile": ("participation", "straggler_profile"),
    "parallelism": ("exchange", "parallelism"),
    "fsdp_axis": ("exchange", "fsdp_axis"),
    "zero_stage": ("exchange", "zero_stage"),
    "moment_compressor": ("moments", "compressor"),
    "moment_ef": ("moments", "error_feedback"),
    "obs_metrics": ("observability", "metrics"),
    "obs_spans": ("observability", "spans"),
    "obs_profile": ("observability", "profile"),
}


@dataclass(frozen=True)
class Strategy:
    """The distribution strategy `DQGAN` consumes. Frozen and hashable
    (jit-static safe); the default is the paper's setting (qsgd8 + EF,
    sim exchange, lockstep every-step schedule, full participation)."""

    compression: Compression = Compression()
    exchange: ExchangePlan = ExchangePlan()
    schedule: Schedule = Schedule()
    participation: Participation = Participation()
    moments: MomentCompression = MomentCompression()
    observability: Observability = Observability()

    def __post_init__(self):
        for name, cls in _COMPONENTS:
            got = getattr(self, name)
            if not isinstance(got, cls):
                raise StrategyError(
                    f"{name}: expected a {cls.__name__}, got "
                    f"{type(got).__name__}")
        # ---- the cross-field lattice ---------------------------------- #
        if self.participation.partial and self.exchange.kind == "exact":
            raise StrategyError(
                "participation.fraction: partial participation needs a "
                "compressed exchange ('sim'/'allgather'/'two_phase') — "
                "with exchange.kind='exact' non-participants cannot ride "
                "through the collective as zero payloads")
        if self.exchange.spmd == "vmap":
            if self.compression.bucketing:
                raise StrategyError(
                    "compression.plan: bucketing needs "
                    "exchange.spmd='shard_map' — the vmap worker "
                    "formulation keeps per-tensor semantics (its wire "
                    "format is compiler-chosen), so a comm plan would be "
                    "silently ignored")
            if self.exchange.kind != "sim":
                raise StrategyError(
                    f"exchange.kind: spmd='vmap' implements the 'sim' "
                    f"(per-worker roundtrip + mean) semantics only; "
                    f"kind={self.exchange.kind!r} would be silently "
                    f"reinterpreted — spell it exchange.kind='sim'")
        if self.observability.on and not self.compression.error_feedback:
            raise StrategyError(
                "observability.metrics: empirical-δ telemetry reads the "
                "materialized EF residual (e_new = m − Q(m)); it needs "
                "compression.error_feedback=True")
        if self.exchange.fsdp:
            if self.participation.partial:
                raise StrategyError(
                    "participation.fraction: partial participation with "
                    "exchange.parallelism='fsdp' is undefined — a "
                    "participation mask composes with *replicated* "
                    "exchange only; masked reduce-scatter would average "
                    "with silently wrong denominators on every shard. "
                    "Use participation.fraction=1.0 with fsdp")
            if not self.compression.bucketing:
                raise StrategyError(
                    "compression.plan: exchange.parallelism='fsdp' shards "
                    "flat buckets (one lane-aligned chunk per worker); it "
                    "needs the bucketing pipeline — set a comm plan "
                    "(e.g. plan='uniform')")
            if self.compression.adaptive:
                raise StrategyError(
                    "compression.adaptive: round-adaptive plan selection "
                    "keys on the participant count, which fsdp pins to "
                    "the full worker set — the combination is untested; "
                    "use a static plan with parallelism='fsdp'")
        elif self.moments != MomentCompression():
            raise StrategyError(
                "moments.compressor: the optimizer-state compression "
                "slot is only consumed by exchange.parallelism='fsdp' "
                "(replicated DDP never puts moments on the wire) — a "
                "non-default moments component would be silently ignored")

    # ------------------------------------------------------------------ #
    # serialization: canonical, exact JSON round-trip
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {name: dataclasses.asdict(getattr(self, name))
                for name, _ in _COMPONENTS}

    def to_json(self) -> str:
        """Canonical JSON: sorted keys, no whitespace — two equal
        strategies always serialize to the same bytes (the regression
        gate and checkpoint guard hash this string)."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_dict(cls, d: dict) -> "Strategy":
        if not isinstance(d, dict):
            raise StrategyError(f"strategy: expected an object, got "
                                f"{type(d).__name__}")
        known = {name for name, _ in _COMPONENTS}
        unknown = sorted(set(d) - known)
        if unknown:
            raise StrategyError(
                f"strategy: unknown component(s) {unknown}; have "
                f"{sorted(known)}")
        parts = {}
        for name, comp_cls in _COMPONENTS:
            sub = d.get(name, {})
            fields = {f.name for f in dataclasses.fields(comp_cls)}
            bad = sorted(set(sub) - fields)
            if bad:
                raise StrategyError(
                    f"{name}: unknown field(s) {bad}; have {sorted(fields)}")
            parts[name] = comp_cls(**sub)
        return cls(**parts)

    @classmethod
    def from_json(cls, s: str) -> "Strategy":
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise StrategyError(f"strategy: invalid JSON ({e})") from None
        return cls.from_dict(d)

    def identity_dict(self) -> dict:
        """The trajectory-defining subset of `to_dict()` — every
        component except observability, which is contractually
        bit-exact-invariant and therefore not structural identity."""
        return {name: dataclasses.asdict(getattr(self, name))
                for name in _IDENTITY_COMPONENTS}

    def short_hash(self) -> str:
        """12-hex digest of the canonical *identity* JSON — the
        structural identity the benchmark-regression gate keys baselines
        by and the checkpoint guard verifies. Telemetry settings
        (observability.*) do not shift it, so baselines recorded without
        obs stay valid for instrumented runs."""
        ident = json.dumps(self.identity_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(ident.encode()).hexdigest()[:12]

    # ------------------------------------------------------------------ #
    def diff(self, other: "Strategy") -> List[str]:
        """Field-level differences, one dotted line each (both-ways)."""
        out = []
        for comp, _ in _COMPONENTS:
            a, b = getattr(self, comp), getattr(other, comp)
            for f in dataclasses.fields(a):
                va, vb = getattr(a, f.name), getattr(b, f.name)
                if va != vb:
                    out.append(f"{comp}.{f.name}: {va!r} != {vb!r}")
        return out

    def describe(self) -> str:
        c, e, s, p = (self.compression, self.exchange, self.schedule,
                      self.participation)
        bits = [f"{c.compressor}{'+ef' if c.error_feedback else ''}",
                e.kind, s.describe()]
        if c.bucketing:
            bits.append(f"plan={c.plan}"
                        + ("(adaptive)" if c.adaptive else ""))
        if p.partial:
            bits.append(f"part={p.fraction}")
        if p.straggler_profile != "none":
            bits.append(f"stragglers={p.straggler_profile}")
        if e.fsdp:
            bits.append(f"fsdp(zero{e.zero_stage}"
                        + ("" if self.moments.lossless
                           else f",moments={self.moments.compressor}")
                        + ")")
        if e.spmd != "shard_map":
            bits.append(e.spmd)
        if self.observability.on:
            bits.append(f"obs={self.observability.metrics}")
        return " ".join(bits)

    # ------------------------------------------------------------------ #
    # the legacy flat-field bridge
    # ------------------------------------------------------------------ #
    def evolve(self, **legacy_kw) -> "Strategy":
        """A copy with legacy flat-field spellings applied, e.g.
        ``strategy.evolve(schedule="delayed", staleness_tau=4)``. Sweep
        code and the `DQConfig` shim share this mapping."""
        unknown = sorted(set(legacy_kw) - set(LEGACY_FIELDS))
        if unknown:
            raise StrategyError(
                f"strategy: unknown legacy field(s) {unknown}; have "
                f"{sorted(LEGACY_FIELDS)}")
        by_comp: Dict[str, Dict[str, Any]] = {}
        for k, v in legacy_kw.items():
            comp, fld = LEGACY_FIELDS[k]
            by_comp.setdefault(comp, {})[fld] = v
        changes = {comp: dataclasses.replace(getattr(self, comp), **sub)
                   for comp, sub in by_comp.items()}
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_legacy(cls, **legacy_kw) -> "Strategy":
        """Build from the flat DQConfig field spellings."""
        return cls().evolve(**legacy_kw)

    def legacy_fields(self) -> Dict[str, Any]:
        """The flat DQConfig mirror of this strategy."""
        return {k: getattr(getattr(self, comp), fld)
                for k, (comp, fld) in LEGACY_FIELDS.items()}

    # ------------------------------------------------------------------ #
    def modeled_wire_bytes(self, n_elems: int, n_workers: int) -> int:
        """Analytic per-worker bytes of one exchange of `n_elems` floats
        under this strategy (benchmarks' wire model). Under fsdp this is
        the split round: gradient reduce-scatter + moments/param
        all-gather, each leg under its own compressor."""
        if self.exchange.fsdp:
            from repro.core import compressors as C
            from repro.core import exchange as X
            return X.modeled_fsdp_wire_bytes(
                self.exchange.kind, C.get(self.compression.compressor),
                C.get(self.moments.compressor), (n_elems,), n_workers)
        return self.exchange.modeled_wire_bytes(
            self.compression.compressor, n_elems, n_workers)
