"""Typed, frozen distribution-strategy components (DESIGN.md §9).

Each component owns one axis of the paper's composition — *what* goes on
the wire (`Compression`), *how* workers move it (`ExchangePlan`), *when*
they talk (`Schedule`) and *who* talks (`Participation`) — and validates
its own fields at construction so a bad spelling is a one-line
`StrategyError` naming the field, not a jit-time stack trace. The
components are plain frozen dataclasses: hashable (jit-static safe),
comparable, and serializable field-by-field (strategy.py holds the JSON
round-trip and the cross-field validation of the composed `Strategy`).

The runtime dispatch that `core.dqgan` used to do by string-matching
`DQConfig` flags lives here as component methods: `Schedule.init_slots`/
`wire_head`/`fold`/`staleness_correction` implement the per-step schedule
dataflow shared by both SPMD paths, `Compression.build` produces the
bucket layout + per-bucket compressor plan, `ExchangePlan.leaf_plans`
plans the per-tensor collectives, and `Participation.round_setup` draws
the shared round mask.

Every field that is a CLI knob carries ``metadata`` with its legacy flag
spelling — `strategy.cli` generates the `launch.train` argparse surface
from these schemas, so the flag set, the dataclass and the JSON schema
cannot drift apart.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


class StrategyError(ValueError):
    """A mis-composed distribution strategy, raised at construction time.

    Subclasses ValueError so legacy call sites (and tests) that guarded
    the old jit-time `ValueError`s keep working."""


def _cli(legacy: str, help_: str, choices: Optional[Callable] = None) -> dict:
    """Field metadata for the auto-generated CLI: ``legacy`` is the
    DQConfig field / argparse dest name (the flag is ``--legacy-name``;
    booleans additionally get a generated ``--no-`` negation), ``choices``
    is a thunk evaluated at parser-build time (registries may grow after
    import)."""
    return {"legacy": legacy, "help": help_, "choices": choices,
            "flag": "--" + legacy.replace("_", "-")}


def _compressor_names():
    from repro.core import compressors as C
    return tuple(sorted(C.REGISTRY))


def _plan_policies():
    from repro.comm.planner import ALL_POLICIES
    return ALL_POLICIES


def _exchange_kinds():
    from repro.core.exchange import STRATEGIES
    return STRATEGIES


def _schedule_kinds():
    from repro.sched.schedule import SCHEDULES
    return SCHEDULES


def _straggler_profiles():
    from repro.sched.straggler import PROFILES
    return tuple(sorted(PROFILES))


SPMD_STYLES = ("shard_map", "vmap")

PARALLELISM_MODES = ("replicated", "fsdp")

ZERO_STAGES = (2, 3)


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Compression:
    """WHAT goes on the wire: the δ-approximate compressor, error
    feedback, and the repro.comm bucket/planner pipeline."""

    compressor: str = field(default="qsgd8_linf", metadata=_cli(
        "compressor", "key into core.compressors.REGISTRY",
        _compressor_names))
    error_feedback: bool = field(default=True, metadata=_cli(
        "error_feedback", "carry the compression residual (paper EF)"))
    ef_dtype: str = field(default="float32", metadata=_cli(
        "ef_dtype", "dtype of the EF residuals (bf16 halves EF memory)"))
    plan: str = field(default="none", metadata=_cli(
        "comm_plan", "repro.comm bucketing + layer-wise planner policy",
        _plan_policies))
    bucket_mb: float = field(default=4.0, metadata=_cli(
        "bucket_mb", "f32 MiB per gradient bucket"))
    budget_mb: float = field(default=0.0, metadata=_cli(
        "comm_budget_mb", "delta_budget policy: payload MiB/step target"))
    adaptive: bool = field(default=False, metadata=_cli(
        "comm_adaptive", "round-adaptive PlanFamily: re-run the "
        "delta_budget descent per participation count n against the "
        "effective budget B*M/n (DESIGN.md §10)"))

    def __post_init__(self):
        from repro.core import compressors as C
        if self.compressor not in C.REGISTRY:
            raise StrategyError(
                f"compression.compressor: unknown compressor "
                f"{self.compressor!r}; have {sorted(C.REGISTRY)}")
        try:
            dt = jnp.dtype(self.ef_dtype)
        except TypeError as e:
            raise StrategyError(
                f"compression.ef_dtype: {self.ef_dtype!r} is not a dtype "
                f"({e})") from None
        if not jnp.issubdtype(dt, jnp.floating):
            raise StrategyError(
                f"compression.ef_dtype: residuals need a floating dtype, "
                f"got {self.ef_dtype!r}")
        if self.plan not in _plan_policies():
            raise StrategyError(
                f"compression.plan: unknown comm plan {self.plan!r}; "
                f"have {_plan_policies()}")
        if self.bucket_mb <= 0:
            raise StrategyError(
                f"compression.bucket_mb: must be > 0, got {self.bucket_mb}")
        if self.budget_mb < 0:
            raise StrategyError(
                f"compression.budget_mb: must be >= 0, got {self.budget_mb}")
        if self.plan == "delta_budget" and self.budget_mb <= 0:
            raise StrategyError(
                "compression.budget_mb: plan='delta_budget' needs a "
                "positive per-step byte budget (set budget_mb / "
                "--comm-budget-mb)")
        if self.plan != "delta_budget" and self.budget_mb > 0:
            raise StrategyError(
                f"compression.budget_mb: a byte budget only applies to "
                f"plan='delta_budget', not plan={self.plan!r}")
        if self.adaptive:
            if self.plan != "delta_budget":
                raise StrategyError(
                    f"compression.adaptive: a round-adaptive PlanFamily "
                    f"re-runs the delta_budget descent per participation "
                    f"count; it needs plan='delta_budget', not "
                    f"plan={self.plan!r}")
            from repro.comm.planner import quant_ladder
            try:
                quant_ladder(self.compressor)
            except ValueError as e:
                raise StrategyError(
                    f"compression.compressor: {e}") from None

    # ------------------------------------------------------------------ #
    def get(self):
        """The base Compressor instance."""
        from repro.core import compressors as C
        return C.get(self.compressor)

    @property
    def bucketing(self) -> bool:
        """True when the flat-bucket exchange path is active (Strategy
        construction refuses a plan with spmd='vmap', whose per-tensor
        semantics cannot bucket)."""
        return self.plan != "none"

    def build(self, shapes_tree, param_specs, n_workers: int,
              shard_axes: Tuple[str, ...] = (), axis_sizes=None):
        """(BucketLayout, CommPlan): the planner+compressor pipeline,
        statically derived from leaf shapes (DESIGN.md §3). With
        ``shard_axes`` the layout is shard-aware: leaves sharded only
        over those axes bucket at their local shard shape (DESIGN.md
        §15.1) instead of bypassing buckets."""
        from repro import comm as RC
        layout = RC.build_layout(
            shapes_tree, param_specs, max(n_workers, 1),
            bucket_bytes=int(self.bucket_mb * (1 << 20)),
            shard_axes=shard_axes, axis_sizes=axis_sizes)
        plan = RC.plan_comm(
            layout, self.compressor, self.plan,
            budget_bytes=int(self.budget_mb * (1 << 20)))
        return layout, plan

    def build_family(self, shapes_tree, param_specs, n_workers: int):
        """(BucketLayout, PlanFamily): one delta_budget plan per
        participation count n ∈ {1..n_workers}, each cut against the
        effective budget B·M/n (DESIGN.md §10). Only valid when
        ``adaptive`` is set."""
        if not self.adaptive:
            raise ValueError("build_family needs compression.adaptive")
        from repro import comm as RC
        from repro.comm.planner import plan_family
        layout = RC.build_layout(
            shapes_tree, param_specs, max(n_workers, 1),
            bucket_bytes=int(self.bucket_mb * (1 << 20)))
        fam = plan_family(layout, self.compressor,
                          int(self.budget_mb * (1 << 20)),
                          max(n_workers, 1))
        return layout, fam


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ExchangePlan:
    """HOW the message moves: the collective strategy, the SPMD style
    implementing it, the mesh axes acting as the paper's M workers, and
    whether `delayed(τ)` lowers onto *overlapped* (split-phase)
    collectives — started before the round's field compute, finished at
    consumption (DESIGN.md §13)."""

    kind: str = field(default="sim", metadata=_cli(
        "exchange", "collective strategy", _exchange_kinds))
    spmd: str = field(default="shard_map", metadata=_cli(
        "spmd", "worker SPMD style (DESIGN.md §2)", lambda: SPMD_STYLES))
    worker_axes: Tuple[str, ...] = ("data",)
    overlap: bool = field(default=False, metadata=_cli(
        "overlap", "start delayed(τ) collectives before the round's "
                   "compute (split-phase lowering, DESIGN.md §13)"))
    parallelism: str = field(default="replicated", metadata=_cli(
        "parallelism", "parameter/optimizer-state layout: every worker "
                       "replicates (DDP) or shards ZeRO-style (fsdp, "
                       "DESIGN.md §15)", lambda: PARALLELISM_MODES))
    fsdp_axis: str = field(default="data", metadata=_cli(
        "fsdp_axis", "mesh axis that owns the parameter/moment shards "
                     "under parallelism='fsdp' (must be a worker axis)"))
    zero_stage: int = field(default=3, metadata=_cli(
        "zero_stage", "fsdp sharding stage: 2 shards moments (all-gather "
                      "moves the update), 3 also keeps the authoritative "
                      "params on the shard owner (all-gather moves the "
                      "updated params)"))

    def __post_init__(self):
        if self.kind not in _exchange_kinds():
            raise StrategyError(
                f"exchange.kind: unknown exchange {self.kind!r}; "
                f"have {_exchange_kinds()}")
        if self.spmd not in SPMD_STYLES:
            raise StrategyError(
                f"exchange.spmd: unknown SPMD style {self.spmd!r}; "
                f"have {SPMD_STYLES}")
        axes = self.worker_axes
        if isinstance(axes, list):
            axes = tuple(axes)
            object.__setattr__(self, "worker_axes", axes)
        if not isinstance(axes, tuple) or not all(
                isinstance(a, str) and a for a in axes):
            raise StrategyError(
                f"exchange.worker_axes: need a tuple of mesh-axis names, "
                f"got {self.worker_axes!r}")
        if not isinstance(self.overlap, bool):
            raise StrategyError(
                f"exchange.overlap: must be a bool, got {self.overlap!r}")
        if self.overlap and self.spmd == "vmap":
            raise StrategyError(
                "exchange.overlap: overlap=True needs real per-device "
                "collectives; spmd='vmap' simulates workers on one "
                "device and has nothing to overlap — use "
                "spmd='shard_map'")
        if self.overlap and self.kind == "exact":
            raise StrategyError(
                "exchange.overlap: overlap=True with exchange='exact' "
                "would hide an *uncompressed* pmean, defeating the "
                "measured-overlap comparison the flag exists for — use "
                "kind='sim'/'allgather'/'two_phase'")
        if self.parallelism not in PARALLELISM_MODES:
            raise StrategyError(
                f"exchange.parallelism: unknown mode "
                f"{self.parallelism!r}; have {PARALLELISM_MODES}")
        if not isinstance(self.zero_stage, int) or \
                self.zero_stage not in ZERO_STAGES:
            raise StrategyError(
                f"exchange.zero_stage: must be one of {ZERO_STAGES}, "
                f"got {self.zero_stage!r}")
        if not isinstance(self.fsdp_axis, str) or not self.fsdp_axis:
            raise StrategyError(
                f"exchange.fsdp_axis: need a mesh-axis name, got "
                f"{self.fsdp_axis!r}")
        if self.fsdp:
            if self.spmd == "vmap":
                raise StrategyError(
                    "exchange.parallelism: fsdp shards optimizer state "
                    "across devices; spmd='vmap' simulates every worker "
                    "on one device and has nothing to shard — use "
                    "spmd='shard_map'")
            if self.kind not in ("exact", "two_phase"):
                raise StrategyError(
                    f"exchange.kind: parallelism='fsdp' lowers the "
                    f"gradient exchange onto a (compressed) "
                    f"reduce-scatter, which only 'exact' and 'two_phase' "
                    f"define — got {self.kind!r}")
            if self.worker_axes and self.fsdp_axis not in self.worker_axes:
                raise StrategyError(
                    f"exchange.fsdp_axis: {self.fsdp_axis!r} is not one "
                    f"of the worker axes {self.worker_axes!r}; the shard "
                    f"owners are laid out along the worker axes")

    # ------------------------------------------------------------------ #
    def leaf_plans(self, shapes_tree, specs_tree, n_workers: int):
        """Per-tensor collective plans (core.exchange.plan_leaf over the
        tree)."""
        from repro.core import exchange as X
        return X.plan_for_tree(self.kind, shapes_tree, specs_tree,
                               n_workers)

    def bucket_plan(self, size: int, n_workers: int) -> dict:
        from repro.core import exchange as X
        return X.plan_bucket(self.kind, size, max(n_workers, 1))

    # ---- fsdp surface (DESIGN.md §15) --------------------------------- #
    @property
    def fsdp(self) -> bool:
        """True when params/moments shard across the worker axes (the
        typed replacement for string-matching on ``parallelism``)."""
        return self.parallelism == "fsdp"

    def start_reduce_scatter(self, compressor, p, ef_state: dict, key,
                             n_workers: int, use_ef: bool, widx=None):
        """Issue the (compressed) reduce-scatter of one flat bucket over
        this plan's worker axes; the handle finishes to this worker's
        mean shard (DESIGN.md §15.2)."""
        from repro.core import exchange as X
        return X.start_reduce_scatter(
            compressor, self.kind, p, ef_state, key, self.worker_axes,
            n_workers, use_ef, widx=widx)

    def start_all_gather_shard(self, compressor, shard, ag_ef, key,
                               n_workers: int, use_ef: bool, widx=None):
        """Issue the (compressed) all-gather of one owner shard; the
        handle finishes to (full flat bucket, new owner EF)."""
        from repro.core import exchange as X
        return X.start_all_gather_shard(
            compressor, shard, ag_ef, key, self.worker_axes, n_workers,
            use_ef, widx=widx)

    # ---- split-phase surface (DESIGN.md §13) -------------------------- #
    @property
    def owner_ef(self) -> bool:
        """True when the strategy carries owner-side (e2) error feedback —
        i.e. the EF tree has a second, chunk-sharded residual. The typed
        replacement for string-matching on ``kind == 'two_phase'``."""
        from repro.core import exchange as X
        return X.plan_has_owner_ef({"strategy": self.kind})

    def start(self, compressor, plan: dict, p, ef_state: dict, key,
              n_workers: int, use_ef: bool, widx=None):
        """Issue the wire collectives for one tensor under this plan's
        worker axes; returns a `core.exchange.ExchangeHandle`."""
        from repro.core import exchange as X
        return X.start_exchange(compressor, plan, p, ef_state, key,
                                self.worker_axes, n_workers, use_ef,
                                widx=widx)

    def finish(self, handle):
        """(q̂, new_ef_state) from a handle returned by `start`."""
        from repro.core import exchange as X
        return X.finish_exchange(handle)

    def transport_factor(self, n_workers: int) -> float:
        """Ring-transport multiplier 2·(W−1)/W (core.exchange)."""
        from repro.core import exchange as X
        return X.transport_factor(n_workers)

    def modeled_wire_bytes(self, compressor: str, n_elems: int,
                           n_workers: int) -> int:
        """Analytic per-worker bytes of one exchange of `n_elems` floats."""
        from repro.core import compressors as C
        from repro.core import exchange as X
        return X.modeled_wire_bytes(self.kind, C.get(compressor),
                                    (n_elems,), n_workers)


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MomentCompression:
    """WHAT the fsdp all-gather moves: the compressor applied to the
    optimizer-state exchange — the update shard (zero-2) or the updated
    parameter shard (zero-3) each owner broadcasts after applying Adam on
    its shard. *Quantized Adam with Error Feedback* (arXiv 2004.14180)
    shows this leg tolerates the same δ-approximate compressor + error
    feedback stack as the gradient; the residual lives with the shard
    owner (one flat EF slot per bucket shard). Only consumed under
    ``exchange.parallelism='fsdp'`` — Strategy construction refuses a
    non-default moments slot on a replicated plan."""

    compressor: str = field(default="identity", metadata=_cli(
        "moment_compressor", "compressor for the fsdp optimizer-state / "
        "parameter all-gather (arXiv 2004.14180)", _compressor_names))
    error_feedback: bool = field(default=True, metadata=_cli(
        "moment_ef", "owner-side error feedback on the quantized "
        "all-gather shard"))

    def __post_init__(self):
        if self.compressor not in _compressor_names():
            raise StrategyError(
                f"moments.compressor: unknown compressor "
                f"{self.compressor!r}; have {_compressor_names()}")
        if not isinstance(self.error_feedback, bool):
            raise StrategyError(
                f"moments.error_feedback: must be a bool, got "
                f"{self.error_feedback!r}")

    @property
    def lossless(self) -> bool:
        return self.compressor == "identity"

    def get(self):
        """The core.compressors instance."""
        from repro.core import compressors as C
        return C.get(self.compressor)


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Schedule:
    """WHEN workers talk: exchange cadence (k) × staleness (tau). Use the
    constructors — `Schedule.every_step()`, `Schedule.local_k(K)`,
    `Schedule.delayed(tau)` — rather than spelling kind/k/tau by hand."""

    kind: str = field(default="every_step", metadata=_cli(
        "schedule", "repro.sched exchange schedule", _schedule_kinds))
    k: int = field(default=1, metadata=_cli(
        "local_k", "local_k schedule: exchange every K steps"))
    tau: int = field(default=1, metadata=_cli(
        "staleness_tau", "delayed schedule: bounded-staleness pipeline "
                         "depth τ"))
    # heterogeneous per-worker staleness: worker m applies the message it
    # produced τ_m steps ago (ring depth stays max τ_m = tau). Empty =
    # homogeneous (every worker at τ). No CLI flag — like worker_axes,
    # the launcher/benchmarks set it programmatically (length must match
    # the worker count, validated at DQGAN init).
    tau_vector: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in _schedule_kinds():
            raise StrategyError(
                f"schedule.kind: unknown schedule {self.kind!r}; "
                f"have {_schedule_kinds()}")
        if self.k < 1:
            raise StrategyError(f"schedule.k: must be >= 1, got {self.k}")
        if self.kind != "local_k" and self.k != 1:
            raise StrategyError(
                f"schedule.k: k={self.k} only meaningful with "
                f"kind='local_k', not {self.kind!r}")
        if self.tau < 1:
            raise StrategyError(
                f"schedule.tau: must be >= 1, got {self.tau}")
        if self.kind != "delayed" and self.tau != 1:
            raise StrategyError(
                f"schedule.tau: tau={self.tau} only meaningful with "
                f"kind='delayed', not {self.kind!r}")
        tv = self.tau_vector
        if isinstance(tv, list):
            tv = tuple(tv)
            object.__setattr__(self, "tau_vector", tv)
        if tv:
            if self.kind != "delayed":
                raise StrategyError(
                    f"schedule.tau_vector: per-worker staleness only "
                    f"applies to kind='delayed', not {self.kind!r}")
            if not all(isinstance(t, int) and t >= 1 for t in tv):
                raise StrategyError(
                    f"schedule.tau_vector: entries must be ints >= 1, "
                    f"got {tv!r}")
            if max(tv) != self.tau:
                raise StrategyError(
                    f"schedule.tau_vector: the ring depth is max(τ_m) — "
                    f"tau={self.tau} must equal max(tau_vector)="
                    f"{max(tv)}")

    # ---- constructors ------------------------------------------------- #
    @classmethod
    def every_step(cls) -> "Schedule":
        """Seed semantics: one lockstep exchange per step."""
        return cls("every_step")

    @classmethod
    def local_k(cls, K: int) -> "Schedule":  # noqa: N802 (K is the paper's)
        """Exchange every K steps; the message accumulates in between."""
        return cls("local_k", k=K)

    @classmethod
    def delayed(cls, tau: int = 1,
                tau_vector: Tuple[int, ...] = ()) -> "Schedule":
        """Bounded-staleness exchange overlapping compute: step t applies
        the message produced at step t−τ (DESIGN.md §8). A non-empty
        ``tau_vector`` gives worker m its own τ_m ≤ τ pull cadence over
        the shared depth-τ ring (heterogeneous staleness)."""
        return cls("delayed", tau=tau, tau_vector=tuple(tau_vector))

    @classmethod
    def delayed_hetero(cls, tau_vector) -> "Schedule":
        """Heterogeneous bounded staleness from an explicit per-worker
        τ_m tuple; the ring depth is max(τ_m). For a seeded draw use
        `repro.sched.seeded_tau_vector`."""
        tv = tuple(int(t) for t in tau_vector)
        return cls("delayed", tau=max(tv), tau_vector=tv)

    # ---- host-side arithmetic (delegated to sched.ExchangeSchedule) --- #
    def runtime(self):
        """The repro.sched.ExchangeSchedule engine for this point."""
        from repro import sched as S
        return S.get(self.kind, self.k, self.tau)

    @property
    def period(self) -> int:
        return self.k if self.kind == "local_k" else 1

    @property
    def staleness(self) -> int:
        return self.tau if self.kind == "delayed" else 0

    @property
    def overlappable(self) -> bool:
        """True when the wire message is already known at round start
        (pure carried state — the delayed(τ) pending ring), so
        `exchange.overlap` can issue the collectives before the field
        compute. every_step/local_k messages depend on the round's own
        gradients, so they stay start+immediate-finish."""
        return self.kind == "delayed"

    def describe(self) -> str:
        return self.runtime().describe()

    # ---- in-step dataflow (shared by both SPMD paths of core.dqgan) --- #
    def init_slots(self, params, worker_like, ring_like, versions_like):
        """The DQState.sched buffers for this schedule, or None.

        `worker_like(leaf)` makes a per-worker (W, *shape) f32 slot,
        `ring_like(leaf)` a (W, τ, *shape) ring, `versions_like()` the
        (W,) int32 version vector — the caller owns shape/sharding."""
        if self.kind == "every_step":
            return None
        if self.kind == "local_k":
            return {"accum": jax.tree.map(worker_like, params)}
        pending = jax.tree.map(
            worker_like if self.tau == 1 else ring_like, params)
        return {"pending": pending, "versions": versions_like()}

    # -- heterogeneous-staleness helpers (tau_vector, DESIGN.md §10.4) -- #
    def _tau_of(self, widx):
        """This worker's τ_m: a static int (homogeneous / single worker /
        constant vector) or a traced gather from the jit-static
        tau_vector table. A constant vector stays static so spelling the
        homogeneous schedule as tau_vector=(τ,)*M keeps the compiled
        graph bit-identical to plain delayed(τ)."""
        if not self.tau_vector:
            return self.tau
        if len(set(self.tau_vector)) == 1 or widx is None:
            # widx None: single worker (validated len == 1)
            return self.tau_vector[0]
        return jnp.asarray(self.tau_vector, jnp.int32)[widx]

    def _pull_pos(self, widx):
        """Ring slot this worker exchanges: slot p holds the message
        produced (τ − p) steps ago, so worker m pulls p_m = τ − τ_m.
        Messages keep shifting toward slot 0 after their exchange and
        fall off the end — each passes slot p_m exactly once."""
        return self.tau - self._tau_of(widx)

    def wire_head(self, sched_state, widx=None):
        """(pending_buf, head): the raw delayed-schedule ring buffer and
        the message on the wire THIS step — its oldest slot, or worker
        m's pull slot τ−τ_m under a tau_vector — or (None, None) for the
        other schedules."""
        if self.kind != "delayed":
            return None, None
        buf = sched_state["pending"]
        if self.tau == 1:
            return buf, buf
        if not self.tau_vector:
            return buf, jax.tree.map(lambda r: r[0], buf)
        p = self._pull_pos(widx)
        if isinstance(p, int):
            return buf, jax.tree.map(lambda r: r[p], buf)
        return buf, jax.tree.map(
            lambda r: jax.lax.dynamic_index_in_dim(r, p, axis=0,
                                                   keepdims=False), buf)

    def staleness_correction(self, pending_buf, message: str, lr: float,
                             widx=None):
        """The delayed worker's in-flight messages in update units — the
        staleness-correction proxy added to the OMD lookahead. For τ>1
        this sums the not-yet-applied slots: all of them (the τ-step
        recursion of DESIGN.md §8), or the τ_m slots from this worker's
        pull position on under a tau_vector."""
        if pending_buf is None:
            return None
        if self.tau > 1:
            p = self._pull_pos(widx) if self.tau_vector else 0
            if isinstance(p, int):
                # static pull position (homogeneous / constant vector):
                # r[0:] folds away, keeping the plain-delayed graph
                tot = jax.tree.map(lambda r: r[p:].sum(axis=0),
                                   pending_buf)
            else:
                w = (jnp.arange(self.tau) >= p).astype(jnp.float32)
                tot = jax.tree.map(
                    lambda r: jnp.tensordot(w, r.astype(jnp.float32),
                                            axes=1).astype(r.dtype),
                    pending_buf)
        else:
            tot = pending_buf
        if message == "update":
            return tot
        return jax.tree.map(lambda p: lr * p, tot)

    def shift(self, pending_buf, new_message):
        """Next pending buffer: overwrite the single slot (τ=1, PR 2's
        compiled graph kept bit-identical) or shift the ring and append
        (τ>1)."""
        if self.tau == 1:
            return jax.tree.map(lambda p, m: m.astype(p.dtype),
                                pending_buf, new_message)
        return jax.tree.map(
            lambda r, m: jnp.concatenate(
                [r[1:], m[None].astype(r.dtype)], axis=0),
            pending_buf, new_message)

    def advance_version(self, old_version, step, mask=None, widx=None):
        """Push/pull version after an exchange: a participating worker's
        applied message was produced τ (or τ_m) steps ago; a worker
        sitting the round out (mask 0) keeps its old version — its
        staleness keeps growing while the folded message rides the EF
        residual."""
        tau_m = self._tau_of(widx)
        v_new = (step - tau_m).astype(jnp.int32)
        if mask is None:
            return v_new
        return jnp.where(mask > 0, v_new, old_version)

    def fold(self, sched_state, message, head, do_exchange, step, mask,
             zeros: Callable[[Any], Any], widx=None):
        """One step of schedule dataflow: (exchange_message | None,
        new_sched_state | None). `message` is this step's fresh message,
        `head` the delayed ring head from `wire_head`, `zeros(tree)` the
        caller's zero-like."""
        if self.kind == "every_step":
            return message, None
        if self.kind == "local_k":
            if self.k == 1 and do_exchange:
                # length-1 rounds: the accumulator is identically zero at
                # every exchange; skipping the add keeps the compiled
                # graph (hence XLA's FMA contraction) bit-identical to
                # every_step.
                return message, {"accum": zeros(sched_state["accum"])}
            accum = jax.tree.map(lambda a, m: (a + m).astype(a.dtype),
                                 sched_state["accum"], message)
            if do_exchange:
                return accum, {"accum": zeros(accum)}
            return None, {"accum": accum}  # mid-round: nothing on the wire
        # delayed: exchange the step-(t−τ) message (ring head)
        return head, {
            "pending": self.shift(sched_state["pending"], message),
            "versions": self.advance_version(
                sched_state["versions"], step, mask, widx),
        }

    def staleness_now(self, step, new_sched):
        """Per-worker staleness (step − version) after this step's
        exchange, or scalar 0 for staleness-free schedules."""
        if self.kind != "delayed":
            return jnp.zeros(())
        return (step - new_sched["versions"]).astype(jnp.float32)


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Participation:
    """WHO talks each round: the sampled worker fraction, plus the
    heterogeneity profile consumed by the host-side wall-clock model
    (never by the jitted step)."""

    fraction: float = field(default=1.0, metadata=_cli(
        "participation", "fraction of workers sampled per exchange round"))
    straggler_profile: str = field(default="none", metadata=_cli(
        "straggler_profile", "heterogeneity profile for the wall-clock "
                             "model", _straggler_profiles))

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise StrategyError(
                f"participation.fraction: must be in (0, 1], got "
                f"{self.fraction}")
        if self.straggler_profile not in _straggler_profiles():
            raise StrategyError(
                f"participation.straggler_profile: unknown profile "
                f"{self.straggler_profile!r}; have {_straggler_profiles()}")

    # ------------------------------------------------------------------ #
    @property
    def partial(self) -> bool:
        return self.fraction < 1.0

    def profile(self):
        from repro.sched import straggler as strag
        return strag.get_profile(self.straggler_profile)

    def round_setup(self, key, step, n_workers: int, period: int):
        """(mask_vec (W,), n_participants) for this round, or None for
        full participation / a single worker. Must be called with the
        shared key (before the per-worker fold_in) so every worker draws
        the same round permutation."""
        if not self.partial or n_workers <= 1:
            return None
        from repro.sched import participation as SP
        n_part = SP.n_participants(self.fraction, n_workers)
        if n_part >= n_workers:
            return None
        return SP.round_mask(key, step // period, n_workers, n_part), n_part


# --------------------------------------------------------------------------- #
METRIC_LEVELS = ("off", "wire", "full")


def _metric_levels():
    return METRIC_LEVELS


@dataclass(frozen=True)
class Observability:
    """WHAT we measure while training: the jit-static telemetry level
    consumed by `repro.obs` (DESIGN.md §11).

    Levels form a lattice: ``off`` ⊂ ``wire`` (empirical δ + EF residual
    norms, read off the already-materialized compressed messages) ⊂
    ``full`` (adds per-bucket gradient moments and the staleness
    histogram). ``off`` is contractually bit-identical to a build without
    the obs subsystem — enforced by HLO comparison in tests — which is
    why observability is excluded from `Strategy.short_hash()`: it can
    never change the trajectory, so it is not structural identity."""

    metrics: str = field(default="off", metadata=_cli(
        "obs_metrics", "on-device telemetry level (repro.obs)",
        _metric_levels))
    spans: bool = field(default=False, metadata=_cli(
        "obs_spans", "named phase spans (compress/exchange/apply/eval) "
                     "for the jax profiler"))
    # Host-side step profiler (repro.obs.profile, DESIGN.md §12.1):
    # block_until_ready-bracketed step walls over a --profile-steps
    # window, per-phase attribution keyed off the repro.obs/ span names.
    # Purely host-side, so it cannot perturb the compiled step — and like
    # metrics/spans it is excluded from short_hash() (structural identity
    # never includes observability).
    profile: bool = field(default=False, metadata=_cli(
        "obs_profile", "step profiler: emit `profile` events over the "
                       "--profile-steps window (repro.obs.profile)"))

    def __post_init__(self):
        if self.metrics not in METRIC_LEVELS:
            raise StrategyError(
                f"observability.metrics: unknown level "
                f"{self.metrics!r}; have {METRIC_LEVELS}")
        for name in ("spans", "profile"):
            if not isinstance(getattr(self, name), bool):
                raise StrategyError(
                    f"observability.{name}: expected a bool, got "
                    f"{getattr(self, name)!r}")

    # ------------------------------------------------------------------ #
    @property
    def on(self) -> bool:
        return self.metrics != "off"

    def spec(self):
        """The resolved `repro.obs.MetricSpec` for this level."""
        from repro.obs import METRIC_SPECS
        return METRIC_SPECS[self.metrics]
