"""Named strategy presets — the registry behind ``--preset`` and the
preset-instantiation CI smoke job.

Each preset is a full `Strategy` (constructed, hence validated, at import
time) covering one regime the repo's experiments exercise. Presets are
mesh-agnostic: `worker_axes` stays at the default ``("data",)`` and is
overridden by the launcher from the actual mesh.
"""
from __future__ import annotations

from typing import Dict

from .components import (
    Compression,
    ExchangePlan,
    MomentCompression,
    Participation,
    Schedule,
    StrategyError,
)
from .strategy import Strategy

PRESETS: Dict[str, Strategy] = {
    # The paper's Algorithm 2: 8-bit stochastic quantization + error
    # feedback, lockstep exchange every step.
    "paper_dqgan": Strategy(),
    # Full-precision exact averaging (the CPOAdam baseline's wire).
    "exact_baseline": Strategy(
        compression=Compression(compressor="identity",
                                error_feedback=False),
        exchange=ExchangePlan(kind="exact")),
    # Quantized-but-no-EF ablation (CPOAdam-GQ).
    "quantized_no_ef": Strategy(
        compression=Compression(error_feedback=False)),
    # Constrained uplink: two-phase int8 collectives over size-tiered
    # buckets, exchanging only every 4th step.
    "low_bandwidth": Strategy(
        compression=Compression(plan="size_tiered"),
        exchange=ExchangePlan(kind="two_phase"),
        schedule=Schedule.local_k(4)),
    # Hard byte budget: greedy per-bucket bit-width descent to 1 MiB/step.
    "byte_budget": Strategy(
        compression=Compression(plan="delta_budget", budget_mb=1.0),
        exchange=ExchangePlan(kind="two_phase")),
    # Round-adaptive byte budget (DESIGN.md §10): a PlanFamily re-runs
    # the descent per participation count, so when only half the workers
    # report each round their effective budget doubles and the reporting
    # workers quantize finer — same fleet-average bytes as byte_budget.
    "adaptive_budget": Strategy(
        compression=Compression(plan="delta_budget", budget_mb=1.0,
                                adaptive=True),
        exchange=ExchangePlan(kind="two_phase"),
        participation=Participation(fraction=0.5)),
    # One-step-stale exchange overlapping compute (PR 2's delayed),
    # lowered split-phase: the round's collective starts before the
    # field evaluation and finishes at the τ-stale consume (DESIGN.md
    # §13), so XLA's async scheduler can hide the wire time.
    "overlap": Strategy(
        exchange=ExchangePlan(overlap=True),
        schedule=Schedule.delayed(1)),
    # Bounded-staleness parameter server: τ=4 push/pull pipeline under a
    # mild straggler profile (DESIGN.md §8), split-phase overlapped.
    "ssp_server": Strategy(
        exchange=ExchangePlan(kind="two_phase", overlap=True),
        schedule=Schedule.delayed(4),
        participation=Participation(straggler_profile="mild")),
    # Half the workers report per round; the rest fold into EF.
    "partial_participation": Strategy(
        participation=Participation(fraction=0.5)),
    # 100B-scale FSDP layout: workers as a vmapped axis (DESIGN.md §2).
    "fsdp_vmap": Strategy(
        exchange=ExchangePlan(kind="sim", spmd="vmap",
                              worker_axes=("pod",))),
    # ZeRO-2: Adam moments shard across the workers; gradients ride a
    # compressed reduce-scatter, the *update* shard rides a quantized
    # all-gather with owner-side EF (arXiv 2004.14180; DESIGN.md §15).
    "fsdp_zero2": Strategy(
        compression=Compression(plan="uniform"),
        exchange=ExchangePlan(kind="two_phase", parallelism="fsdp",
                              zero_stage=2),
        moments=MomentCompression(compressor="qsgd8_linf")),
    # ZeRO-3: the shard owner also keeps the authoritative params; the
    # all-gather moves the *updated parameter* shard instead.
    "fsdp_zero3": Strategy(
        compression=Compression(plan="uniform"),
        exchange=ExchangePlan(kind="two_phase", parallelism="fsdp",
                              zero_stage=3),
        moments=MomentCompression(compressor="qsgd8_linf")),
}


# one-line docs, rendered by `python -m repro.strategy --list-presets`
PRESET_DOCS: Dict[str, str] = {
    "paper_dqgan": "the paper's Algorithm 2: qsgd8 + EF, lockstep",
    "exact_baseline": "full-precision exact averaging (CPOAdam wire)",
    "quantized_no_ef": "quantized but no error feedback (CPOAdam-GQ)",
    "low_bandwidth": "two_phase int8 over size-tiered buckets, local_k=4",
    "byte_budget": "static per-bucket bit-width descent to 1 MiB/step",
    "adaptive_budget": "round-adaptive PlanFamily: absent workers' byte "
                       "budget re-spent on finer bits (participation 0.5)",
    "overlap": "one-step-stale split-phase exchange overlapping compute",
    "ssp_server": "bounded-staleness τ=4 server under mild stragglers, "
                  "split-phase overlapped",
    "partial_participation": "half the workers report per round",
    "fsdp_vmap": "100B-scale FSDP layout, workers as a vmapped axis",
    "fsdp_zero2": "ZeRO-2: sharded moments, compressed reduce-scatter + "
                  "quantized update all-gather (2004.14180)",
    "fsdp_zero3": "ZeRO-3: sharded moments + params, quantized updated-"
                  "param all-gather with owner EF",
}


def get_preset(name: str) -> Strategy:
    try:
        return PRESETS[name]
    except KeyError:
        raise StrategyError(
            f"strategy: unknown preset {name!r}; have "
            f"{sorted(PRESETS)}") from None


def register_preset(name: str, strategy: Strategy, doc: str = "") -> None:
    """Add a preset (experiment configs may register their own)."""
    if not isinstance(strategy, Strategy):
        raise StrategyError(
            f"strategy: preset {name!r} must be a Strategy, got "
            f"{type(strategy).__name__}")
    PRESETS[name] = strategy
    if doc:
        PRESET_DOCS[name] = doc
