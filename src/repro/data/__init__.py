from .synthetic import (  # noqa: F401
    gaussian_mixture_sampler,
    lm_batch_iterator,
    procedural_images,
    synthetic_lm_batch,
)
