"""Synthetic data pipelines (no external datasets are available offline).

* LM token streams: a deterministic Zipf-distributed Markov-ish stream so
  the loss is learnable (next token correlates with the current one).
* 2-D Gaussian mixtures: the classic GAN mode-coverage benchmark.
* Procedural images: CIFAR-shaped structured images (colored oriented
  blobs) giving the DCGAN a non-trivial distribution; stands in for
  CIFAR10/CelebA (DESIGN.md §7).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# LM tokens
# --------------------------------------------------------------------------- #
def synthetic_lm_batch(key, batch, seq, vocab):
    """Correlated token stream: t_{i+1} = (a * t_i + noise) mod vocab with a
    few preferred successor offsets — learnable by a small LM."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    offsets = jnp.array([1, 7, 13, 29])
    chose = jax.random.randint(k2, (batch, seq), 0, len(offsets))
    steps = offsets[chose]
    toks = (start + jnp.cumsum(steps, axis=1)) % vocab
    tokens = jnp.concatenate([start, toks[:, :-1]], axis=1)
    targets = toks
    return {"tokens": tokens.astype(jnp.int32),
            "targets": targets.astype(jnp.int32)}


def lm_batch_iterator(seed, batch, seq, vocab, enc_shape=None):
    key = jax.random.key(seed)
    i = 0
    while True:
        k = jax.random.fold_in(key, i)
        b = synthetic_lm_batch(k, batch, seq, vocab)
        if enc_shape is not None:
            b["enc_embeds"] = 0.1 * jax.random.normal(
                jax.random.fold_in(k, 1), (batch,) + enc_shape
            )
        yield b
        i += 1


# --------------------------------------------------------------------------- #
# 2-D Gaussian mixture (GAN synthetic benchmark)
# --------------------------------------------------------------------------- #
def gaussian_mixture_sampler(n_modes=8, radius=2.0, std=0.05):
    angles = np.linspace(0, 2 * math.pi, n_modes, endpoint=False)
    centers = jnp.array(
        np.stack([radius * np.cos(angles), radius * np.sin(angles)], -1),
        jnp.float32,
    )

    def sample(key, n):
        k1, k2 = jax.random.split(key)
        idx = jax.random.randint(k1, (n,), 0, n_modes)
        noise = std * jax.random.normal(k2, (n, 2))
        return centers[idx] + noise

    return sample, centers


# --------------------------------------------------------------------------- #
# procedural images (CIFAR stand-in)
# --------------------------------------------------------------------------- #
def procedural_images(key, n, size=32, channels=3):
    """Images of a randomly-placed, randomly-oriented Gaussian blob with a
    color gradient — structured enough that a GAN must learn position,
    orientation and color jointly. Values in [-1, 1]."""
    ks = jax.random.split(key, 5)
    cx = jax.random.uniform(ks[0], (n, 1, 1, 1), minval=0.25, maxval=0.75)
    cy = jax.random.uniform(ks[1], (n, 1, 1, 1), minval=0.25, maxval=0.75)
    sig = jax.random.uniform(ks[2], (n, 1, 1, 1), minval=0.05, maxval=0.15)
    hue = jax.random.uniform(ks[3], (n, 1, 1, channels))
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, size), jnp.linspace(0, 1, size),
                          indexing="ij")
    grid_x = xx[None, :, :, None]
    grid_y = yy[None, :, :, None]
    blob = jnp.exp(-((grid_x - cx) ** 2 + (grid_y - cy) ** 2) / (2 * sig**2))
    phase = 2 * math.pi * (hue + jnp.arange(channels) / channels)
    color = 0.5 + 0.5 * jnp.sin(phase)
    img = blob * color + 0.1 * (grid_x + grid_y) - 0.5
    return jnp.clip(2 * img, -1, 1)
