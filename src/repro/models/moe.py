"""Mixture-of-experts FFN: top-k routing with capacity-based one-hot-cumsum
dispatch (Mesh-TensorFlow style — fully auto-shardable: experts over the
'model' axis, capacity slots over 'data'), load-balance + router-z losses,
and Arctic's dense-residual variant (a small dense FFN added in parallel).

Production note (DESIGN.md): a shard_map ragged all-to-all dispatch would
cut dispatch memory further; the einsum form is chosen because it composes
with the auto-sharded model axis and lowers cleanly for every mesh.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _constrain_batch_only as _constrain
from .layers import linear, linear_init, mlp, mlp_init


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(m.d_ff_expert)

    def experts(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": linear_init(ks[0], d, m.num_experts, False, jnp.float32),
        "gate_proj": experts(ks[1], (m.num_experts, d, m.d_ff_expert), scale_in),
        "up_proj": experts(ks[2], (m.num_experts, d, m.d_ff_expert), scale_in),
        "down_proj": experts(ks[3], (m.num_experts, m.d_ff_expert, d), scale_out),
    }
    if m.has_dense_residual:
        p["dense"] = mlp_init(ks[4], d, m.dense_residual_d_ff, cfg.activation,
                              cfg.use_bias, dtype)
    return p


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (y, aux_losses dict)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S

    xt = x.reshape(T, d)
    logits = linear(p["router"], xt.astype(jnp.float32))        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)       # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # ---- aux losses ------------------------------------------------------ #
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, m.num_experts), axis=1), axis=0
    )                                                           # frac routed
    aux = {
        "moe_load_balance": m.router_aux_coef * m.num_experts
        * jnp.sum(me * ce),
        "moe_router_z": m.router_z_coef
        * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }

    if m.dispatch == "per_row":
        # ranks + capacity per batch row: everything left of the expert
        # einsum is local to a 'data' shard (no cross-device cumsum), and
        # the stacked dispatch buffers are pinned batch-sharded so the
        # scatter never forces replication (§Perf hillclimb 1).
        cap = int(max(m.top_k, math.ceil(m.top_k * S / m.num_experts
                                         * m.capacity_factor)))
        ei = expert_idx.reshape(B, S, m.top_k)
        gv = gate_vals.reshape(B, S, m.top_k)
        y = jax.vmap(
            lambda xr, er, gr: _dispatch_combine(p, cfg, xr, er, gr, cap)
        )(x, ei, gv)
        y = _constrain(y.reshape(B, S, d), B)
    else:
        cap = int(max(m.top_k, math.ceil(m.top_k * S / m.num_experts
                                         * m.capacity_factor)) * B)
        y = _dispatch_combine(p, cfg, xt, expert_idx, gate_vals,
                              cap).reshape(B, S, d)

    if "dense" in p:  # Arctic: dense FFN residual in parallel with MoE
        y = y + mlp(p["dense"], xt, cfg.activation).reshape(B, S, d)
    return y, aux


def _dispatch_combine(p, cfg, xt, expert_idx, gate_vals, cap):
    """One-hot-cumsum capacity dispatch + batched expert FFN + combine.
    xt: (T, d); expert_idx/gate_vals: (T, k)."""
    m = cfg.moe
    T, d = xt.shape
    k = m.top_k
    E = m.num_experts

    flat_e = expert_idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot                 # rank within expert
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = rank < cap
    rank_c = jnp.where(keep, rank, 0)

    x_rep = jnp.repeat(xt, k, axis=0)                           # (T*k, d)
    buf = jnp.zeros((E, cap, d), xt.dtype)
    buf = buf.at[flat_e, rank_c].add(
        jnp.where(keep[:, None], x_rep, 0), mode="drop"
    )

    # ---- expert FFN (batched over experts) -------------------------------- #
    act = jax.nn.silu if cfg.activation in ("silu",) else (
        lambda v: jax.nn.gelu(v, approximate=True)
    )
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["gate_proj"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["up_proj"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down_proj"])     # (E,cap,d)

    # ---- combine ----------------------------------------------------------- #
    tok_out = out_buf[flat_e, rank_c]                           # (T*k, d)
    tok_out = jnp.where(keep[:, None], tok_out, 0)
    w = gate_vals.reshape(T * k)[:, None].astype(tok_out.dtype)
    return jnp.sum((tok_out * w).reshape(T, k, d), axis=1)
