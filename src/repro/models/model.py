"""The unified decoder LM (+ optional encoder for enc-dec) used by all ten
assigned architectures: a cycled pattern of blocks (attention / RG-LRU /
SSD mixers, dense / MoE FFNs), scan-over-layers with remat, chunked
cross-entropy, KV/SSM caches with O(1) decode.

Public entry points (see registry.py):
    init(key, cfg, max_seq)                    -> params
    forward(params, cfg, tokens|embeds, ...)   -> hidden states
    loss_fn(params, cfg, batch, rng)           -> (loss, metrics)
    prefill(params, cfg, tokens, cache)        -> (logits_last, cache)
    decode_step(params, cfg, tokens, cache)    -> (logits, cache)
    init_cache(cfg, batch, seq, dtype)         -> cache pytree
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import moe as moe_lib
from .layers import (
    apply_norm,
    attn_apply,
    attn_cache_init,
    attn_init,
    embed_init,
    linear,
    mlp,
    mlp_init,
    norm_init,
)
from .mixers import (
    rglru_apply,
    rglru_init,
    rglru_state_init,
    ssd_apply,
    ssd_init,
    ssd_state_init,
)


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def pattern_kinds(cfg) -> list:
    return [cfg.layer_pattern[i % len(cfg.layer_pattern)]
            for i in range(cfg.num_layers)]


# ------------------------------------------------------------------------- #
# single block
# ------------------------------------------------------------------------- #
def block_init(key, cfg: ModelConfig, kind: str, cross: bool = False):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"norm1": norm_init(cfg.norm, d, dt)}
    if kind == "attn":
        p["attn"] = attn_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                              cfg.head_dim, cfg.use_bias, dt)
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks[0], cfg, dt)
    elif kind == "ssd":
        p["ssd"] = ssd_init(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = norm_init(cfg.norm, d, dt)
        p["cross"] = attn_init(ks[1], d, cfg.num_heads, cfg.num_kv_heads,
                               cfg.head_dim, cfg.use_bias, dt)
    if cfg.d_ff > 0 or cfg.moe is not None:
        p["norm2"] = norm_init(cfg.norm, d, dt)
        if cfg.moe is not None:
            p["moe"] = moe_lib.moe_init(ks[2], cfg, dt)
        else:
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.activation,
                                cfg.use_bias, dt)
    return p


def block_apply(p, cfg: ModelConfig, kind, x, positions, cache=None,
                cross_kv=None, causal=True, fill_cache=False):
    """Returns (x, new_cache, aux_losses). cross_kv is the raw encoder
    output; per-layer K/V projections are applied here."""
    aux = {}
    h = apply_norm(cfg.norm, p["norm1"], x)
    new_cache = None
    if kind == "attn":
        out, new_cache = attn_apply(
            p["attn"], h, cfg, positions,
            cache=None if cache is None else cache,
            causal=causal, fill_cache=fill_cache,
        )
    elif kind == "rglru":
        out, new_cache = rglru_apply(p["rglru"], cfg, h, state=cache,
                                     return_state=fill_cache)
    elif kind == "ssd":
        out, new_cache = ssd_apply(p["ssd"], cfg, h, state=cache,
                                   return_state=fill_cache)
    x = x + out
    if "cross" in p and cross_kv is not None:
        h = apply_norm(cfg.norm, p["cross_norm"], x)
        enc = cross_kv
        B, Se = enc.shape[:2]
        ck = (enc @ p["cross"]["k"]["w"]).reshape(
            B, Se, cfg.num_kv_heads, cfg.head_dim)
        cv = (enc @ p["cross"]["v"]["w"]).reshape(
            B, Se, cfg.num_kv_heads, cfg.head_dim)
        if "b" in p["cross"]["k"]:
            ck = ck + p["cross"]["k"]["b"].reshape(cfg.num_kv_heads,
                                                   cfg.head_dim)
            cv = cv + p["cross"]["v"]["b"].reshape(cfg.num_kv_heads,
                                                   cfg.head_dim)
        out, _ = attn_apply(p["cross"], h, cfg, positions, cross_kv=(ck, cv))
        x = x + out
    if "mlp" in p:
        x = x + mlp(p["mlp"], apply_norm(cfg.norm, p["norm2"], x),
                    cfg.activation)
    elif "moe" in p:
        y, aux = moe_lib.moe_apply(p["moe"], cfg,
                                   apply_norm(cfg.norm, p["norm2"], x))
        x = x + y
    return x, new_cache, aux


def block_cache_init(cfg, kind, batch, seq, dtype):
    if kind == "attn":
        return attn_cache_init(cfg, batch, seq, dtype)
    if kind == "rglru":
        return rglru_state_init(cfg, batch, dtype)
    if kind == "ssd":
        return ssd_state_init(cfg, batch, dtype)
    raise ValueError(kind)


# ------------------------------------------------------------------------- #
# parameter tree
# ------------------------------------------------------------------------- #
def init(key, cfg: ModelConfig, max_seq: int = 0):
    """Full parameter pytree. Layer stacks are leading-axis-stacked for
    lax.scan: params['scan'][name] has shape (n_periods, ...)."""
    dt = _dtype(cfg)
    kinds = pattern_kinds(cfg)
    period = len(cfg.layer_pattern)
    n_scan = cfg.num_layers // period if cfg.scan_layers else 0
    tail_kinds = kinds[n_scan * period:]
    cross = cfg.is_encdec

    keys = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = {
            "w": embed_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
        }
    p["final_norm"] = norm_init(cfg.norm, cfg.d_model, dt)

    if n_scan:
        def one_period(k):
            ks = jax.random.split(k, period)
            return {f"b{i}": block_init(ks[i], cfg, cfg.layer_pattern[i], cross)
                    for i in range(period)}
        p["scan"] = jax.vmap(one_period)(jax.random.split(keys[2], n_scan))
    if tail_kinds:
        ks = jax.random.split(keys[3], len(tail_kinds))
        p["tail"] = [block_init(ks[i], cfg, kind, cross)
                     for i, kind in enumerate(tail_kinds)]

    if cfg.is_encdec:
        e = cfg.encdec
        ks = jax.random.split(keys[4], e.enc_layers + 2)
        p["enc"] = {
            "blocks": [block_init(ks[i], cfg, "attn") for i in range(e.enc_layers)],
            "final_norm": norm_init(cfg.norm, cfg.d_model, dt),
            "pos": (jax.random.normal(ks[-1], (e.enc_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt),
        }
    if cfg.positional == "learned":
        assert max_seq > 0, "absolute-position model needs max_seq"
        p["pos"] = (jax.random.normal(keys[5], (max_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt)
    return p


# ------------------------------------------------------------------------- #
# forward over the block stack
# ------------------------------------------------------------------------- #
def _stack_apply(params, cfg, x, positions, caches=None, cross_kv=None,
                 fill_cache=False):
    """Run all layers. Three modes:
      train   : caches=None, fill_cache=False  (remat'd scan, aux carried)
      prefill : caches=None, fill_cache=True   (caches emitted as scan ys)
      decode  : caches=dict                     (caches threaded as xs/ys)
    Returns (x, new_caches, aux)."""
    period = len(cfg.layer_pattern)
    n_scan = cfg.num_layers // period if cfg.scan_layers else 0
    aux_total = {}

    def add_aux(aux):
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v

    def period_fn(x, layer_p, layer_cache):
        lc_out = {}
        aux_p = {}
        for i in range(period):
            kind = cfg.layer_pattern[i]
            c = None if layer_cache is None else layer_cache[f"b{i}"]
            x, nc, aux = block_apply(layer_p[f"b{i}"], cfg, kind, x,
                                     positions, cache=c, cross_kv=cross_kv,
                                     fill_cache=fill_cache)
            lc_out[f"b{i}"] = nc
            for k, v in aux.items():
                aux_p[k] = aux_p.get(k, 0.0) + v
        return x, lc_out, aux_p

    new_caches = {"scan": None, "tail": []}
    if n_scan:
        if caches is None and not fill_cache:          # --- train --------- #
            def body(carry, layer_p):
                x, aux_c = carry
                x, _, aux = period_fn(x, layer_p, None)
                aux_c = {k: aux_c.get(k, 0.0) + aux.get(k, 0.0)
                         for k in set(aux_c) | set(aux)}
                return (x, aux_c), None

            aux0 = ({"moe_load_balance": jnp.zeros(()),
                     "moe_router_z": jnp.zeros(())}
                    if cfg.moe is not None else {})
            if cfg.remat != "none":
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_c), _ = jax.lax.scan(body, (x, aux0), params["scan"])
            add_aux(aux_c)
        elif caches is None and fill_cache:            # --- prefill ------- #
            def body(x, layer_p):
                x, lc, _ = period_fn(x, layer_p, None)
                return x, lc

            x, scan_caches = jax.lax.scan(body, x, params["scan"])
            new_caches["scan"] = scan_caches
        else:                                          # --- decode -------- #
            def body(x, inp):
                layer_p, layer_cache = inp
                x, lc, _ = period_fn(x, layer_p, layer_cache)
                return x, lc

            x, scan_caches = jax.lax.scan(
                body, x, (params["scan"], caches["scan"])
            )
            new_caches["scan"] = scan_caches

    for i, bp in enumerate(params.get("tail", [])):
        kind = cfg.layer_pattern[(n_scan * period + i) % period]
        c = None if caches is None else caches["tail"][i]
        x, nc, aux = block_apply(bp, cfg, kind, x, positions, cache=c,
                                 cross_kv=cross_kv, fill_cache=fill_cache)
        add_aux(aux)
        new_caches["tail"].append(nc)

    if caches is None and not fill_cache:
        new_caches = None
    return x, new_caches, aux_total


def encode(params, cfg, enc_embeds):
    """Whisper-style encoder over precomputed (stub-frontend) embeddings."""
    e = params["enc"]
    x = enc_embeds.astype(_dtype(cfg)) + e["pos"][None, : enc_embeds.shape[1]]
    pos = jnp.arange(x.shape[1])
    for bp in e["blocks"]:
        x, _, _ = block_apply(bp, cfg, "attn", x, pos, causal=False)
    return apply_norm(cfg.norm, e["final_norm"], x)


def _embed_tokens(params, cfg, tokens, positions):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * math.sqrt(cfg.d_model)
    if cfg.positional == "learned" and "pos" in params:
        x = x + jnp.take(params["pos"], jnp.broadcast_to(positions, tokens.shape),
                         axis=0)
    return x.astype(_dtype(cfg))


def _cross_kvs(params, cfg, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    def kv(attn_p):
        k = enc_out @ attn_p["k"]["w"]
        v = enc_out @ attn_p["v"]["w"]
        if "b" in attn_p["k"]:
            k = k + attn_p["k"]["b"]
            v = v + attn_p["v"]["b"]
        B, S = enc_out.shape[:2]
        return (k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim),
                v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim))
    return kv


def forward(params, cfg, tokens, positions, caches=None, enc_out=None,
            fill_cache=False):
    """tokens: (B,S) int32. Returns (hidden, new_caches, aux)."""
    x = _embed_tokens(params, cfg, tokens, positions)
    x, new_caches, aux = _stack_apply(params, cfg, x, positions, caches,
                                      cross_kv=enc_out, fill_cache=fill_cache)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x, new_caches, aux


def logits_fn(params, cfg, hidden):
    if cfg.tie_embeddings:
        return hidden @ params["embed"].T
    return linear(params["unembed"], hidden)


# ------------------------------------------------------------------------- #
# losses
# ------------------------------------------------------------------------- #
def _xent(logits, targets):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - ll


def chunked_xent(params, cfg, hidden, targets, chunk):
    """Cross entropy without materializing (B,S,V): scan over S chunks,
    rematerializing logits in the backward pass."""
    B, S, _ = hidden.shape
    n = S // chunk
    assert S % chunk == 0

    @jax.checkpoint
    def body(tot, idx):
        h = jax.lax.dynamic_slice_in_dim(hidden, idx * chunk, chunk, axis=1)
        t = jax.lax.dynamic_slice_in_dim(targets, idx * chunk, chunk, axis=1)
        return tot + jnp.sum(_xent(logits_fn(params, cfg, h), t)), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n))
    return tot / (B * S)


def loss_fn(params, cfg: ModelConfig, batch, rng=None):
    """batch: {"tokens": (B,S), "targets": (B,S)[, "enc_embeds": (B,Se,d)]}"""
    del rng
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"])
    hidden, _, aux = forward(params, cfg, tokens, positions, enc_out=enc_out)
    if cfg.xent_chunk and tokens.shape[1] % cfg.xent_chunk == 0:
        loss = chunked_xent(params, cfg, hidden, batch["targets"],
                            cfg.xent_chunk)
    else:
        loss = jnp.mean(_xent(logits_fn(params, cfg, hidden), batch["targets"]))
    metrics = {"loss": loss, **{k: v for k, v in aux.items()}}
    total = loss + sum(aux.values()) if aux else loss
    return total, metrics


# ------------------------------------------------------------------------- #
# serving
# ------------------------------------------------------------------------- #
def init_cache(cfg, batch, seq, dtype=None):
    dtype = dtype or _dtype(cfg)
    kinds = pattern_kinds(cfg)
    period = len(cfg.layer_pattern)
    n_scan = cfg.num_layers // period if cfg.scan_layers else 0

    def one(kind):
        return block_cache_init(cfg, kind, batch, seq, dtype)

    caches = {"scan": None, "tail": []}
    if n_scan:
        period_cache = {f"b{i}": one(cfg.layer_pattern[i]) for i in range(period)}
        caches["scan"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            period_cache,
        )
    for kind in kinds[n_scan * period:]:
        caches["tail"].append(one(kind))
    if cfg.is_encdec:
        caches["enc_out"] = jnp.zeros(
            (batch, cfg.encdec.enc_seq, cfg.d_model), dtype
        )
    return caches


def decode_step(params, cfg, tokens, caches):
    """tokens: (B,1). Uses and updates caches; returns (logits (B,V), caches)."""
    t = _cache_pos(caches, cfg)
    positions = t + jnp.zeros((1,), jnp.int32)
    enc_out = caches.get("enc_out") if cfg.is_encdec else None
    model_caches = {k: v for k, v in caches.items() if k != "enc_out"}
    hidden, new_caches, _ = forward(params, cfg, tokens, positions,
                                    caches=model_caches, enc_out=enc_out)
    if cfg.is_encdec:
        new_caches["enc_out"] = caches["enc_out"]
    logits = logits_fn(params, cfg, hidden[:, -1])
    return logits, new_caches


def decode_step_paged(params, cfg, tokens, caches, lengths):
    """Decode one token per row against a paged KV cache (repro.serve).

    tokens: (B, 1) int32; lengths: (B,) int32 — each row's current context
    length, which is simultaneously its RoPE position, its KV write
    position, and its attention mask bound (the paged cache carries no
    "pos" leaf; per-row positions flow through here). Returns
    (logits (B, V), new_caches)."""
    positions = lengths[:, None]
    hidden, new_caches, _ = forward(params, cfg, tokens, positions,
                                    caches=caches)
    return logits_fn(params, cfg, hidden[:, -1]), new_caches


def prefill(params, cfg, tokens, enc_embeds=None, max_len: int = 0):
    """Run the full prompt in one pass; return (last_logits, decode-ready
    caches). Attention K/V land directly in cache layout; recurrent mixers
    emit their final states. max_len > prompt length reserves decode slots
    in global-attention caches (rolling-window caches are fixed-size)."""
    positions = jnp.arange(tokens.shape[1])
    enc_out = encode(params, cfg, enc_embeds) if cfg.is_encdec else None
    hidden, caches, _ = forward(params, cfg, tokens, positions,
                                enc_out=enc_out, fill_cache=True)
    if max_len > tokens.shape[1] and cfg.attention_window == 0:
        caches = _pad_attn_caches(caches, max_len)
    if cfg.is_encdec:
        caches["enc_out"] = enc_out
    logits = logits_fn(params, cfg, hidden[:, -1])
    return logits, caches


def _pad_attn_caches(caches, max_len):
    def pad(sub):
        if isinstance(sub, dict) and "k" in sub and "pos" in sub:
            extra = max_len - sub["k"].shape[-3]
            if extra > 0:
                widths = [(0, 0)] * sub["k"].ndim
                widths[-3] = (0, extra)
                sub = dict(sub, k=jnp.pad(sub["k"], widths),
                           v=jnp.pad(sub["v"], widths))
            return sub
        return sub

    return jax.tree.map(
        pad, caches,
        is_leaf=lambda x: isinstance(x, dict) and "k" in x and "pos" in x,
    )


def _cache_pos(caches, cfg):
    leaves = caches["tail"] if caches.get("tail") else None
    if caches.get("scan") is not None:
        for v in caches["scan"].values():
            if isinstance(v, dict) and "pos" in v:
                return v["pos"][0] if v["pos"].ndim else v["pos"]
    if leaves:
        for v in leaves:
            if isinstance(v, dict) and "pos" in v:
                return v["pos"]
    return jnp.zeros((), jnp.int32)  # pure-recurrent models track no pos
