"""Model registry: bind a ModelConfig/GANConfig to a uniform functional
bundle used by training, serving, the dry-run, and the tests."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import gan as gan_lib
from . import model as lm


@dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    init: Callable                  # (key, max_seq) -> params
    field_fn: Callable              # (params, batch, rng) -> (grads, metrics)
    loss_fn: Optional[Callable]     # (params, batch) -> (loss, metrics)
    prefill: Optional[Callable]     # (params, tokens[, enc]) -> (logits, cache)
    decode_step: Optional[Callable]
    init_cache: Optional[Callable]
    # (params, tokens (B,1), paged_caches, lengths (B,)) -> (logits, caches);
    # the repro.serve engine's per-row-position decode (None for GANs).
    decode_paged: Optional[Callable] = None


def build(cfg) -> ModelBundle:
    if isinstance(cfg, gan_lib.GANConfig):
        return ModelBundle(
            cfg=cfg,
            init=lambda key, max_seq=0: gan_lib.init(key, cfg),
            field_fn=gan_lib.gan_field_fn(cfg),
            loss_fn=None,
            prefill=None,
            decode_step=None,
            init_cache=None,
        )
    assert isinstance(cfg, ModelConfig), cfg

    def loss_fn(params, batch):
        return lm.loss_fn(params, cfg, batch)

    def field_fn(params, batch, rng):
        del rng
        (_, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return grads, metrics

    return ModelBundle(
        cfg=cfg,
        init=lambda key, max_seq=0: lm.init(key, cfg, max_seq),
        field_fn=field_fn,
        loss_fn=loss_fn,
        prefill=lambda params, tokens, enc=None, max_len=0: lm.prefill(
            params, cfg, tokens, enc, max_len=max_len),
        decode_step=lambda params, tokens, caches: lm.decode_step(
            params, cfg, tokens, caches),
        init_cache=lambda batch, seq, dtype=None: lm.init_cache(
            cfg, batch, seq, dtype),
        decode_paged=lambda params, tokens, caches, lengths: lm.decode_step_paged(
            params, cfg, tokens, caches, lengths),
    )
