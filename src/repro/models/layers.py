"""Shared layers: norms, RoPE, linears, MLPs, attention (GQA/MQA, sliding
window, KV-cache decode, chunked long-context prefill).

Everything is functional: `*_init(key, ...) -> params` and pure apply
functions. Params are plain nested dicts; linears are {"w": ..., "b"?: ...}
so `parallel.sharding` can assign PartitionSpecs by path name.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ------------------------------------------------------------------------- #
# init helpers
# ------------------------------------------------------------------------- #
def linear_init(key, d_in, d_out, use_bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) / math.sqrt(d)).astype(dtype)


# ------------------------------------------------------------------------- #
# norms
# ------------------------------------------------------------------------- #
def norm_init(kind: str, d, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(kind: str, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------------- #
# RoPE
# ------------------------------------------------------------------------- #
def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------------------- #
# MLP
# ------------------------------------------------------------------------- #
def mlp_init(key, d, ff, activation, use_bias, dtype):
    ks = jax.random.split(key, 3)
    if activation in ("silu", "geglu"):
        return {
            "gate": linear_init(ks[0], d, ff, use_bias, dtype),
            "up": linear_init(ks[1], d, ff, use_bias, dtype),
            "down": linear_init(ks[2], ff, d, use_bias, dtype, scale=1 / math.sqrt(ff)),
        }
    return {
        "up": linear_init(ks[1], d, ff, use_bias, dtype),
        "down": linear_init(ks[2], ff, d, use_bias, dtype, scale=1 / math.sqrt(ff)),
    }


def mlp(p, x, activation: str):
    if activation in ("silu", "geglu"):
        act = jax.nn.silu if activation == "silu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        h = act(linear(p["gate"], x)) * linear(p["up"], x)
    else:
        h = jax.nn.gelu(linear(p["up"], x), approximate=True)
    return linear(p["down"], h)


# ------------------------------------------------------------------------- #
# attention
# ------------------------------------------------------------------------- #
# Paged-decode attention implementation, switchable at trace time:
#   "gather" — gather the block pool through the table into a dense
#              (B, S, K, hd) view and run the exact grouped-einsum decode
#              math below (bit-identical to the dense cache path, which the
#              serving engine's equivalence tests pin).
#   "pallas" — repro.kernels.flash_attention.paged_flash_attention, an
#              online-softmax kernel that reads only the live blocks.
# A module global (not a cfg field) so repro.serve can flip it without a
# config/schema change and without layers importing serve (cycle).
_PAGED_ATTN_IMPL = ["gather"]


def set_paged_attn_impl(impl: str) -> str:
    """Set the paged decode attention impl; returns the previous value."""
    assert impl in ("gather", "pallas"), impl
    prev = _PAGED_ATTN_IMPL[0]
    _PAGED_ATTN_IMPL[0] = impl
    return prev


def _constrain_batch_only(x, batch_size):
    """with_sharding_constraint: batch dim over the data axes (when they
    divide it), everything else replicated. Used to stop XLA from sharding
    decode attention scores over 'model' along the KV-sequence dim — the
    choice that forces cache/probs regathers (EXPERIMENTS.md §Perf hc2)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover
        return x
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    chosen = []
    for a in axes:
        if batch_size % (n * mesh.shape[a]) == 0:
            chosen.append(a)
            n *= mesh.shape[a]
    spec = jax.sharding.PartitionSpec(
        tuple(chosen) if chosen else None, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def attn_init(key, d, n_heads, n_kv, head_dim, use_bias, dtype):
    ks = jax.random.split(key, 4)
    return {
        "q": linear_init(ks[0], d, n_heads * head_dim, use_bias, dtype),
        "k": linear_init(ks[1], d, n_kv * head_dim, use_bias, dtype),
        "v": linear_init(ks[2], d, n_kv * head_dim, use_bias, dtype),
        "o": linear_init(ks[3], n_heads * head_dim, d, use_bias, dtype,
                         scale=1 / math.sqrt(n_heads * head_dim)),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_heads, n_kv):
    if n_heads == n_kv:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def _causal_band_mask(q_pos, k_pos, window: int):
    """True where attention is allowed."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = diff >= 0
    if window > 0:
        ok &= diff < window
    return ok


def attention_dense(q, k, v, q_pos, k_pos, window=0, causal=True, softcap=0.0):
    """Plain O(S²) attention. q: (B,Sq,H,hd); k,v: (B,Sk,K,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    k = _repeat_kv(k, H, K)
    v = _repeat_kv(v, H, K)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    if causal:
        mask = _causal_band_mask(q_pos, k_pos, window)
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H * hd)


def attention_chunked(q, k, v, window=0, causal=True, q_chunk=1024):
    """Memory-bounded attention for long sequences: scan over query chunks,
    each attending to a dynamically-sliced KV band. Avoids materializing
    O(S²) scores; with a sliding window it also avoids O(S²) FLOPs (the KV
    slice is bounded by window + chunk).

    q: (B,S,H,hd), k/v: (B,S,K,hd). Self-attention with aligned positions.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    n_chunks = S // q_chunk
    assert S % q_chunk == 0, (S, q_chunk)
    kv_span = (
        S if window <= 0 else min(S, q_chunk * ((window + q_chunk - 1) // q_chunk + 1))
    )

    def body(_, idx):
        q_start = idx * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, q_chunk, axis=1)
        if window <= 0:
            kc, vc, k_start = k, v, 0
        else:
            k_start = jnp.maximum(q_start + q_chunk - kv_span, 0)
            kc = jax.lax.dynamic_slice_in_dim(k, k_start, kv_span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k_start, kv_span, axis=1)
        q_pos = q_start + jnp.arange(q_chunk)
        k_pos = k_start + jnp.arange(kc.shape[1])
        out = attention_dense(qc, kc, vc, q_pos, k_pos, window=window,
                              causal=causal)
        return None, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # (n_chunks, B, q_chunk, H*hd) -> (B, S, H*hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H * hd)


def attn_apply(p, x, cfg, positions, cache=None, cross_kv=None, causal=True,
               fill_cache=False):
    """Unified attention: train/prefill (cache None), decode (cache dict),
    or cross-attention (cross_kv = (k, v) precomputed from encoder).

    With fill_cache=True (prefill), the freshly computed K/V are returned
    as a decode-ready cache (rolled into window layout for sliding-window
    models). Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, Kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(linear(p["q"], x), H, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = attention_dense(q, k, v, positions, jnp.arange(k.shape[1]),
                              causal=False)
        return linear(p["o"], out), None

    k = _split_heads(linear(p["k"], x), Kh, hd)
    v = _split_heads(linear(p["v"], x), Kh, hd)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if cache is None:  # train / prefill
        if S > 2048:
            out = attention_chunked(q, k, v, window=cfg.attention_window,
                                    causal=causal)
        else:
            pos = jnp.arange(S)
            out = attention_dense(q, k, v, pos, pos,
                                  window=cfg.attention_window, causal=causal)
        new_cache = None
        if fill_cache:
            win = cfg.attention_window
            if win > 0 and S >= win:
                # rolling layout: slot i holds absolute position
                # p = S - win + ((i - S) mod win), so that p ≡ i (mod win)
                idx = S - win + jnp.mod(jnp.arange(win) - S, win)
                ck, cv = jnp.take(k, idx, 1), jnp.take(v, idx, 1)
            elif win > 0:  # prompt shorter than the window: pad to win slots
                pad = ((0, 0), (0, win - S), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                ck, cv = k, v
            new_cache = {"k": ck, "v": cv, "pos": jnp.asarray(S, jnp.int32)}
        return linear(p["o"], out), new_cache

    # ---- decode with KV cache ------------------------------------------- #
    if "table" in cache:
        # paged cache (repro.serve): {"k": (NB, bs, K, hd) pool, "v": pool,
        # "table": (B, max_blocks) int32}. Per-row write positions arrive
        # via `positions` (B, 1) — the paged layout carries no "pos" leaf.
        return _paged_attn_decode(p, cfg, q, k, v, cache, positions)
    # cache: {"k": (B, S_cache, K, hd), "v": ..., "pos": ()} — rolling when
    # cfg.attention_window > 0 (cache length == window).
    ck, cv = cache["k"], cache["v"]
    t = cache["pos"]
    if cfg.attention_window > 0 and ck.shape[1] == cfg.attention_window:
        slot = t % cfg.attention_window
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        k_pos = jnp.arange(ck.shape[1])
        # rolling positions: entry i holds absolute position
        # t - ((slot - i) mod window)
        k_pos = t - jnp.mod(slot - k_pos, cfg.attention_window)
        valid = k_pos >= 0
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, t, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, t, axis=1)
        k_pos = jnp.arange(ck.shape[1])
        valid = k_pos <= t
    # grouped-GQA attention: no repeat_kv materialization (the repeat is a
    # broadcast that forces XLA to regather the sharded cache — §Perf
    # hillclimb 2), f32 only on the (tiny) score tensor.
    G = H // Kh
    qg = q.reshape(B, S, Kh, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = _constrain_batch_only(scores, B)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(cv.dtype), cv)
    out = out.reshape(B, S, H * hd)
    new_cache = {"k": ck, "v": cv, "pos": t + S}
    return linear(p["o"], out), new_cache


def _paged_attn_decode(p, cfg, q, k, v, cache, positions):
    """Single-token decode against a paged KV cache.

    q: (B, 1, H, hd) post-RoPE; k, v: (B, 1, K, hd) post-RoPE. The incoming
    token's K/V are scattered into the pool block the row's table maps its
    write position to, then attention reads the row's blocks. Rows whose
    table is parked on the scratch block (inactive serving slots) write
    there harmlessly; their reads are fully masked.

    The default "gather" impl keeps the einsum strings, op order and
    reduction shapes of the dense-cache branch above, so an engine decode
    step is bit-identical to a dense sequential decode at the same context
    length (tests/test_serve.py pins this).
    """
    pool_k, pool_v, table = cache["k"], cache["v"], cache["table"]
    B = q.shape[0]
    H, Kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    bs = pool_k.shape[1]
    max_blocks = table.shape[1]
    S = bs * max_blocks                                  # gathered view length
    t = positions[:, -1]                                 # (B,) write position
    rows = jnp.arange(B)
    bidx = table[rows, t // bs]                          # (B,) pool block id
    pool_k = pool_k.at[bidx, t % bs].set(k[:, 0])
    pool_v = pool_v.at[bidx, t % bs].set(v[:, 0])
    new_cache = {"k": pool_k, "v": pool_v, "table": table}

    if _PAGED_ATTN_IMPL[0] == "pallas":
        from repro.kernels.flash_attention import paged_flash_attention
        out = paged_flash_attention(
            q[:, 0].reshape(B, Kh, H // Kh, hd), pool_k, pool_v, table, t + 1)
        return linear(p["o"], out.reshape(B, 1, H * hd)), new_cache

    ck = pool_k[table].reshape(B, S, Kh, hd)
    cv = pool_v[table].reshape(B, S, Kh, hd)
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] <= t[:, None]                 # (B, S) per-row mask
    G = H // Kh
    qg = q.reshape(B, 1, Kh, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = _constrain_batch_only(scores, B)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(cv.dtype), cv)
    out = out.reshape(B, 1, H * hd)
    return linear(p["o"], out), new_cache


def attn_cache_init(cfg, batch, seq, dtype):
    win = cfg.attention_window
    length = min(seq, win) if win > 0 else seq
    return {
        "k": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
