"""GANs — the paper's own experimental architectures.

Two families:
  * DCGAN-style conv generator/discriminator for image data (the paper's
    CIFAR10/CelebA setup, §4), built on lax.conv_general_dilated.
  * MLP generator/discriminator for low-dimensional synthetic data
    (2-D Gaussian mixtures) — used by the quickstart + convergence bench.

Loss: WGAN (paper Eq. 3):
    L_D = -E_x[D(x)] + E_z[D(G(z))]       L_G = -E_z[D(G(z))]
The min-max field (paper Eq. 10) is F(w) = [∇θ L_G, ∇φ L_D] — that is what
DQGAN exchanges/averages across workers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import linear, linear_init


@dataclass(frozen=True)
class GANConfig:
    name: str = "dcgan32"
    arch_type: str = "gan"
    image_size: int = 32          # 0 -> vector data (MLP GAN)
    channels: int = 3
    latent_dim: int = 128
    base_width: int = 64
    data_dim: int = 2             # for MLP GAN
    hidden: int = 128
    weight_clip: float = 0.1      # WGAN Lipschitz via clipping
    # critic-to-generator learning-rate ratio; the simultaneous-update
    # equivalent of WGAN's n_critic=5 (scales the disc part of the field)
    disc_grad_mult: float = 5.0

    @property
    def is_image(self) -> bool:
        return self.image_size > 0

    def reduced(self) -> "GANConfig":
        return GANConfig(name=self.name + "-smoke", image_size=8, channels=1,
                         latent_dim=16, base_width=8)


# --------------------------------------------------------------------------- #
# conv helpers (NHWC)
# --------------------------------------------------------------------------- #
def conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return {"w": jax.random.normal(key, (kh, kw, cin, cout)) * 0.02,
            "b": jnp.zeros((cout,))}


def conv(p, x, stride=2):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def conv_t(p, x, stride=2):
    y = jax.lax.conv_transpose(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _bn_free_act(x):  # DCGAN without batchnorm (WGAN-friendly): leaky relu
    return jax.nn.leaky_relu(x, 0.2)


# --------------------------------------------------------------------------- #
# DCGAN
# --------------------------------------------------------------------------- #
def dcgan_init(key, cfg: GANConfig):
    bw = cfg.base_width
    s0 = cfg.image_size // 8  # three stride-2 upsamples
    ks = jax.random.split(key, 10)
    gen = {
        "fc": linear_init(ks[0], cfg.latent_dim, s0 * s0 * bw * 4, True),
        "c1": conv_init(ks[1], 4, 4, bw * 4, bw * 2),
        "c2": conv_init(ks[2], 4, 4, bw * 2, bw),
        "c3": conv_init(ks[3], 4, 4, bw, cfg.channels),
    }
    disc = {
        "c1": conv_init(ks[4], 4, 4, cfg.channels, bw),
        "c2": conv_init(ks[5], 4, 4, bw, bw * 2),
        "c3": conv_init(ks[6], 4, 4, bw * 2, bw * 4),
        "fc": linear_init(ks[7], s0 * s0 * bw * 4, 1, True),
    }
    return {"gen": gen, "disc": disc}


def dcgan_generate(gen, cfg: GANConfig, z):
    bw = cfg.base_width
    s0 = cfg.image_size // 8
    x = jax.nn.relu(linear(gen["fc"], z)).reshape(-1, s0, s0, bw * 4)
    x = jax.nn.relu(conv_t(gen["c1"], x))
    x = jax.nn.relu(conv_t(gen["c2"], x))
    return jnp.tanh(conv_t(gen["c3"], x))


def dcgan_discriminate(disc, cfg: GANConfig, x):
    h = _bn_free_act(conv(disc["c1"], x))
    h = _bn_free_act(conv(disc["c2"], h))
    h = _bn_free_act(conv(disc["c3"], h))
    return linear(disc["fc"], h.reshape(h.shape[0], -1))[:, 0]


# --------------------------------------------------------------------------- #
# MLP GAN (synthetic 2-D data)
# --------------------------------------------------------------------------- #
def mlp_gan_init(key, cfg: GANConfig):
    ks = jax.random.split(key, 6)
    h = cfg.hidden
    gen = {
        "l1": linear_init(ks[0], cfg.latent_dim, h, True),
        "l2": linear_init(ks[1], h, h, True),
        "l3": linear_init(ks[2], h, cfg.data_dim, True),
    }
    disc = {
        "l1": linear_init(ks[3], cfg.data_dim, h, True),
        "l2": linear_init(ks[4], h, h, True),
        "l3": linear_init(ks[5], h, 1, True),
    }
    return {"gen": gen, "disc": disc}


def mlp_generate(gen, cfg, z):
    h = jax.nn.relu(linear(gen["l1"], z))
    h = jax.nn.relu(linear(gen["l2"], h))
    return linear(gen["l3"], h)


def mlp_discriminate(disc, cfg, x):
    h = jax.nn.leaky_relu(linear(disc["l1"], x), 0.2)
    h = jax.nn.leaky_relu(linear(disc["l2"], h), 0.2)
    return linear(disc["l3"], h)[:, 0]


# --------------------------------------------------------------------------- #
# the min-max field (what DQGAN transports)
# --------------------------------------------------------------------------- #
def generate(params, cfg, z):
    f = dcgan_generate if cfg.is_image else mlp_generate
    return f(params["gen"], cfg, z)


def discriminate(params, cfg, x):
    f = dcgan_discriminate if cfg.is_image else mlp_discriminate
    return f(params["disc"], cfg, x)


def init(key, cfg: GANConfig, max_seq: int = 0):
    del max_seq
    return (dcgan_init if cfg.is_image else mlp_gan_init)(key, cfg)


def gan_field_fn(cfg: GANConfig):
    """Returns field_fn(params, batch, rng) -> (grads, metrics) for DQGAN.
    batch: {"real": real samples}."""

    def loss_g(gen_params, disc_params, z):
        fake = generate({"gen": gen_params}, cfg, z) if False else (
            (dcgan_generate if cfg.is_image else mlp_generate)(gen_params, cfg, z)
        )
        d = (dcgan_discriminate if cfg.is_image else mlp_discriminate)(
            disc_params, cfg, fake)
        return -jnp.mean(d)

    def loss_d(disc_params, gen_params, real, z):
        disc = dcgan_discriminate if cfg.is_image else mlp_discriminate
        genf = dcgan_generate if cfg.is_image else mlp_generate
        fake = jax.lax.stop_gradient(genf(gen_params, cfg, z))
        return -jnp.mean(disc(disc_params, cfg, real)) + jnp.mean(
            disc(disc_params, cfg, fake))

    def field_fn(params, batch, rng):
        real = batch["real"]
        z = jax.random.normal(rng, (real.shape[0], cfg.latent_dim))
        lg, g_gen = jax.value_and_grad(loss_g)(params["gen"], params["disc"], z)
        ld, g_disc = jax.value_and_grad(loss_d)(params["disc"], params["gen"],
                                                real, z)
        grads = {"gen": g_gen,
                 "disc": jax.tree.map(lambda x: cfg.disc_grad_mult * x,
                                      g_disc)}
        return grads, {"loss": ld + lg, "loss_g": lg, "loss_d": ld}

    return field_fn


def clip_disc(params, cfg: GANConfig):
    """WGAN weight clipping (applied to the discriminator after a step)."""
    c = cfg.weight_clip
    return {
        "gen": params["gen"],
        "disc": jax.tree.map(lambda w: jnp.clip(w, -c, c), params["disc"]),
    }
