from .registry import ModelBundle, build  # noqa: F401
