"""Sequence mixers beyond attention: Mamba2 SSD and the RG-LRU recurrent
block (RecurrentGemma / Griffin). Both provide train/prefill over full
sequences and O(1)-state decode steps.

TPU adaptation notes (DESIGN.md): the CUDA SSD kernel is replaced by the
chunked einsum formulation (state-space duality) — intra-chunk work is
MXU-friendly batched matmuls, inter-chunk state is a short lax.scan. The
RG-LRU uses lax.associative_scan (log-depth) instead of a fused CUDA scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import linear, linear_init, apply_norm

# ------------------------------------------------------------------------- #
# causal depthwise conv1d (shared by SSD and RG-LRU)
# ------------------------------------------------------------------------- #
def conv1d_init(key, channels, width, dtype):
    return {
        "w": (jax.random.normal(key, (width, channels), jnp.float32)
              / math.sqrt(width)).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p, x):
    """x: (B, T, C) -> (B, T, C), causal, depthwise."""
    width = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["w"][i] for i in range(width)
    )
    return out + p["b"]


def conv_step(p, buf, x_t):
    """Single decode step. buf: (B, width-1, C) past inputs; x_t: (B, 1, C)."""
    width = p["w"].shape[0]
    window = jnp.concatenate([buf, x_t], axis=1)  # (B, width, C)
    out = jnp.einsum("bwc,wc->bc", window, p["w"]) + p["b"]
    return out[:, None, :], window[:, 1:, :]


# ------------------------------------------------------------------------- #
# Mamba2 / SSD
# ------------------------------------------------------------------------- #
def ssd_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n_h = di // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        "z": linear_init(ks[0], d, di, False, dtype),
        "x": linear_init(ks[1], d, di, False, dtype),
        "B": linear_init(ks[2], d, s.state_dim, False, dtype),
        "C": linear_init(ks[3], d, s.state_dim, False, dtype),
        "dt": linear_init(ks[4], d, n_h, False, dtype),
        "dt_bias": jnp.zeros((n_h,), dtype),
        "A_log": jnp.zeros((n_h,), jnp.float32),
        "D": jnp.ones((n_h,), dtype),
        "conv": conv1d_init(ks[5], di + 2 * s.state_dim, s.conv_width, dtype),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out": linear_init(ks[6], di, d, False, dtype, scale=1 / math.sqrt(di)),
    }


def _ssd_inputs(p, cfg, u):
    """Shared projections for prefill and decode: returns (z, xBC, dt)."""
    s = cfg.ssm
    z = linear(p["z"], u)
    xBC = jnp.concatenate(
        [linear(p["x"], u), linear(p["B"], u), linear(p["C"], u)], axis=-1
    )
    dt = jax.nn.softplus(
        linear(p["dt"], u).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    return z, xBC, dt


def _ssd_split(xBC, cfg):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    x = xBC[..., :di]
    Bm = xBC[..., di : di + s.state_dim]
    Cm = xBC[..., di + s.state_dim :]
    return x, Bm, Cm


def ssd_apply(p, cfg, u, state=None, return_state=False):
    """u: (B, T, d). state None -> full-sequence (chunked SSD);
    state dict -> single-token decode. With return_state=True the final
    recurrent state + conv buffer are returned (prefill). Returns
    (y, new_state)."""
    s = cfg.ssm
    di = s.expand * cfg.d_model
    n_h = di // s.head_dim
    P_ = s.head_dim
    N = s.state_dim
    A = -jnp.exp(p["A_log"])  # (H,) negative decay rates

    z, xBC, dt = _ssd_inputs(p, cfg, u)

    if state is not None:
        conv_out, conv_buf = conv_step(p["conv"], state["conv"], xBC)
        x, Bm, Cm = _ssd_split(jax.nn.silu(conv_out), cfg)
        B_, T, _ = x.shape  # T == 1
        xh = x.reshape(B_, n_h, P_)
        dt1 = dt[:, 0]  # (B,H)
        da = jnp.exp(A[None] * dt1)  # (B,H)
        h = state["h"] * da[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt1, xh.astype(jnp.float32),
            Bm[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0].astype(jnp.float32))
        y = y.astype(u.dtype) + p["D"].astype(u.dtype)[None, :, None] * xh
        y = y.reshape(B_, 1, di)
        y = _gated_norm(p["norm"], y, z)
        return linear(p["out"], y), {"h": h, "conv": conv_buf}

    # ---- chunked SSD over the full sequence ------------------------------ #
    x_conv = jax.nn.silu(causal_conv1d(p["conv"], xBC))
    x, Bm, Cm = _ssd_split(x_conv, cfg)
    B_, T, _ = x.shape
    L = min(s.chunk_size, T)
    assert T % L == 0, (T, L)
    nc = T // L
    xh = x.reshape(B_, nc, L, n_h, P_)
    Bc = Bm.reshape(B_, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, L, N).astype(jnp.float32)
    dtc = dt.reshape(B_, nc, L, n_h)                       # f32
    Adt = A[None, None, None] * dtc                        # (B,nc,L,H)
    cum = jnp.cumsum(Adt, axis=2)                          # running log-decay
    # intra-chunk (lower-triangular kernel)
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    kern = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)              # (B,nc,L,L)
    W = G[..., None] * kern * dtc[:, :, None]              # weight for (l<-m)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", W, xh.astype(jnp.float32))
    # chunk-final states
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,L,H)
    S_c = jnp.einsum(
        "bclh,bclhp,bcln->bchpn", dtc * dec_to_end, xh.astype(jnp.float32), Bc
    )
    chunk_decay = jnp.exp(jnp.sum(Adt, axis=2))            # (B,nc,H)

    def scan_fn(h_prev, inp):
        s_c, dec = inp
        h_new = h_prev * dec[..., None, None] + s_c
        return h_new, h_prev

    h0 = jnp.zeros((B_, n_h, P_, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,P,N) state before chunk
    y_inter = jnp.einsum(
        "bcln,bchpn,bclh->bclhp", Cc, h_prevs, jnp.exp(cum)
    )
    y = (y_intra + y_inter).astype(u.dtype)
    y = y + p["D"].astype(y.dtype)[None, None, None, :, None] * xh
    y = y.reshape(B_, T, di)
    y = _gated_norm(p["norm"], y, z)
    new_state = None
    if return_state:
        width = s.conv_width
        new_state = {"h": h_final, "conv": xBC[:, -(width - 1):, :]}
    return linear(p["out"], y), new_state


def _gated_norm(norm_p, y, z):
    return apply_norm("rmsnorm", norm_p, y * jax.nn.silu(z))


def ssd_state_init(cfg, batch, dtype):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    n_h = di // s.head_dim
    return {
        "h": jnp.zeros((batch, n_h, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, di + 2 * s.state_dim), dtype),
    }


# ------------------------------------------------------------------------- #
# RG-LRU (RecurrentGemma / Griffin recurrent block)
# ------------------------------------------------------------------------- #
def rglru_init(key, cfg, dtype):
    r = cfg.rglru
    d = cfg.d_model
    w = r.expand * d
    ks = jax.random.split(key, 6)
    # Λ initialized so a = σ(Λ)^c lands in [0.9, 0.999] (griffin init)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam_logit = jnp.log(lam ** (1.0 / r.c_constant) / (1 - lam ** (1.0 / r.c_constant)))
    return {
        "in_x": linear_init(ks[1], d, w, False, dtype),
        "in_gate": linear_init(ks[2], d, w, False, dtype),
        "conv": conv1d_init(ks[3], w, r.conv_width, dtype),
        "W_a": linear_init(ks[4], w, w, True, dtype),
        "W_i": linear_init(ks[5], w, w, True, dtype),
        "lam": lam_logit,
        "out": linear_init(jax.random.fold_in(key, 9), w, d, False, dtype,
                           scale=1 / math.sqrt(w)),
    }


def _rglru_gates(p, cfg, u):
    """u: conv'd x branch, (B,T,w). Returns (a, b) recurrence coefficients."""
    c = cfg.rglru.c_constant
    r_gate = jax.nn.sigmoid(linear(p["W_a"], u).astype(jnp.float32))
    i_gate = jax.nn.sigmoid(linear(p["W_i"], u).astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lam"]) * r_gate   # log a_t  (B,T,w)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate * u.astype(jnp.float32)
    )
    return a, b


def rglru_apply(p, cfg, x, state=None, return_state=False):
    """x: (B,T,d). Returns (y, new_state). state: {"h": (B,w), "conv": buf}."""
    u0 = linear(p["in_x"], x)
    gate = jax.nn.gelu(linear(p["in_gate"], x), approximate=True)

    if state is not None:
        conv_out, conv_buf = conv_step(p["conv"], state["conv"], u0)
        a, b = _rglru_gates(p, cfg, conv_out)
        h = a[:, 0] * state["h"] + b[:, 0]               # (B,w)
        y = (h[:, None, :]).astype(x.dtype) * gate
        return linear(p["out"], y), {"h": h, "conv": conv_buf}

    u = causal_conv1d(p["conv"], u0)
    a, b = _rglru_gates(p, cfg, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(x.dtype) * gate
    new_state = None
    if return_state:
        width = cfg.rglru.conv_width
        new_state = {"h": h[:, -1], "conv": u0[:, -(width - 1):, :]}
    return linear(p["out"], y), new_state


def rglru_state_init(cfg, batch, dtype):
    w = cfg.rglru.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
    }
