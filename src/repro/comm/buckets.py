"""Static gradient bucketing (DESIGN.md §3.1).

The gradient pytree of a real model is hundreds of ragged tensors — biases,
norm gains, conv kernels, embedding tables. Compressing and exchanging them
one-by-one costs a collective launch per tensor, leaves the Pallas
quantize+EF kernel with tiles it cannot lane-align, and (worst) forces the
``two_phase`` exchange to fall back to ``sim`` whenever a tensor has no
worker-divisible unsharded axis. DDP-style bucketing fixes all three at
once: flatten the tree into a handful of large contiguous f32 buckets whose
padded length is divisible by ``n_workers * LANE * SUBLANE``, so

  * every bucket has a trivial two_phase chunking (axis 0, size % W == 0),
  * every bucket reshapes to an (R, 128·k) tile grid for the fused kernel,
  * the per-step collective count drops from O(#tensors) to O(#buckets).

The layout is computed once from static shapes (+ PartitionSpecs) and is a
frozen, hashable dataclass — safe to close over in a jitted step. Leaves
whose spec shards a dimension over a mesh axis cannot be flattened locally
(their ravel would gather across devices); by default they stay on the
per-tensor exchange path and are recorded in ``BucketLayout.skipped``.

Shard-aware mode (DESIGN.md §15.1): passing ``shard_axes`` (+ the mesh
``axis_sizes``) buckets leaves that are sharded ONLY over those axes at
their *local* shard shape — each owner's tile enters a flat bucket,
lane-aligned within the shard, so the fused Pallas quantize+EF kernel
runs over shard tiles instead of the leaf bypassing buckets entirely.
Such slots carry ``local=True``; pack/unpack then consume/produce the
local (per-shard) arrays. Leaves sharded over any *other* axis (e.g. a
tensor-model axis) still skip.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

LANE = 128      # TPU lane width (last-dim tile unit)
SUBLANE = 8     # f32 sublane; LANE*SUBLANE keeps (R, C) tiles well-formed

DEFAULT_BUCKET_BYTES = 4 << 20  # 4 MiB of f32 per bucket before closing it


@dataclass(frozen=True)
class LeafSlot:
    """One tensor's place in the layout. ``bucket == -1`` means the leaf is
    skipped (sharded) and stays on the per-tensor exchange path."""
    index: int                  # position in jax.tree.flatten order
    path: str                   # pretty key path, for planner tiers + logs
    shape: Tuple[int, ...]      # LOCAL shape when ``local`` (shard-aware)
    size: int
    bucket: int
    offset: int                 # element offset inside the bucket's flat array
    local: bool = False         # True: shape/size are the per-owner shard


@dataclass(frozen=True)
class Bucket:
    bid: int
    size: int                   # padded length (elements), % align == 0
    used: int                   # sum of member leaf sizes
    slots: Tuple[LeafSlot, ...]

    @property
    def padding(self) -> int:
        return self.size - self.used


@dataclass(frozen=True)
class BucketLayout:
    buckets: Tuple[Bucket, ...]
    skipped: Tuple[LeafSlot, ...]
    n_workers: int
    align: int
    n_leaves: int

    @property
    def bucketed_elems(self) -> int:
        return sum(b.used for b in self.buckets)

    @property
    def padded_elems(self) -> int:
        return sum(b.size for b in self.buckets)

    @property
    def pad_fraction(self) -> float:
        tot = self.padded_elems
        return (tot - self.bucketed_elems) / tot if tot else 0.0

    def describe(self) -> str:
        return (f"{len(self.buckets)} buckets ({self.bucketed_elems} elems, "
                f"{self.pad_fraction:.1%} pad), {len(self.skipped)} leaves "
                f"on the per-tensor path")


# --------------------------------------------------------------------------- #
# layout construction
# --------------------------------------------------------------------------- #
def _is_shape(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(i, int) for i in x)


def _spec_shards_locally(spec, shape, axis_sizes=None) -> bool:
    """True if any tensor dim is partitioned over a mesh axis (its local
    ravel would not be the global ravel). With ``axis_sizes`` known,
    'sharding' over size-1 axes (a degenerate model-parallel mesh) is
    replication and does not count."""
    if spec is None:
        return False
    for ax in range(min(len(spec), len(shape))):
        axes = _spec_entry_axes(spec[ax])
        if not axes:
            continue
        if axis_sizes and all(axis_sizes.get(a) == 1 for a in axes):
            continue
        return True
    return False


def _spec_entry_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def _local_shape(spec, shape, shard_axes, axis_sizes):
    """The per-owner local shape of a sharded leaf, or None when it is
    sharded over an axis outside ``shard_axes`` (or not evenly) and must
    keep the per-tensor path."""
    local = list(shape)
    for ax in range(min(len(spec), len(shape))):
        axes = _spec_entry_axes(spec[ax])
        if not axes:
            continue
        if not all(a in shard_axes for a in axes):
            return None
        try:
            div = math.prod(axis_sizes[a] for a in axes)
        except KeyError:
            return None
        if div <= 0 or local[ax] % div:
            return None
        local[ax] //= div
    return tuple(local)


def _leaf_paths(shapes_tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        shapes_tree, is_leaf=_is_shape)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def build_layout(
    shapes_tree,
    specs_tree=None,
    n_workers: int = 1,
    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
    shard_axes: Tuple[str, ...] = (),
    axis_sizes=None,
) -> BucketLayout:
    """Greedy first-fit bucketing in flatten order (locality-preserving, so
    a bucket usually holds adjacent layers — what the size_tiered planner
    leans on). Shapes must be tuples of ints (use jax.tree.map(lambda x:
    tuple(x.shape), params)). With ``shard_axes`` (+ ``axis_sizes``,
    {axis name: size}), leaves sharded only over those axes are bucketed
    at their local shard shape instead of skipped (shard-aware mode)."""
    shapes = jax.tree.leaves(shapes_tree, is_leaf=_is_shape)
    paths = _leaf_paths(shapes_tree)
    if specs_tree is None:
        specs = [None] * len(shapes)
    else:
        treedef = jax.tree.structure(shapes_tree, is_leaf=_is_shape)
        specs = treedef.flatten_up_to(specs_tree)
    align = n_workers * LANE * SUBLANE
    cap = max(1, bucket_bytes // 4)          # elements of f32 per bucket

    buckets, skipped = [], []
    cur_slots, cur_used = [], 0

    def close():
        nonlocal cur_slots, cur_used
        if not cur_slots:
            return
        bid = len(buckets)
        size = -(-cur_used // align) * align
        buckets.append(Bucket(bid=bid, size=size, used=cur_used,
                              slots=tuple(
                                  LeafSlot(s.index, s.path, s.shape,
                                           s.size, bid, s.offset, s.local)
                                  for s in cur_slots)))
        cur_slots, cur_used = [], 0

    for idx, (shape, path, spec) in enumerate(zip(shapes, paths, specs)):
        shape = tuple(shape)
        is_local = False
        if _spec_shards_locally(spec, shape, axis_sizes):
            local = (_local_shape(spec, shape, shard_axes, axis_sizes or {})
                     if shard_axes else None)
            if local is None:
                skipped.append(LeafSlot(idx, path, shape,
                                        math.prod(shape), -1, 0))
                continue
            shape, is_local = local, True
        size = math.prod(shape)
        if cur_used and cur_used + size > cap:
            close()
        cur_slots.append(LeafSlot(idx, path, shape, size, -1, cur_used,
                                  is_local))
        cur_used += size
    close()

    return BucketLayout(buckets=tuple(buckets), skipped=tuple(skipped),
                        n_workers=n_workers, align=align, n_leaves=len(shapes))


def layout_for_params(params, specs_tree=None, n_workers: int = 1,
                      bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                      shard_axes: Tuple[str, ...] = (),
                      axis_sizes=None) -> BucketLayout:
    shapes = jax.tree.map(lambda x: tuple(x.shape), params)
    return build_layout(shapes, specs_tree, n_workers, bucket_bytes,
                        shard_axes=shard_axes, axis_sizes=axis_sizes)


# --------------------------------------------------------------------------- #
# pack / unpack (runs under jit; pure reshapes + one concat per bucket)
# --------------------------------------------------------------------------- #
def pack(layout: BucketLayout, leaves, dtype=jnp.float32):
    """Gather the bucketed leaves (a flat list in tree-flatten order) into
    one 1-D array per bucket, zero-padded to the aligned size. Slots with
    ``local=True`` expect the caller to pass the LOCAL shard array."""
    flats = []
    for b in layout.buckets:
        parts = [jnp.ravel(leaves[s.index]).astype(dtype) for s in b.slots]
        if b.padding:
            parts.append(jnp.zeros((b.padding,), dtype))
        flats.append(jnp.concatenate(parts) if len(parts) > 1 else parts[0])
    return flats


def unpack_into(layout: BucketLayout, flats, leaves):
    """Scatter bucket contents back over a COPY of ``leaves`` (a flat list);
    skipped leaves keep their existing entries. Returns the new list."""
    out = list(leaves)
    for b in layout.buckets:
        flat = flats[b.bid]
        for s in b.slots:
            out[s.index] = jax.lax.dynamic_slice_in_dim(
                flat, s.offset, s.size
            ).reshape(s.shape).astype(
                leaves[s.index].dtype if hasattr(leaves[s.index], "dtype")
                else flat.dtype)
    return out
