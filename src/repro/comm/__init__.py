"""repro.comm — the communication subsystem (DESIGN.md §3).

Three cooperating pieces:

  buckets.py  : static DDP-style bucketing of the gradient pytree into
                contiguous, worker-divisible, lane-aligned flat arrays.
  planner.py  : per-bucket compressor assignment (uniform / size_tiered /
                delta_budget policies) from analytic δ + a byte budget.
  ledger.py   : CommLedger — per-step and cumulative on-wire byte
                telemetry, computed statically from payload shapes.

`core.dqgan` routes the exchange through bucket views when
DQConfig.comm_plan != "none"; `launch.train` and `benchmarks.run`
surface the ledger.
"""
from .buckets import (  # noqa: F401
    Bucket,
    BucketLayout,
    LeafSlot,
    build_layout,
    layout_for_params,
    pack,
    unpack_into,
)
from .ledger import (  # noqa: F401
    CommLedger,
    LedgerEntry,
    payload_nbytes,
    strategy_wire_bytes,
)
from .planner import (  # noqa: F401
    ALL_POLICIES,
    BucketAssignment,
    CommPlan,
    POLICIES,
    analytic_delta,
    plan_comm,
)
