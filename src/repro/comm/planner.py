"""Layer-wise compression planning (DESIGN.md §3.2).

One global compressor for every tensor is the paper's setting, but it is
not the byte-optimal one: biases and norm gains are a rounding error of
the wire budget yet dominate the δ penalty when crushed to 4 bits, while
the big matmul kernels are where the bytes actually are (the layer-wise
direction of QODA / "Layer-wise Quantization for Quantized Optimistic
Dual Averaging", PAPERS.md). The planner assigns one compressor per
bucket from three policies:

  uniform      : every bucket gets DQConfig.compressor (paper semantics).
  size_tiered  : buckets made only of small tensors (< SMALL_ELEMS) keep
                 full precision — they are ≤ a few % of the bytes but
                 carry δ=1; everything else gets the base compressor.
  delta_budget : greedy bit-width descent. Start every bucket at the base
                 compressor and, while the modeled per-step payload
                 exceeds ``budget_bytes``, downgrade the bucket with the
                 best (bytes saved) / (δ lost) ratio one rung down the
                 ladder base → qsgd4_linf → sign.

δ for the stochastic quantizers is data-dependent (compressors.py returns
None); the planner uses a documented Gaussian heuristic instead — good
enough to *rank* buckets, which is all the greedy needs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core import compressors as C

from .buckets import BucketLayout

POLICIES = ("uniform", "size_tiered", "delta_budget")
# the full DQConfig.comm_plan / Compression.plan domain: "none" keeps the
# seed per-tensor exchange, any planner policy routes through buckets
ALL_POLICIES = ("none",) + POLICIES

SMALL_ELEMS = 1 << 16           # size_tiered: "small" bucket threshold
LADDER = ("qsgd4_linf", "sign")  # delta_budget downgrade rungs after base


def analytic_delta(comp: C.Compressor, d: int) -> float:
    """δ hint in (0, 1]. Exact where the compressor reports one (identity,
    topk, randk); for linf stochastic quantizers use the Gaussian-input
    estimate E||Q(v)-v||²/||v||² ≈ d·(s/2L)²·(1/3)/||v||² with s² ≈
    2·ln(d)·σ² (expected max² of d gaussians) and ||v||² ≈ d·σ², i.e.
    δ ≈ 1 − ln(d)/(6L²); for sign, δ = (E|v|)²/E[v²] = 2/π."""
    exact = comp.delta(d)
    if exact is not None:
        return float(exact)
    if isinstance(comp, C.StochasticQuant):
        block = comp.per_block if comp.per_block > 0 else d
        loss = math.log(max(block, 2)) / (6.0 * comp.levels**2)
        return max(1e-3, 1.0 - loss)
    if isinstance(comp, C.SignMean):
        return 2.0 / math.pi
    return 0.5


@dataclass(frozen=True)
class BucketAssignment:
    bid: int
    compressor: str
    elems: int
    wire_bytes: int             # analytic payload bytes for this bucket
    delta: float                # δ hint for the assigned compressor


@dataclass(frozen=True)
class CommPlan:
    policy: str
    assignments: Tuple[BucketAssignment, ...]
    base_compressor: str

    @property
    def payload_bytes(self) -> int:
        """Per-worker compressed payload bytes per step (before the
        strategy's collective multiplier — see ledger.strategy_multiplier)."""
        return sum(a.wire_bytes for a in self.assignments)

    @property
    def min_delta(self) -> float:
        return min((a.delta for a in self.assignments), default=1.0)

    def compressor_for(self, bid: int) -> str:
        return self.assignments[bid].compressor

    def describe(self) -> str:
        by = {}
        for a in self.assignments:
            by[a.compressor] = by.get(a.compressor, 0) + 1
        mix = " ".join(f"{k}x{n}" for k, n in sorted(by.items()))
        return (f"policy={self.policy} [{mix}] payload={self.payload_bytes}B "
                f"min_delta={self.min_delta:.3f}")


def _assign(bid: int, name: str, elems: int) -> BucketAssignment:
    comp = C.get(name)
    return BucketAssignment(
        bid=bid, compressor=name, elems=elems,
        wire_bytes=int(comp.wire_bytes((elems,))),
        delta=analytic_delta(comp, elems),
    )


def plan_comm(
    layout: BucketLayout,
    base_compressor: str,
    policy: str = "uniform",
    budget_bytes: int = 0,
) -> CommPlan:
    if policy not in POLICIES:
        raise ValueError(f"unknown comm policy {policy!r}; have {POLICIES}")
    if policy == "delta_budget" and budget_bytes <= 0:
        raise ValueError(
            "comm policy 'delta_budget' needs a positive byte budget "
            "(set DQConfig.comm_budget_mb / --comm-budget-mb)")
    C.get(base_compressor)  # fail fast on bad names

    names = [base_compressor] * len(layout.buckets)

    if policy == "size_tiered":
        for b in layout.buckets:
            if all(s.size < SMALL_ELEMS for s in b.slots):
                names[b.bid] = "identity"

    if policy == "delta_budget":
        ladder = [base_compressor] + [n for n in LADDER
                                      if n != base_compressor]
        rung = [0] * len(layout.buckets)

        def total():
            return sum(_assign(b.bid, names[b.bid], b.size).wire_bytes
                       for b in layout.buckets)

        while total() > budget_bytes:
            best, best_score = None, 0.0
            for b in layout.buckets:
                r = rung[b.bid]
                if r + 1 >= len(ladder):
                    continue
                cur = _assign(b.bid, ladder[r], b.size)
                nxt = _assign(b.bid, ladder[r + 1], b.size)
                saved = cur.wire_bytes - nxt.wire_bytes
                lost = max(cur.delta - nxt.delta, 1e-6)
                if saved <= 0:
                    continue
                score = saved / lost
                if best is None or score > best_score:
                    best, best_score = b.bid, score
            if best is None:
                break  # every bucket already at the cheapest rung
            rung[best] += 1
            names[best] = ladder[rung[best]]

    assignments = tuple(_assign(b.bid, names[b.bid], b.size)
                        for b in layout.buckets)
    return CommPlan(policy=policy, assignments=assignments,
                    base_compressor=base_compressor)
