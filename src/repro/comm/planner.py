"""Layer-wise compression planning (DESIGN.md §3.2).

One global compressor for every tensor is the paper's setting, but it is
not the byte-optimal one: biases and norm gains are a rounding error of
the wire budget yet dominate the δ penalty when crushed to 4 bits, while
the big matmul kernels are where the bytes actually are (the layer-wise
direction of QODA / "Layer-wise Quantization for Quantized Optimistic
Dual Averaging", PAPERS.md). The planner assigns one compressor per
bucket from three policies:

  uniform      : every bucket gets DQConfig.compressor (paper semantics).
  size_tiered  : buckets made only of small tensors (< SMALL_ELEMS) keep
                 full precision — they are ≤ a few % of the bytes but
                 carry δ=1; everything else gets the base compressor.
  delta_budget : greedy bit-width descent. Start every bucket at the base
                 compressor and, while the modeled per-step payload
                 exceeds ``budget_bytes``, downgrade the bucket with the
                 best (bytes saved) / (δ lost) ratio one rung down the
                 ladder — the same-structure 8→4→2-bit quant ladder for
                 linf StochasticQuant bases (quant_ladder; shared with
                 the round-adaptive PlanFamily so its full-participation
                 member is bit-exact with this plan), base → qsgd4_linf
                 → sign otherwise.

δ for the stochastic quantizers is data-dependent (compressors.py returns
None); the planner uses a documented Gaussian heuristic instead — good
enough to *rank* buckets, which is all the greedy needs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core import compressors as C

from .buckets import BucketLayout

POLICIES = ("uniform", "size_tiered", "delta_budget")
# the full DQConfig.comm_plan / Compression.plan domain: "none" keeps the
# seed per-tensor exchange, any planner policy routes through buckets
ALL_POLICIES = ("none",) + POLICIES

SMALL_ELEMS = 1 << 16           # size_tiered: "small" bucket threshold
LADDER = ("qsgd4_linf", "sign")  # delta_budget downgrade rungs after base


def analytic_delta(comp: C.Compressor, d: int) -> float:
    """δ hint in (0, 1]. Exact where the compressor reports one (identity,
    topk, randk); for linf stochastic quantizers use the Gaussian-input
    estimate E||Q(v)-v||²/||v||² ≈ d·(s/2L)²·(1/3)/||v||² with s² ≈
    2·ln(d)·σ² (expected max² of d gaussians) and ||v||² ≈ d·σ², i.e.
    δ ≈ 1 − ln(d)/(6L²); for sign, δ = (E|v|)²/E[v²] = 2/π."""
    exact = comp.delta(d)
    if exact is not None:
        return float(exact)
    if isinstance(comp, C.StochasticQuant):
        block = comp.per_block if comp.per_block > 0 else d
        loss = math.log(max(block, 2)) / (6.0 * comp.levels**2)
        return max(1e-3, 1.0 - loss)
    if isinstance(comp, C.SignMean):
        return 2.0 / math.pi
    return 0.5


@dataclass(frozen=True)
class BucketAssignment:
    bid: int
    compressor: str
    elems: int
    wire_bytes: int             # analytic payload bytes for this bucket
    delta: float                # δ hint for the assigned compressor


@dataclass(frozen=True)
class CommPlan:
    policy: str
    assignments: Tuple[BucketAssignment, ...]
    base_compressor: str

    @property
    def payload_bytes(self) -> int:
        """Per-worker compressed payload bytes per step (before the
        strategy's collective multiplier — see ledger.strategy_multiplier)."""
        return sum(a.wire_bytes for a in self.assignments)

    @property
    def min_delta(self) -> float:
        return min((a.delta for a in self.assignments), default=1.0)

    def compressor_for(self, bid: int) -> str:
        return self.assignments[bid].compressor

    def describe(self) -> str:
        by = {}
        for a in self.assignments:
            by[a.compressor] = by.get(a.compressor, 0) + 1
        mix = " ".join(f"{k}x{n}" for k, n in sorted(by.items()))
        return (f"policy={self.policy} [{mix}] payload={self.payload_bytes}B "
                f"min_delta={self.min_delta:.3f}")


def _assign(bid: int, name: str, elems: int) -> BucketAssignment:
    comp = C.get(name)
    return BucketAssignment(
        bid=bid, compressor=name, elems=elems,
        wire_bytes=int(comp.wire_bytes((elems,))),
        delta=analytic_delta(comp, elems),
    )


def _descent_trajectory(layout: BucketLayout,
                        ladder: List[str]) -> List[Tuple[List[str], int]]:
    """The greedy bit-width descent as a budget-independent trajectory.

    Each iteration downgrades the bucket with the best (bytes saved)/(δ
    lost) ratio one rung down `ladder`; the pick depends only on the
    current rung state, never on the budget — the budget only decides how
    far along the trajectory to stop. Returns the list of
    (bucket→compressor names, total payload bytes) states from "all at
    base" down to "all at the cheapest rung", so every budget (and every
    PlanFamily member) is a prefix cut of ONE descent — which is what
    makes family bit-widths monotone in the participant count for free.
    """
    names = [ladder[0]] * len(layout.buckets)
    rung = [0] * len(layout.buckets)

    def total():
        return sum(_assign(b.bid, names[b.bid], b.size).wire_bytes
                   for b in layout.buckets)

    states = [(list(names), total())]
    while True:
        best, best_score = None, 0.0
        for b in layout.buckets:
            r = rung[b.bid]
            if r + 1 >= len(ladder):
                continue
            cur = _assign(b.bid, ladder[r], b.size)
            nxt = _assign(b.bid, ladder[r + 1], b.size)
            saved = cur.wire_bytes - nxt.wire_bytes
            lost = max(cur.delta - nxt.delta, 1e-6)
            if saved <= 0:
                continue
            score = saved / lost
            if best is None or score > best_score:
                best, best_score = b.bid, score
        if best is None:
            return states  # every bucket already at the cheapest rung
        rung[best] += 1
        names[best] = ladder[rung[best]]
        states.append((list(names), total()))


def _cut_trajectory(states, budget_bytes: int) -> List[str]:
    """First trajectory state fitting the budget (or the floor state)."""
    for names, payload in states:
        if payload <= budget_bytes:
            return names
    return states[-1][0]


def _warn_floor_overrun(layout, names, ladder, budget_bytes: int) -> None:
    """The descent can bottom out above the budget (every bucket at the
    cheapest rung). That was always silent; since the linf quant ladder's
    floor is 2-bit ternary (vs the legacy 1-bit sign floor) the overrun
    can now be up to 2x — surface it so a too-tight budget_mb is a
    visible modeling decision, not a quiet one."""
    payload = sum(_assign(b.bid, names[b.bid], b.size).wire_bytes
                  for b in layout.buckets)
    if payload > budget_bytes:
        import warnings
        warnings.warn(
            f"delta_budget: the descent floor ({ladder[-1]}) still costs "
            f"{payload} B/step, over the {budget_bytes} B budget — the "
            f"plan ships the floor and overruns the budget",
            stacklevel=3)


def plan_comm(
    layout: BucketLayout,
    base_compressor: str,
    policy: str = "uniform",
    budget_bytes: int = 0,
) -> CommPlan:
    if policy not in POLICIES:
        raise ValueError(f"unknown comm policy {policy!r}; have {POLICIES}")
    if policy == "delta_budget" and budget_bytes <= 0:
        raise ValueError(
            "comm policy 'delta_budget' needs a positive byte budget "
            "(set DQConfig.comm_budget_mb / --comm-budget-mb)")
    C.get(base_compressor)  # fail fast on bad names

    names = [base_compressor] * len(layout.buckets)

    if policy == "size_tiered":
        for b in layout.buckets:
            if all(s.size < SMALL_ELEMS for s in b.slots):
                names[b.bid] = "identity"

    if policy == "delta_budget":
        # linf StochasticQuant bases descend the same-structure 8→4→2-bit
        # ladder (identical payload layout per rung — what makes the
        # adaptive PlanFamily's full-participation member bit-exact with
        # this static plan at any budget); other bases keep the legacy
        # mixed ladder ending in sign.
        try:
            ladder = quant_ladder(base_compressor)
        except ValueError:
            ladder = [base_compressor] + [n for n in LADDER
                                          if n != base_compressor]
        names = _cut_trajectory(_descent_trajectory(layout, ladder),
                                budget_bytes)
        _warn_floor_overrun(layout, names, ladder, budget_bytes)

    assignments = tuple(_assign(b.bid, names[b.bid], b.size)
                        for b in layout.buckets)
    return CommPlan(policy=policy, assignments=assignments,
                    base_compressor=base_compressor)


# --------------------------------------------------------------------------- #
# round-adaptive plan families (DESIGN.md §10)
# --------------------------------------------------------------------------- #
def quant_ladder(base_compressor: str) -> List[str]:
    """The same-structure downgrade ladder for an adaptive family.

    Every rung is a linf `StochasticQuant` with the base's block layout
    and a lower bit-width (8 → 4 → 2), so every family member emits the
    SAME payload pytree (int8 codes + f32 scales, shapes fixed by
    per_block) and the per-round selection reduces to gathering a levels
    scalar from a jit-static table — no `lax.switch` over structurally
    different payloads, no retrace. Raises for bases outside that shape
    (sign/topk/l2 quantizers change the payload structure or the scale
    semantics between rungs).
    """
    base = C.get(base_compressor)
    if not (isinstance(base, C.StochasticQuant) and base.norm == "linf"):
        raise ValueError(
            f"adaptive plan families need a linf StochasticQuant base "
            f"(same-structure bit-width ladder); got {base_compressor!r}")
    out = []
    for bits in (8, 4, 2):
        if bits > base.bits:
            continue
        suffix = (f"block{base.per_block}" if base.per_block > 0 else "linf")
        name = f"qsgd{bits}_{suffix}"
        comp = C.REGISTRY.get(name)
        if (comp is None or not isinstance(comp, C.StochasticQuant)
                or comp.per_block != base.per_block or comp.bits != bits):
            raise ValueError(
                f"adaptive ladder rung {name!r} missing from the "
                f"compressor registry for base {base_compressor!r}")
        out.append(name)
    if out[0] != base_compressor:
        raise ValueError(
            f"adaptive plan families start at a registry 8/4/2-bit rung; "
            f"got base {base_compressor!r}")
    return out


@dataclass(frozen=True)
class PlanFamily:
    """One `CommPlan` per participation count n ∈ {1..n_workers}.

    Built by `plan_family` from one descent trajectory, cut at the
    *effective* per-round budget ``budget_bytes · M / n`` for each n —
    when only n of M workers report, each reporting worker may spend the
    absent workers' share on finer quantization. Because every member is
    a prefix cut of the same trajectory the family is monotone by
    construction: fewer participants ⇒ per-bucket bit-widths
    non-decreasing and min_delta non-increasing in n (finer plans for
    smaller rounds), and every member's payload fits its effective
    budget (or sits at the ladder floor). Frozen/hashable: jit-static.
    """
    plans: Tuple[CommPlan, ...]     # index n-1 → plan for n participants
    n_workers: int
    budget_bytes: int
    base_compressor: str

    def __post_init__(self):
        assert len(self.plans) == self.n_workers

    def plan_for(self, n: int) -> CommPlan:
        if not 1 <= n <= self.n_workers:
            raise ValueError(
                f"participant count {n} outside 1..{self.n_workers}")
        return self.plans[n - 1]

    @property
    def full(self) -> CommPlan:
        """The full-participation (n = M) plan — today's static plan."""
        return self.plans[-1]

    @property
    def n_distinct(self) -> int:
        return len({p.assignments for p in self.plans})

    def effective_budget(self, n: int) -> int:
        return int(self.budget_bytes * self.n_workers / max(n, 1))

    def levels_table(self) -> Tuple[Tuple[int, ...], ...]:
        """(n_workers, n_buckets) quantization level counts — the
        jit-static table the in-step gather dispatches on (row n-1 is
        the plan for n participants)."""
        return tuple(
            tuple(C.get(a.compressor).levels for a in p.assignments)
            for p in self.plans)

    def bits_table(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(
            tuple(C.get(a.compressor).bits for a in p.assignments)
            for p in self.plans)

    def diff(self, other: "PlanFamily") -> List[str]:
        """Field-level differences, naming the participation count whose
        sub-plan differs (the strategy-CLI / resume-guard rendering)."""
        out = []
        if self.n_workers != other.n_workers:
            out.append(f"plan_family.n_workers: {self.n_workers} != "
                       f"{other.n_workers}")
        if self.budget_bytes != other.budget_bytes:
            out.append(f"plan_family.budget_bytes: {self.budget_bytes} != "
                       f"{other.budget_bytes}")
        for n in range(1, min(self.n_workers, other.n_workers) + 1):
            a, b = self.plan_for(n), other.plan_for(n)
            if len(a.assignments) != len(b.assignments):
                out.append(
                    f"plan_family[n={n}]: {len(a.assignments)} buckets "
                    f"!= {len(b.assignments)} buckets (different layouts)")
                continue
            for aa, bb in zip(a.assignments, b.assignments):
                if aa.compressor != bb.compressor:
                    out.append(
                        f"plan_family[n={n}].bucket{aa.bid}: "
                        f"{aa.compressor!r} != {bb.compressor!r}")
        return out

    def describe(self) -> str:
        cuts = " | ".join(
            f"n={n}:{self.plan_for(n).payload_bytes}B"
            for n in range(1, self.n_workers + 1))
        return (f"family[{self.n_workers}] base={self.base_compressor} "
                f"budget={self.budget_bytes}B distinct={self.n_distinct} "
                f"({cuts})")


def plan_family(
    layout: BucketLayout,
    base_compressor: str,
    budget_bytes: int,
    n_workers: int,
) -> PlanFamily:
    """Precompute the delta_budget plan for every participation count.

    One `_descent_trajectory` walk; member n is the first trajectory
    state fitting ``budget_bytes · M / n``. Monotonicity (fewer
    participants ⇒ finer or equal bits everywhere) holds because smaller
    n ⇒ larger effective budget ⇒ an earlier (finer) prefix cut of the
    same descent.
    """
    if budget_bytes <= 0:
        raise ValueError(
            "plan_family needs a positive per-round byte budget")
    M = max(n_workers, 1)
    ladder = quant_ladder(base_compressor)
    states = _descent_trajectory(layout, ladder)
    # the n = M member has the tightest effective budget; if even the
    # floor overruns it, say so once for the whole family
    _warn_floor_overrun(layout, _cut_trajectory(states, budget_bytes),
                        ladder, budget_bytes)
    plans = []
    for n in range(1, M + 1):
        eff = int(budget_bytes * M / n)
        names = _cut_trajectory(states, eff)
        plans.append(CommPlan(
            policy="delta_budget",
            assignments=tuple(_assign(b.bid, names[b.bid], b.size)
                              for b in layout.buckets),
            base_compressor=base_compressor))
    return PlanFamily(plans=tuple(plans), n_workers=M,
                      budget_bytes=int(budget_bytes),
                      base_compressor=base_compressor)
