"""Wire-byte telemetry (DESIGN.md §3.3).

`CommLedger` answers "how many bytes did this step actually move?" without
a host callback in the hot loop: every payload shape is static, so the
per-step cost of each exchange is computable once at plan time from the
*real* payload structure (codes + scales + phase-2 EF re-quantization),
then accumulated host-side as the training loop ticks.

Two byte counts are kept per entry:

  wire_bytes    : analytic bits-on-the-wire (Compressor.wire_bytes × the
                  strategy's collective multiplier) — what an optimal wire
                  format costs; matches benchmarks' modeled numbers.
  carried_bytes : bytes of the payload buffers the collectives actually
                  move (via jax.eval_shape over Compressor.compress) —
                  e.g. sign codes ride in int8, 8× the 1-bit wire model.

For int8 quantizers the two coincide; divergence is the packing headroom
a custom wire format would recover.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressors as C
from repro.core import exchange as X

from .buckets import BucketLayout
from .planner import CommPlan, analytic_delta


# --------------------------------------------------------------------------- #
# static payload measurement
# --------------------------------------------------------------------------- #
def payload_nbytes(comp: C.Compressor, shape) -> int:
    """Bytes of the buffers comp.compress emits for one tensor (codes +
    scales + indices ...), measured from abstract shapes — no FLOPs run."""
    v = jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
    payload = jax.eval_shape(lambda x: comp.compress(x, jax.random.key(0)), v)
    return int(sum(math.prod(p.shape) * jnp.dtype(p.dtype).itemsize
                   for p in jax.tree.leaves(payload)))


def strategy_wire_bytes(strategy: str, comp: C.Compressor, shape,
                        n_workers: int, carried: bool = False) -> float:
    """Per-worker send+receive bytes for one tensor under a strategy.
    Mirrors exchange.modeled_wire_bytes; ``carried`` swaps the analytic
    compressor model for measured payload buffer sizes."""
    if not carried:
        return X.modeled_wire_bytes(strategy, comp, shape, n_workers)
    d = math.prod(shape)
    W = n_workers
    cb = payload_nbytes(comp, shape)
    if strategy in ("exact", "sim"):
        return 2 * (W - 1) / W * 4 * d     # float ring all-reduce either way
    if strategy == "allgather":
        return cb + (W - 1) * cb
    if strategy == "two_phase":
        return 2 * (W - 1) / W * cb
    raise ValueError(strategy)


# --------------------------------------------------------------------------- #
# the ledger
# --------------------------------------------------------------------------- #
@dataclass
class LedgerEntry:
    tag: str
    strategy: str
    compressor: str
    elems: int
    n_workers: int
    wire_bytes: float
    carried_bytes: float
    fallback: bool = False
    bucket: int = -1         # comm-bucket id; -1 for per-tensor leaves
    skipped: bool = False    # sharded leaf bypassing buckets (per-tensor)


@dataclass
class CommLedger:
    """Accumulates per-step wire cost. Register entries once (at plan
    time), then ``tick()`` each training step; read ``summary()``.

    Schedule-aware (repro.sched, DESIGN.md §5): steps and exchange rounds
    are tracked separately — under ``local_k`` only 1-in-K steps moves
    bytes, so cumulative wire cost follows ``rounds``, not ``steps``. The
    host may also feed the simulated wall clock (``sched.clock``) through
    ``tick(wall_s=...)`` so log rows carry a time axis.

    Participation-aware (DESIGN.md §10.3): ``tick(participants=n)`` bills
    the round at the bytes the n reporting workers actually moved — the
    fleet-average (n/M)·(per-participant payload), with the payload taken
    from the round-adaptive ``family`` member the step really selected
    when one is attached (previously every round was billed as if all M
    workers shipped the full-M plan)."""
    entries: List[LedgerEntry] = field(default_factory=list)
    steps: int = 0
    rounds: int = 0          # exchange rounds actually executed
    sim_clock_s: float = 0.0  # accumulated simulated wall clock
    n_workers: int = 0       # fleet size M (0 = unknown, scaling off)
    family: Optional[object] = None   # planner.PlanFamily | None
    cum_wire: float = 0.0    # participation-aware cumulative bytes
    cum_carried: float = 0.0
    budget_bytes: float = 0.0  # delta_budget payload target/worker (0 = none)
    last_participants: Optional[int] = None
    _round_memo: dict = field(default_factory=dict, repr=False)

    # -- registration ------------------------------------------------------- #
    def register(self, tag, strategy, comp: C.Compressor, shape,
                 n_workers: int, fallback: bool = False, bucket: int = -1,
                 skipped: bool = False, wire_bytes: Optional[float] = None,
                 carried_bytes: Optional[float] = None):
        """Record one per-step exchange entry; explicit wire/carried byte
        overrides let composite exchanges (fsdp RS+AG) bill their real
        two-leg cost instead of the single-collective model."""
        self.entries.append(LedgerEntry(
            tag=tag, strategy=strategy, compressor=comp.name,
            elems=math.prod(shape), n_workers=n_workers,
            wire_bytes=(strategy_wire_bytes(strategy, comp, shape, n_workers)
                        if wire_bytes is None else wire_bytes),
            carried_bytes=(strategy_wire_bytes(strategy, comp, shape,
                                               n_workers, carried=True)
                           if carried_bytes is None else carried_bytes),
            fallback=fallback, bucket=bucket, skipped=skipped,
        ))

    @classmethod
    def from_plan(cls, layout: BucketLayout, plan: CommPlan, strategy: str,
                  n_workers: int, base_compressor: str,
                  leaf_plans: Optional[list] = None,
                  family=None, budget_bytes: float = 0.0,
                  moment_compressor: Optional[str] = None) -> "CommLedger":
        """Ledger for the bucketed path: one entry per bucket (its assigned
        compressor) + one per skipped leaf on the per-tensor path.
        ``leaf_plans`` are the exchange.plan_leaf dicts for skipped leaves
        (to account their sim fallbacks faithfully). Without them we cannot
        re-derive the real plan — skipped leaves are skipped *because* they
        are sharded, and the spec is gone from the layout — so we account
        them conservatively as sim fallbacks (full-precision wire).
        ``family`` attaches the round-adaptive PlanFamily so ticks billed
        at participants=n re-price the buckets under the selected plan;
        ``budget_bytes`` the delta_budget payload target so per-bucket
        rows can report utilization against the effective budget.
        ``moment_compressor`` marks the fsdp layout: each bucket is
        billed for both legs (gradient reduce-scatter + moments/param
        all-gather, exchange.modeled_fsdp_wire_bytes) instead of one
        replicated collective."""
        if not budget_bytes and family is not None:
            budget_bytes = float(getattr(family, "budget_bytes", 0) or 0)
        led = cls(n_workers=max(n_workers, 1), family=family,
                  budget_bytes=float(budget_bytes))
        W = max(n_workers, 2)  # collective multipliers degenerate at W=1
        mom = C.get(moment_compressor) if moment_compressor else None
        for b, a in zip(layout.buckets, plan.assignments):
            comp = C.get(a.compressor)
            wire = carried = None
            if mom is not None:
                wire = X.modeled_fsdp_wire_bytes(
                    strategy, comp, mom, (b.size,), W)
                f = (W - 1) / W
                carried = f * ((4 * b.size if strategy == "exact"
                                else payload_nbytes(comp, (b.size,)))
                               + payload_nbytes(mom, (b.size,)))
            led.register(f"bucket/{b.bid}", strategy, comp,
                         (b.size,), W, bucket=b.bid,
                         wire_bytes=wire, carried_bytes=carried)
        base = C.get(base_compressor)
        for i, s in enumerate(layout.skipped):
            if leaf_plans:
                lp = leaf_plans[i]
            else:
                lp = {"strategy": "sim" if strategy == "two_phase"
                      else strategy,
                      "fallback": strategy == "two_phase"}
            led.register(f"leaf{s.path}", lp["strategy"], base, s.shape, W,
                         fallback=lp.get("fallback", False), skipped=True)
        return led

    @classmethod
    def from_tree(cls, strategy: str, comp_name: str, shapes_tree,
                  specs_tree, n_workers: int) -> "CommLedger":
        """Ledger for the seed per-tensor path (comm_plan='none')."""
        led = cls(n_workers=max(n_workers, 1))
        W = max(n_workers, 2)
        is_shape = (lambda x: isinstance(x, tuple)
                    and all(isinstance(i, int) for i in x))
        if specs_tree is None:
            from jax.sharding import PartitionSpec as P
            specs_tree = jax.tree.map(lambda _: P(), shapes_tree,
                                      is_leaf=is_shape)
        plans = X.plan_for_tree(strategy, shapes_tree, specs_tree, n_workers)
        comp = C.get(comp_name)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            shapes_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, int) for i in x))
        plan_leaves = jax.tree.leaves(
            plans, is_leaf=lambda x: isinstance(x, dict) and "strategy" in x)
        for (path, shape), lp in zip(flat, plan_leaves):
            led.register(f"leaf{jax.tree_util.keystr(path)}",
                         lp["strategy"], comp, shape, W,
                         fallback=lp.get("fallback", False))
        return led

    # -- accumulation ------------------------------------------------------- #
    def round_bytes(self, participants: Optional[int] = None):
        """(wire, carried) bytes one exchange round moves, fleet-averaged
        per worker. With ``participants=n < M`` only n workers ship a
        payload — and under an attached PlanFamily they ship the n-member
        plan (finer bits, effective budget B·M/n), not the full-M plan."""
        n, M = participants, self.n_workers
        if n is None or not M or n >= M:
            return self.wire_bytes_per_step, self.carried_bytes_per_step
        hit = self._round_memo.get(n)
        if hit is not None:
            return hit
        frac = n / M
        plan = self.family.plan_for(n) if self.family is not None else None
        wire = carried = 0.0
        for e in self.entries:
            if plan is not None and e.bucket >= 0:
                comp = C.get(plan.assignments[e.bucket].compressor)
            else:
                comp = C.get(e.compressor)
            wire += frac * strategy_wire_bytes(
                e.strategy, comp, (e.elems,), e.n_workers)
            carried += frac * strategy_wire_bytes(
                e.strategy, comp, (e.elems,), e.n_workers, carried=True)
        self._round_memo[n] = (wire, carried)
        return wire, carried

    def tick(self, n: int = 1, exchanged: bool = True, wall_s: float = 0.0,
             participants: Optional[int] = None):
        """Advance `n` steps. ``exchanged=False`` records local (mid-round)
        steps that moved no bytes; ``wall_s`` adds simulated wall clock;
        ``participants`` bills the round(s) at the bytes the reporting
        workers actually moved (round_bytes)."""
        self.steps += n
        if exchanged:
            self.rounds += n
            w, c = self.round_bytes(participants)
            self.cum_wire += n * w
            self.cum_carried += n * c
        if participants is not None:
            self.last_participants = participants
        self.sim_clock_s += wall_s

    # -- readouts ----------------------------------------------------------- #
    @property
    def wire_bytes_per_step(self) -> float:
        return sum(e.wire_bytes for e in self.entries)

    @property
    def carried_bytes_per_step(self) -> float:
        return sum(e.carried_bytes for e in self.entries)

    @property
    def raw_bytes_per_step(self) -> float:
        """What the exact (f32 ring all-reduce) exchange would move."""
        total = 0.0
        for e in self.entries:
            total += strategy_wire_bytes(
                "exact", C.get("identity"), (e.elems,), e.n_workers)
        return total

    @property
    def cumulative_wire_bytes(self) -> float:
        return self.cum_wire

    @property
    def compression_ratio(self) -> float:
        w = self.wire_bytes_per_step
        return self.raw_bytes_per_step / w if w else 1.0

    def n_fallbacks(self) -> int:
        return sum(1 for e in self.entries if e.fallback)

    def skipped_leaves(self) -> Tuple[int, float]:
        """(count, wire bytes/step) of sharded leaves that bypassed the
        bucket pipeline onto the per-tensor path — the silent cost the
        train-log warning surfaces (conservatively full-precision unless
        leaf_plans said otherwise)."""
        hits = [e for e in self.entries if e.skipped]
        return len(hits), sum(e.wire_bytes for e in hits)

    def effective_budget(self, participants: Optional[int] = None) -> float:
        """The per-participant payload budget of a round: B at full
        participation, B·M/n when only n of M workers report (the
        round-adaptive re-spend, DESIGN.md §10). 0 when no budget."""
        if not self.budget_bytes:
            return 0.0
        n, M = participants, self.n_workers
        if n is None or not M or n >= M:
            return self.budget_bytes
        return self.budget_bytes * M / max(n, 1)

    def per_bucket(self, participants: Optional[int] = None) -> list:
        """One row per comm bucket — bits / payload / analytic δ /
        utilization vs the effective budget — priced under the plan the
        round actually selected (the PlanFamily member for
        ``participants=n``, else the static full plan). obs/report.py
        and PlanFamily debugging read these instead of re-deriving."""
        n, M = participants, self.n_workers
        plan = None
        if (n is not None and M and n < M and self.family is not None):
            plan = self.family.plan_for(n)
        eff = self.effective_budget(participants)
        rows = []
        for e in self.entries:
            if e.bucket < 0:
                continue
            name = (plan.assignments[e.bucket].compressor if plan is not None
                    else e.compressor)
            comp = C.get(name)
            payload = int(comp.wire_bytes((e.elems,)))
            row = {
                "bucket": e.bucket,
                "compressor": name,
                "bits": getattr(comp, "bits", None),
                "elems": e.elems,
                "payload_bytes": payload,
                "wire_bytes": round(strategy_wire_bytes(
                    e.strategy, comp, (e.elems,), e.n_workers), 1),
                "delta": round(analytic_delta(comp, e.elems), 4),
            }
            if eff:
                # this bucket's spend as a fraction of the round budget;
                # the rows sum to the round's budget utilization
                row["budget_share"] = round(payload / eff, 4)
            rows.append(row)
        return rows

    def summary(self) -> dict:
        out = {
            "steps": self.steps,
            "rounds": self.rounds,
            "sim_clock_s": round(self.sim_clock_s, 4),
            "wire_bytes_per_step": round(self.wire_bytes_per_step),
            "carried_bytes_per_step": round(self.carried_bytes_per_step),
            "raw_bytes_per_step": round(self.raw_bytes_per_step),
            "cumulative_wire_bytes": round(self.cumulative_wire_bytes),
            "compression_ratio": round(self.compression_ratio, 2),
            "n_entries": len(self.entries),
            "n_fallbacks": self.n_fallbacks(),
        }
        n_skip, skip_bytes = self.skipped_leaves()
        if n_skip:
            out["skipped_leaves"] = n_skip
            out["skipped_leaf_bytes_per_step"] = round(skip_bytes)
        if self.last_participants is not None:
            out["participants"] = self.last_participants
        rows = self.per_bucket(self.last_participants)
        if rows:
            out["per_bucket"] = rows
            if self.budget_bytes:
                eff = self.effective_budget(self.last_participants)
                out["budget_bytes"] = round(self.budget_bytes)
                out["budget_utilization"] = round(
                    sum(r["payload_bytes"] for r in rows) / eff, 4)
        return out
