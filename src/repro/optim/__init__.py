"""Optimizer subsystem. The distributed optimizers (OMD / optimistic Adam /
Adam / SGD with the quantized exchange) live in `repro.core.dqgan` — this
module exposes single-machine transforms used by tests, examples, and the
GAN baselines, in a tiny optax-like (init_fn, update_fn) interface."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable   # params -> state
    update: callable  # (grads, state, params) -> (new_params, new_state)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda w: jnp.zeros_like(w, jnp.float32), params)
        return {"m": z, "v": z, "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        new = jax.tree.map(
            lambda w, m_, v_: w - (lr * (m_ / (1 - b1**tf))
                                   / (jnp.sqrt(v_ / (1 - b2**tf)) + eps)
                                   ).astype(w.dtype),
            params, m, v,
        )
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def oadam(lr, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    """Optimistic Adam (Daskalakis et al. 2018): w ← w − η(2 d_t − d_{t−1})."""
    base = adam(lr, b1, b2, eps)

    def init(params):
        st = base.init(params)
        st["prev"] = jax.tree.map(lambda w: jnp.zeros_like(w, jnp.float32),
                                  params)
        return st

    def update(grads, state, params):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        d = jax.tree.map(
            lambda m_, v_: (m_ / (1 - b1**tf))
            / (jnp.sqrt(v_ / (1 - b2**tf)) + eps),
            m, v,
        )
        new = jax.tree.map(
            lambda w, d_, p: w - (lr * (2 * d_ - p)).astype(w.dtype),
            params, d, state["prev"],
        )
        return new, {"m": m, "v": v, "t": t, "prev": d}

    return Optimizer(init, update)


def cosine_lr(base_lr, warmup, total):
    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)

    return schedule


REGISTRY = {"sgd": sgd, "adam": adam, "oadam": oadam}
