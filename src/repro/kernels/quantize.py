"""Pallas TPU kernel: fused error-feedback + stochastic int8 quantization.

This is the paper's per-step compute hot spot: every gradient element is
read, compensated (m = g + e), scaled, stochastically rounded to an int8
level, and the fresh residual written back — ~13 bytes of HBM traffic per
element when unfused (g, e reads; codes, scale, e' writes — plus the jnp
intermediates). The fused kernel does one VMEM-resident pass:

    per (BR, C) tile:  m = g + e
                       s = rowmax(|m|)
                       q = floor(m/s*L) + (rand < frac)     (stochastic)
                       e' = m - q*s/L

Tiles are (BR, C) with C a multiple of 128 (lane width) and BR a multiple
of 8 (sublane) — MXU/VPU-aligned per the TPU tiling rules. Randomness is
passed in as a uniform tensor so the kernel is bit-reproducible on CPU
(interpret=True) and TPU alike; on TPU the pltpu PRNG could generate it
in-kernel (saves one read stream — noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qef_body(g, e, r, levels, e_dtype):
    """Shared tile body: EF add, row scale, stochastic round, residual.
    `levels` is a Python int (static kernel) or an f32 scalar read from
    the dynamic-levels operand (PlanFamily dispatch, DESIGN.md §10)."""
    m = g.astype(jnp.float32) + e.astype(jnp.float32)
    s = jnp.max(jnp.abs(m), axis=1, keepdims=True) + 1e-20   # (BR, 1)
    lv = m / s * levels
    low = jnp.floor(lv)
    up = (r < (lv - low)).astype(jnp.float32)
    q = low + up
    return q.astype(jnp.int8), s, (m - q * (s / levels)).astype(e_dtype)


def _quantize_ef_kernel(g_ref, e_ref, r_ref, codes_ref, scale_ref, enew_ref,
                        *, levels: int):
    codes, s, e_new = _qef_body(g_ref[...], e_ref[...], r_ref[...], levels,
                                enew_ref.dtype)
    codes_ref[...] = codes
    scale_ref[...] = s
    enew_ref[...] = e_new


def _quantize_ef_kernel_dyn(g_ref, e_ref, r_ref, lv_ref, codes_ref,
                            scale_ref, enew_ref):
    """Dynamic-levels variant: the level count arrives as a (1, 1) f32
    operand (a gather from the PlanFamily's stacked bit-width table), so
    one compiled kernel serves every member of an adaptive family."""
    codes, s, e_new = _qef_body(g_ref[...], e_ref[...], r_ref[...],
                                lv_ref[0, 0], enew_ref.dtype)
    codes_ref[...] = codes
    scale_ref[...] = s
    enew_ref[...] = e_new


def quantize_ef_blocked(g, e, rand, *, levels=127, block_rows: int = 256,
                        interpret: bool = True):
    """g, e, rand: (R, C) with C % 128 == 0 and R % block_rows == 0.
    Returns (codes int8 (R,C), scales f32 (R,1), e_new (R,C)).

    ``levels`` may be a Python int (baked into the kernel — the original
    path, compiled graph unchanged) or a traced scalar (routed through
    the dynamic-levels kernel as a (1, 1) operand)."""
    R, C = g.shape
    assert C % 128 == 0, f"lane-align C to 128, got {C}"
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    grid = (R // br,)

    def idx(i):
        return (i, 0)

    out_specs = [
        pl.BlockSpec((br, C), idx),
        pl.BlockSpec((br, 1), idx),
        pl.BlockSpec((br, C), idx),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((R, C), jnp.int8),
        jax.ShapeDtypeStruct((R, 1), jnp.float32),
        jax.ShapeDtypeStruct((R, C), e.dtype),
    ]
    tile = pl.BlockSpec((br, C), idx)
    if isinstance(levels, int):
        kernel = functools.partial(_quantize_ef_kernel, levels=levels)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[tile, tile, tile],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(g, e, rand)
    lv = jnp.asarray(levels, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _quantize_ef_kernel_dyn,
        grid=grid,
        in_specs=[tile, tile, tile,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, e, rand, lv)


def bucket_tile_shape(n: int):
    """(R, C, block_rows) tiling for a flat comm bucket of n elements.
    buckets.build_layout pads every bucket to a multiple of
    n_workers·LANE·SUBLANE = n_workers·1024, so C = 1024 always divides; the
    block-row count is the largest divisor of R up to 256."""
    C = 1024 if n % 1024 == 0 else 128
    assert n % C == 0, f"bucket size {n} not lane-aligned"
    R = n // C
    br = min(256, R)
    while R % br:
        br -= 1
    return R, C, br


def quantize_ef_flat(g, e, rand, *, levels=127, interpret: bool = True):
    """Fused quantize+EF over a flat comm bucket (1-D, lane-aligned size).

    Tiles the bucket as (R, 1024) rows — each row is one scale block, i.e.
    the bucket-shaped equivalent of StochasticQuant(bits=8, per_block=1024)
    with the residual update fused into the same VMEM pass.
    Returns (codes (n,) int8, scales (R,) f32, e_new (n,))."""
    n = g.shape[0]
    R, C, br = bucket_tile_shape(n)
    codes, scale, e_new = quantize_ef_blocked(
        g.reshape(R, C), e.reshape(R, C), rand.reshape(R, C),
        levels=levels, block_rows=br, interpret=interpret)
    return codes.reshape(n), scale.reshape(R), e_new.reshape(n)
