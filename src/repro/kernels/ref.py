"""Pure-jnp oracles for the Pallas kernels (the allclose reference in
tests/test_kernels.py)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def quantize_ef_ref(g, e, rand, levels: int = 127):
    """Fused error-feedback stochastic quantization, per-row scales.

    g, e, rand: (R, C) float32 (rand uniform in [0,1)).
    Returns (codes int8, scale (R,1) f32, e_new f32) with
        m      = g + e
        scale  = max(|m|, axis=1)
        codes  = stochastic_round(m / scale * levels)
        e_new  = m - codes * scale / levels
    """
    m = g.astype(jnp.float32) + e.astype(jnp.float32)
    scale = jnp.max(jnp.abs(m), axis=1, keepdims=True) + 1e-20
    lv = m / scale * levels
    low = jnp.floor(lv)
    codes = (low + (rand < (lv - low))).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * (scale / levels)
    return codes, scale, m - deq


def paged_attention_ref(q, pool_k, pool_v, table, lengths):
    """Oracle for kernels.flash_attention.paged_flash_attention: gather the
    block pool through the table into a dense per-row view, mask by length,
    plain softmax.

    q: (B, K, G, D); pool_k/v: (NB, bs, K, D); table: (B, MAXB) int32;
    lengths: (B,). Returns (B, K, G, D)."""
    B, Kh, G, D = q.shape
    bs = pool_k.shape[1]
    S = table.shape[1] * bs
    ck = pool_k[table].reshape(B, S, Kh, D).astype(jnp.float32)
    cv = pool_v[table].reshape(B, S, Kh, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), ck)
    s = s / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with length 0 have an all-masked softmax (uniform probs); zero
    # them explicitly to match the kernel's empty-loop output
    p = jnp.where(lengths[:, None, None, None] > 0, p, 0.0)
    return jnp.einsum("bkgs,bskd->bkgd", p, cv).astype(q.dtype)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Plain softmax attention. q,k,v: (B, S, H, D) (same H for k/v)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
