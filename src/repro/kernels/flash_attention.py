"""Pallas TPU kernel: causal flash attention (online softmax, tiled Q/K).

The long-context prefill hot spot of the assigned architectures. Grid is
(batch*heads, Sq/BQ); each program streams KV tiles of size BK through
VMEM keeping the running (max, sumexp, acc) triple — O(S) memory instead
of O(S²). Tile sizes are MXU-aligned (BQ, BK multiples of 128; head_dim
padded to 128 lanes by the wrapper in ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  causal: bool, sm_scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (BQ, D)
    S = k_ref.shape[1]
    nk = S // bk

    def body(carry, j):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bk, bk, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bk, bk, 0)
        s = q @ k.astype(jnp.float32).T                   # (BQ, BK)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return (m_new, l_new, acc), None

    # iterate KV tiles up to (and including) the diagonal tile when causal
    upper = nk if not causal else jnp.minimum(((qi + 1) * bq + bk - 1) // bk, nk)
    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[1]), jnp.float32)

    def scan_body(j, carry):
        new_carry, _ = body(carry, j)
        return new_carry

    m, l, acc = jax.lax.fori_loop(0, upper, scan_body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, D) — batch*heads flattened, same kv heads as q.
    Returns (BH, S, D)."""
    BH, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    sm_scale = 1.0 / math.sqrt(D)
    grid = (BH, S // bq)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
