"""Pallas TPU kernel: causal flash attention (online softmax, tiled Q/K).

The long-context prefill hot spot of the assigned architectures. Grid is
(batch*heads, Sq/BQ); each program streams KV tiles of size BK through
VMEM keeping the running (max, sumexp, acc) triple — O(S) memory instead
of O(S²). Tile sizes are MXU-aligned (BQ, BK multiples of 128; head_dim
padded to 128 lanes by the wrapper in ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  causal: bool, sm_scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (BQ, D)
    S = k_ref.shape[1]
    nk = S // bk

    def body(carry, j):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bk, bk, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bk, bk, 0)
        s = q @ k.astype(jnp.float32).T                   # (BQ, BK)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return (m_new, l_new, acc), None

    # iterate KV tiles up to (and including) the diagonal tile when causal
    upper = nk if not causal else jnp.minimum(((qi + 1) * bq + bk - 1) // bk, nk)
    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[1]), jnp.float32)

    def scan_body(j, carry):
        new_carry, _ = body(carry, j)
        return new_carry

    m, l, acc = jax.lax.fori_loop(0, upper, scan_body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, D) — batch*heads flattened, same kv heads as q.
    Returns (BH, S, D)."""
    BH, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    sm_scale = 1.0 / math.sqrt(D)
    grid = (BH, S // bq)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------- #
# paged-read decode attention (repro.serve KV blocks)
# --------------------------------------------------------------------------- #
def _paged_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, bs: int,
                  sm_scale: float):
    """One (batch row, kv head) program: stream this row's KV blocks
    through the online-softmax triple. The fori_loop upper bound is the
    row's *live* block count (traced), so a short sequence reads only its
    own blocks — the paged win over a dense max_context scan."""
    L = len_ref[0, 0]                                    # row context length
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (G, D)
    nb = (L + bs - 1) // bs

    def body(j, carry):
        m_prev, l_prev, acc = carry
        bid = tab_ref[0, j]
        k = k_ref[pl.ds(bid, 1), :, 0, :][0].astype(jnp.float32)  # (bs, D)
        v = v_ref[pl.ds(bid, 1), :, 0, :][0].astype(jnp.float32)
        s = q @ k.T                                      # (G, bs)
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < L, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    G, D = q.shape
    m0 = jnp.full((G,), -1e30, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    a0 = jnp.zeros((G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nb, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def paged_flash_attention(q, pool_k, pool_v, table, lengths, *,
                          interpret: bool = True):
    """Decode-step attention over a paged KV cache.

    q:       (B, K, G, D) — one query token per row, grouped GQA heads.
    pool_k/v:(NB, bs, K, D) block pools (shared across rows via the table).
    table:   (B, MAXB) int32 — row's logical block i lives in pool block
             table[row, i].
    lengths: (B,) int32 — valid context per row (entries at positions
             >= lengths[row] are masked; rows with length 0 return 0).
    Returns (B, K, G, D).
    """
    B, Kh, G, D = q.shape
    NB, bs = pool_k.shape[0], pool_k.shape[1]
    MAXB = table.shape[1]
    lengths2 = lengths.astype(jnp.int32).reshape(B, 1)
    kernel = functools.partial(_paged_kernel, bs=bs,
                               sm_scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kernel,
        grid=(B, Kh),
        in_specs=[
            pl.BlockSpec((1, MAXB), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kh, G, D), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths2, q, pool_k, pool_v)
