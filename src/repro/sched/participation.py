"""Partial worker participation per exchange round (DESIGN.md §5.3).

Each round, the server averages only a sampled subset of the M workers;
the rest skip the collective entirely and fold their message into the
error-feedback residual instead (so nothing is lost, it just arrives
compressed later — the federated-averaging move, composed with EF).

Sampling is *count-exact*: exactly `n = max(1, round(p·M))` participants
per round, drawn as the first n entries of a seeded permutation. Every
worker derives the identical permutation from the shared round key, so
the mask is consistent across the mesh with no extra collective, and the
rescale `q̂ ← q̂ · M/n` is a static constant.

In-step semantics (implemented by `core.dqgan._exchange_tree`):

    participant     : p̂ = Q(m + e1),  e1 ← m + e1 − p̂      (usual EF)
    non-participant : p̂ = 0,          e1 ← e1 + m          (accumulate)
    server          : q̂ = (M/n) · (1/M) Σ_m p̂^m = (1/n) Σ_participants p̂

Every compressor in the registry maps the zero tensor to a zero payload
(`Q(0) = 0` bitwise), which is what lets non-participants ride through
the unmodified collectives as masked zeros.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PARTICIPATION_SALT = 0x5CED  # keeps the round key clear of other fold_ins


def n_participants(participation: float, n_workers: int) -> int:
    """Static per-round participant count for rate `participation`."""
    if not 0.0 < participation <= 1.0:
        raise ValueError(f"participation must be in (0, 1], got {participation}")
    return max(1, int(round(participation * n_workers)))


def round_key(key, round_idx):
    """The shared (worker-independent) key for one exchange round. Must be
    derived from the pre-worker-fold key so all workers agree."""
    return jax.random.fold_in(jax.random.fold_in(key, PARTICIPATION_SALT),
                              round_idx)


def round_mask(key, round_idx, n_workers: int, n_part: int):
    """(W,) float32 0/1 participation mask for one round — identical on
    every worker. Traceable (round_idx may be a traced step count)."""
    perm = jax.random.permutation(round_key(key, round_idx), n_workers)
    return jnp.zeros((n_workers,), jnp.float32).at[perm[:n_part]].set(1.0)


def round_count(mask_vec):
    """The round's participant count as traced DATA (identical on every
    worker — the mask derives from the shared round key). The adaptive
    PlanFamily (comm.planner, DESIGN.md §10) gathers its per-round
    bit-width row with this index: a different round size selects a
    different table row, never a retrace."""
    return jnp.sum(mask_vec).astype(jnp.int32)


def host_round_participants(rng: np.random.RandomState, n_workers: int,
                            n_part: int) -> np.ndarray:
    """Host-side sampling for the wall-clock model (numpy, independent of
    the jax draw — the clock only needs *a* count-exact sample, not the
    same one the training step used). Returns sorted participant indices."""
    return np.sort(rng.permutation(n_workers)[:n_part])
