"""Versioned parameter server: bounded-staleness push/pull (DESIGN.md §8).

The paper states its convergence and linear-speedup results in the
parameter-server model, but PR 2's runtime only covered lockstep
collectives with at most one step of staleness. This module is the
server-side half of the τ>1 generalization:

  * `VersionedServer` — the host-side semantics object. The server holds
    parameters at an integer version (one version per applied round);
    each worker `pull`s the current version, computes, and `push`es a
    message tagged with its pull version. A push whose staleness
    (server version − pull version) exceeds τ violates the bounded-
    staleness contract and raises — the scheduler (or the SSP gate in
    `simulate_push_pull`) must block the worker first.

  * `simulate_push_pull` — the event-driven wall-clock model behind
    `sched.clock`'s ``server`` dataflow. Workers run at their own seeded
    straggler pace; round r's aggregate becomes available t_exchange
    after its last participant pushed; worker m may start local step s
    only once round s−τ−1 has been applied (the SSP gate), which bounds
    every applied contribution's staleness by τ. Larger τ gives
    stragglers more slack to absorb (wall-clock win) at the price of
    staler contributions (convergence loss) — the frontier
    `benchmarks.run --only sched` sweeps.

The in-step dataflow that mirrors this on the SPMD mesh — the pending
ring buffer and per-worker version vector under `DQState.sched` — lives
in `core.dqgan`; both sides agree that steady-state staleness is exactly
τ under full participation, and that a skipped round extends a worker's
staleness (content clamped at τ by folding ring overflow into EF).

Everything here is host-side numpy, deterministic in (times, τ, seed).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Set

import numpy as np

from . import participation as part


class StalenessBoundExceeded(RuntimeError):
    """A push violated the bounded-staleness contract (staleness > τ)."""


@dataclass
class VersionedServer:
    """Versioned parameter store, one version per applied round.

    Rounds aggregate: round r applies (version r → r+1) once `n_round`
    DISTINCT workers have pushed into it (a duplicate push from the same
    worker lands in the same round's aggregate and does not advance the
    round). `pull` hands out the current version; `push` validates the
    bounded-staleness contract.
    """
    n_workers: int
    tau: int
    n_round: Optional[int] = None     # pushes per round (participation); M
    version: int = 0                  # applied rounds so far
    # derived in __post_init__ — not constructor arguments
    pull_versions: List[int] = field(default_factory=list, init=False)
    push_counts: List[int] = field(default_factory=list, init=False)
    _round_pushed: Set[int] = field(default_factory=set, init=False)

    def __post_init__(self):
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.n_round is None:
            self.n_round = self.n_workers
        if not 1 <= self.n_round <= self.n_workers:
            raise ValueError(f"n_round must be in [1, {self.n_workers}]")
        self.pull_versions = [0] * self.n_workers
        self.push_counts = [0] * self.n_workers

    # ------------------------------------------------------------------ #
    def pull(self, worker: int) -> int:
        """Worker reads the current parameters; returns their version."""
        self.pull_versions[worker] = self.version
        return self.version

    def staleness(self, worker: int) -> int:
        """Versions the worker's last pull is behind the server."""
        return self.version - self.pull_versions[worker]

    def can_push(self, worker: int) -> bool:
        """Would a push from this worker satisfy the τ bound?"""
        return self.staleness(worker) <= self.tau

    def push(self, worker: int) -> int:
        """Apply one message from `worker` (tagged with its last pull
        version). Returns the observed staleness; raises
        StalenessBoundExceeded past τ — the caller must re-pull/block
        first, exactly what the SSP gate in `simulate_push_pull` (and the
        synchronous pipeline in `core.dqgan`) guarantees never happens."""
        stale = self.staleness(worker)
        if stale > self.tau:
            raise StalenessBoundExceeded(
                f"worker {worker} pushed at staleness {stale} > tau={self.tau}"
                " — pull before pushing")
        self.push_counts[worker] += 1
        self._round_pushed.add(worker)
        if len(self._round_pushed) >= self.n_round:
            self._round_pushed.clear()
            self.version += 1
        return stale


# --------------------------------------------------------------------------- #
def simulate_push_pull(times: np.ndarray, t_exchange: float, tau: int,
                       participation: float = 1.0, seed: int = 0) -> dict:
    """Event-driven bounded-staleness PS loop over `times` ((steps, M)
    per-step per-worker compute seconds).

    Dataflow: worker m's step s starts at
        start[s,m] = max(finish[s-1,m], apply[s-1-τ])
    (the SSP gate: the parameters it pulls already contain round s−1−τ,
    so every contribution it pushes lands within τ rounds of its pull);
    round r's aggregate is available at
        apply[r] = max over round-r participants of finish[r,m] + T_ex
    — pushes overlap later compute, only the aggregate's arrival gates.
    Partial participation drops the sampled-out workers from the round's
    max (their message rides EF, as in the in-step runtime).

    Returns the `sched.clock.simulate` dict plus per-step staleness
    statistics (max/mean over applied contributions), with
    max ≤ τ guaranteed by construction under full participation.
    """
    if tau < 1:
        raise ValueError(f"tau must be >= 1, got {tau}")
    steps, M = times.shape
    n_part = part.n_participants(participation, M)
    rng = np.random.RandomState(seed + 2)

    start = np.zeros((steps, M))
    finish = np.zeros((steps, M))
    apply_t = np.zeros(steps)          # round r aggregate available
    part_masks = np.ones((steps, M), bool)
    for s in range(steps):
        if n_part < M:
            part_masks[s] = False
            part_masks[s, part.host_round_participants(rng, M, n_part)] = True
        gate = apply_t[s - 1 - tau] if s - 1 - tau >= 0 else 0.0
        start[s] = np.maximum(finish[s - 1] if s else 0.0, gate)
        finish[s] = start[s] + times[s]
        ready = finish[s][part_masks[s]].max() + t_exchange
        # versions apply IN ORDER: round s's aggregate may be ready before
        # a straggler-gated earlier round (possible under partial
        # participation), but the server only bumps s once every r <= s is
        # applied — this keeps apply_t monotone, which the staleness
        # bookkeeping below (searchsorted) relies on.
        apply_t[s] = max(ready, apply_t[s - 1]) if s else ready

    # staleness of worker m's round-s contribution: s − (rounds applied by
    # its pull at start[s,m]); the gate makes that ≤ τ for participants.
    stale = np.empty((steps, M))
    for m in range(M):
        pulled = np.searchsorted(apply_t, start[:, m], side="right")
        stale[:, m] = np.arange(steps) - np.minimum(pulled, np.arange(steps))
    stale_part = stale[part_masks]

    makespan = finish.max(axis=1)
    per_step = np.diff(np.concatenate([[0.0], makespan]))
    total = float(makespan[-1] + t_exchange) if steps else 0.0  # drain
    return {
        "per_step_s": per_step,
        "total_s": total,
        "mean_step_s": total / max(steps, 1),
        "n_exchanges": steps,
        "tau": tau,
        "staleness_max": float(stale_part.max()) if steps else 0.0,
        "staleness_mean": float(stale_part.mean()) if steps else 0.0,
    }
