"""Exchange schedules: WHEN the workers of Algorithm 2 talk (DESIGN.md §5, §8).

The seed repo ran one lockstep compressed exchange per step. That is one
point in a schedule space that QODA (layer-wise quantized optimistic dual
averaging) and delayed/overlapped extra-gradient methods show is as
decisive for wall-clock time as the bits on the wire. `ExchangeSchedule`
names the point; `core.dqgan` implements the per-step dataflow; this
module holds the host-side arithmetic (which step exchanges, how many
rounds a run has) used by the launcher, the ledger and the wall-clock
model.

Schedules
---------
every_step : exchange at every step — the seed semantics, the default.
local_k    : exchange every K steps. Between rounds the per-worker message
             (η·g, plus EF at compression time) accumulates into
             `DQState.sched["accum"]`; params and server-side state only
             move at round boundaries. `local_k=1` is bit-exact
             `every_step` (the accumulator is 0 + message).
delayed    : bounded-staleness exchange with pipeline depth τ (>= 1).
             Step t compresses and averages the message produced at step
             t-τ — the oldest slot of the `DQState.sched["pending"]` ring
             buffer — while step t's field evaluation proceeds. With
             `exchange.overlap=True` this is a *real* split-phase
             lowering (DESIGN.md §13): the round's collectives are
             started before the field evaluation is traced and finished
             at the stale consume, so XLA's async/latency-hiding
             scheduler can put wire time under compute; on hardware τ
             collectives are in flight at once, each with τ steps of
             compute to hide under. The OMD extrapolation
             subtracts the SUM of the worker's pending (not-yet-applied)
             messages as the staleness correction (the τ-step recursion,
             DESIGN.md §8). τ=1 is PR 2's one-step-stale `delayed`,
             bit-exact (single-slot layout and dataflow preserved).

`is_exchange_step` takes the 0-based step index; with `local_k` the
exchange fires on steps K-1, 2K-1, ... so every round closes with one.

The typed front-end is `repro.strategy.Schedule` (DESIGN.md §9) —
constructors `every_step()`/`local_k(K)`/`delayed(tau)` whose
`.runtime()` resolves to an `ExchangeSchedule` here; the in-step
dataflow (accumulate / ring-shift / staleness correction) lives on that
component, shared by both SPMD paths of `core.dqgan`.
"""
from __future__ import annotations

from dataclasses import dataclass

SCHEDULES = ("every_step", "local_k", "delayed")


@dataclass(frozen=True)
class ExchangeSchedule:
    """A named point in (exchange cadence × staleness) space."""
    name: str
    local_k: int = 1
    tau: int = 1

    def __post_init__(self):
        if self.name not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.name!r}; choose from {SCHEDULES}")
        if self.local_k < 1:
            raise ValueError(f"local_k must be >= 1, got {self.local_k}")
        if self.name != "local_k" and self.local_k != 1:
            raise ValueError(
                f"local_k={self.local_k} only meaningful with the "
                f"'local_k' schedule, not {self.name!r}")
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.name != "delayed" and self.tau != 1:
            raise ValueError(
                f"tau={self.tau} only meaningful with the 'delayed' "
                f"schedule, not {self.name!r}")

    # ------------------------------------------------------------------ #
    @property
    def staleness(self) -> int:
        """Steps between producing a message and applying its average."""
        return self.tau if self.name == "delayed" else 0

    @property
    def period(self) -> int:
        """Steps per exchange round."""
        return self.local_k if self.name == "local_k" else 1

    def is_exchange_step(self, step: int) -> bool:
        """Does 0-based step `step` run the collective?"""
        return (step + 1) % self.period == 0

    def round_index(self, step: int) -> int:
        """Which exchange round 0-based step `step` belongs to."""
        return step // self.period

    def exchanges_in(self, steps: int) -> int:
        """Number of collectives over `steps` training steps."""
        return steps // self.period

    def describe(self) -> str:
        if self.name == "local_k":
            return f"local_k(K={self.local_k})"
        if self.name == "delayed" and self.tau > 1:
            return f"delayed(tau={self.tau})"
        return self.name


def get(name: str, local_k: int = 1, tau: int = 1) -> ExchangeSchedule:
    """Resolve a schedule by name (+ K for 'local_k', τ for 'delayed')."""
    if name == "local_k":
        return ExchangeSchedule("local_k", local_k)
    if name == "delayed":
        return ExchangeSchedule("delayed", tau=tau)
    return ExchangeSchedule(name)


def seeded_tau_vector(tau_max: int, n_workers: int, seed: int = 0) -> tuple:
    """Seeded heterogeneous per-worker pull cadences τ_m ∈ {1..τ_max} for
    `Schedule.delayed(tau_max, tau_vector=...)` — deterministic in
    (τ_max, M, seed), with max(τ_m) pinned to τ_max so the ring depth is
    exactly what the schedule advertises. Mirrors the straggler profiles'
    host-side seeding discipline: the jitted step only ever sees the
    resulting static tuple."""
    import numpy as np
    if tau_max < 1:
        raise ValueError(f"tau_max must be >= 1, got {tau_max}")
    rs = np.random.RandomState(seed)
    taus = rs.randint(1, tau_max + 1, size=n_workers)
    taus[rs.randint(n_workers)] = tau_max  # the ring depth is max τ_m
    return tuple(int(t) for t in taus)
