"""Exchange schedules: WHEN the workers of Algorithm 2 talk (DESIGN.md §5).

The seed repo ran one lockstep compressed exchange per step. That is one
point in a schedule space that QODA (layer-wise quantized optimistic dual
averaging) and delayed/overlapped extra-gradient methods show is as
decisive for wall-clock time as the bits on the wire. `ExchangeSchedule`
names the point; `core.dqgan` implements the per-step dataflow; this
module holds the host-side arithmetic (which step exchanges, how many
rounds a run has) used by the launcher, the ledger and the wall-clock
model.

Schedules
---------
every_step : exchange at every step — the seed semantics, the default.
local_k    : exchange every K steps. Between rounds the per-worker message
             (η·g, plus EF at compression time) accumulates into
             `DQState.sched["accum"]`; params and server-side state only
             move at round boundaries. `local_k=1` is bit-exact
             `every_step` (the accumulator is 0 + message).
delayed    : one-step-stale exchange. Step t compresses and averages the
             message produced at step t-1 (`DQState.sched["pending"]`)
             while step t's field evaluation proceeds — on hardware the
             collective overlaps compute; in the wall-clock model the
             step cost is max(compute, comm) instead of their sum. The
             OMD extrapolation subtracts the worker's own pending
             (not-yet-applied) message as the staleness correction.

`is_exchange_step` takes the 0-based step index; with `local_k` the
exchange fires on steps K-1, 2K-1, ... so every round closes with one.
"""
from __future__ import annotations

from dataclasses import dataclass

SCHEDULES = ("every_step", "local_k", "delayed")


@dataclass(frozen=True)
class ExchangeSchedule:
    """A named point in (exchange cadence × staleness) space."""
    name: str
    local_k: int = 1

    def __post_init__(self):
        if self.name not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.name!r}; choose from {SCHEDULES}")
        if self.local_k < 1:
            raise ValueError(f"local_k must be >= 1, got {self.local_k}")
        if self.name != "local_k" and self.local_k != 1:
            raise ValueError(
                f"local_k={self.local_k} only meaningful with the "
                f"'local_k' schedule, not {self.name!r}")

    # ------------------------------------------------------------------ #
    @property
    def staleness(self) -> int:
        """Steps between producing a message and applying its average."""
        return 1 if self.name == "delayed" else 0

    @property
    def period(self) -> int:
        """Steps per exchange round."""
        return self.local_k if self.name == "local_k" else 1

    def is_exchange_step(self, step: int) -> bool:
        """Does 0-based step `step` run the collective?"""
        return (step + 1) % self.period == 0

    def round_index(self, step: int) -> int:
        """Which exchange round 0-based step `step` belongs to."""
        return step // self.period

    def exchanges_in(self, steps: int) -> int:
        """Number of collectives over `steps` training steps."""
        return steps // self.period

    def describe(self) -> str:
        if self.name == "local_k":
            return f"local_k(K={self.local_k})"
        return self.name


def get(name: str, local_k: int = 1) -> ExchangeSchedule:
    """Resolve a schedule by name (+ K for 'local_k')."""
    if name == "local_k":
        return ExchangeSchedule("local_k", local_k)
    return ExchangeSchedule(name)
