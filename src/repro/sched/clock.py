"""Simulated wall clock: schedule × stragglers × wire bytes (DESIGN.md §5.2).

The paper's linear-speedup claim is about time, but the repo's benchmarks
only modeled the homogeneous lockstep case (`T(M) = T₁/M + T_comm`). This
module composes the three things that actually set the clock:

  * per-worker compute times from a seeded `straggler.StragglerProfile`,
  * per-exchange wire time from `comm.ledger` byte counts over a
    `LinkModel` (bandwidth + per-collective latency),
  * the `ExchangeSchedule` dataflow, which decides what gates what:

      every_step : every step is a barrier over the round's participants,
                   then the collective — cost = max_m(compute) + T_ex.
      local_k    : workers run K steps unsynchronized, barrier once —
                   cost/round = max_m(Σ_K compute) + T_ex. The max of
                   sums is below the sum of maxes (jitter averages out
                   *within* a worker before the barrier), and T_ex is
                   paid once per K.
      delayed    : the collective for step t-1 overlaps compute of step
                   t — cost = max(max_m(compute), T_ex), plus a one-time
                   pipeline fill/drain of T_ex.
      server     : the bounded-staleness push/pull loop (`sched.server`,
                   DESIGN.md §8) — no per-step barrier at all; worker m's
                   step s only waits for round s−1−τ's aggregate. The
                   default for delayed(τ>1); `dataflow="server"` forces
                   it for any τ (the τ∈{1,2,4,8} frontier sweep).

Partial participation gates the barrier on the sampled participants only
(non-participants are assumed to overlap their local work; their later
rounds are not penalized — a deliberate idealization, noted here so the
benchmark numbers are read correctly).

Everything is host-side numpy, deterministic in (profile, M, steps, seed).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import participation as part
from . import straggler as strag
from .schedule import ExchangeSchedule
from .server import simulate_push_pull


@dataclass(frozen=True)
class LinkModel:
    """Per-worker network link: the PS-uplink regime of the paper's Fig 4
    (at NVLink speeds compression — and scheduling — is moot)."""
    bandwidth_Bps: float = 1e9
    latency_s: float = 1e-4      # per-collective constant term

    def exchange_time(self, bytes_per_worker: float) -> float:
        if bytes_per_worker <= 0:
            return 0.0
        return self.latency_s + bytes_per_worker / self.bandwidth_Bps

    @classmethod
    def from_dict(cls, d: dict) -> "LinkModel":
        """Build from a calibration payload (`repro.obs.calibrate` output
        or any dict carrying the two link constants)."""
        return cls(bandwidth_Bps=float(d["bandwidth_Bps"]),
                   latency_s=float(d["latency_s"]))


def load_calibration(path: str):
    """(LinkModel, full payload) from a calibration JSON written by
    ``python -m repro.obs calibrate --out PATH`` (DESIGN.md §12.3). The
    payload carries ``t_compute_s`` and the per-run drift table beyond
    the link constants."""
    import json
    with open(path) as fh:
        d = json.load(fh)
    return LinkModel.from_dict(d), d


def simulate(schedule: ExchangeSchedule, times: np.ndarray,
             t_exchange: float, participation: float = 1.0,
             seed: int = 0, dataflow: str = "auto") -> dict:
    """Walk `times` ((steps, M) per-step per-worker compute seconds)
    through the schedule's dataflow. Returns per-step and total simulated
    seconds plus the exchange count.

    ``dataflow`` picks the cost model: "auto" keeps the synchronous
    models below for every_step/local_k/delayed(1) — unchanged from PR 2
    — and routes delayed(τ>1) to the bounded-staleness push/pull loop;
    "server" forces the push/pull loop (sched.server) for any τ;
    "sync" forces the synchronous pipelined model."""
    if dataflow not in ("auto", "sync", "server"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    if dataflow == "server" and schedule.name != "delayed":
        raise ValueError(
            f"dataflow='server' models the bounded-staleness push/pull "
            f"loop, which only the 'delayed' schedule runs — got "
            f"{schedule.describe()}")
    if dataflow == "server" or (dataflow == "auto"
                                and schedule.name == "delayed"
                                and schedule.tau > 1):
        return simulate_push_pull(times, t_exchange, schedule.tau,
                                  participation, seed)
    steps, M = times.shape
    n_part = part.n_participants(participation, M)
    rng = np.random.RandomState(seed + 2)
    per_step = np.zeros(steps)
    n_exchanges = 0
    K = schedule.period

    for r0 in range(0, steps, K):
        r1 = min(r0 + K, steps)
        if n_part < M:
            who = part.host_round_participants(rng, M, n_part)
        else:
            who = slice(None)
        block = times[r0:r1, who]
        if schedule.name == "local_k":
            # no barrier inside the round: each worker sums its own steps
            gate = float(block.sum(axis=0).max())
            t_ex = t_exchange if r1 - r0 == K else 0.0  # partial tail round
            n_exchanges += r1 - r0 == K
            per_step[r0:r1] = (gate + t_ex) / (r1 - r0)
        elif schedule.name == "delayed":
            gate = float(block.max(axis=1)[0])
            # steady state: comm for the previous step hides under compute
            per_step[r0] = gate if r0 == 0 else max(gate, t_exchange)
            n_exchanges += 1
        else:  # every_step
            per_step[r0] = float(block.max(axis=1)[0]) + t_exchange
            n_exchanges += 1

    total = float(per_step.sum())
    if schedule.name == "delayed" and steps > 0:
        total += t_exchange  # drain the last in-flight collective
    return {
        "per_step_s": per_step,
        "total_s": total,
        "mean_step_s": total / max(steps, 1),
        "n_exchanges": n_exchanges,
    }


def time_per_step(schedule: ExchangeSchedule, profile: strag.StragglerProfile,
                  M: int, steps: int, t_compute_single: float,
                  bytes_per_exchange: float, link: LinkModel = LinkModel(),
                  participation: float = 1.0, seed: int = 0,
                  dataflow: str = "auto") -> dict:
    """Mean simulated seconds/step for M workers splitting a fixed global
    batch (per-worker compute = t_compute_single / M), under `profile`.
    `bytes_per_exchange` is the per-worker wire cost of ONE exchange
    (e.g. `CommLedger.wire_bytes_per_step` or
    `exchange.modeled_wire_bytes`); pass 0 for M == 1."""
    times = strag.step_times(profile, M, steps, seed,
                             base=t_compute_single / M)
    t_ex = link.exchange_time(bytes_per_exchange) if M > 1 else 0.0
    out = simulate(schedule, times, t_ex, participation, seed, dataflow)
    out["t_exchange_s"] = t_ex
    return out


def baseline_mean_step(profile: strag.StragglerProfile, steps: int,
                       t_compute_single: float,
                       link: LinkModel = LinkModel(), seed: int = 0) -> float:
    """Mean seconds/step of the M=1 run (no comm). With one worker every
    schedule degenerates to the same compute-only walk, so this baseline
    is shared across schedules AND compressors — compute it once per
    (profile, steps, t_compute, seed) and pass it to `speedup_vs_M`
    instead of re-simulating it per sweep (the `benchmarks.run --only
    sched` quick tier halves its work this way)."""
    return time_per_step(ExchangeSchedule("every_step"), profile, 1, steps,
                         t_compute_single, 0.0, link, 1.0,
                         seed)["mean_step_s"]


def speedup_vs_M(schedule: ExchangeSchedule, profile: strag.StragglerProfile,
                 Ms, steps: int, t_compute_single: float, bytes_fn,
                 link: LinkModel = LinkModel(), participation: float = 1.0,
                 seed: int = 0, base: float = 0.0,
                 dataflow: str = "auto") -> list:
    """Speedup rows for a worker-count sweep. `bytes_fn(M)` gives the
    per-worker wire bytes of one exchange at that M. The M=1 run (same
    profile, no comm) is the baseline; pass it via `base` (from
    `baseline_mean_step`) when sweeping several schedules/compressors so
    it is not re-simulated once per sweep."""
    if not base:
        base = baseline_mean_step(profile, steps, t_compute_single, link,
                                  seed)
    rows = []
    for M in Ms:
        if M == 1:
            # the baseline IS the M=1 point (no comm, every schedule
            # walks the same compute times) — reuse it, don't re-simulate
            rows.append({
                "M": 1,
                "mean_step_s": base,
                "t_exchange_s": 0.0,
                "n_exchanges": schedule.exchanges_in(steps),
                "speedup": 1.0,
            })
            continue
        sim = time_per_step(schedule, profile, M, steps, t_compute_single,
                            bytes_fn(M), link, participation, seed, dataflow)
        rows.append({
            "M": M,
            "mean_step_s": sim["mean_step_s"],
            "t_exchange_s": sim["t_exchange_s"],
            "n_exchanges": sim["n_exchanges"],
            "speedup": base / sim["mean_step_s"],
        })
    return rows
