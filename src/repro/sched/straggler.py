"""Deterministic per-worker heterogeneity (DESIGN.md §5.2).

A `StragglerProfile` describes how the paper's M machines deviate from
the homogeneous ideal: a persistent per-worker slowdown (lognormal —
some machines are simply slower), per-step multiplicative jitter (OS
noise), and rare transient spikes (GC pauses, preemptions). Everything
is seeded numpy on the host — the jitted training step never sees it;
only the wall-clock model (`sched.clock`) consumes the sampled times.

`step_times(profile, M, steps, seed)` is the whole API surface the clock
needs: a (steps, M) matrix of per-step compute times in units of the
homogeneous per-worker step time.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StragglerProfile:
    name: str
    # sigma of the persistent lognormal per-worker slowdown (0 = homogeneous)
    slowdown_sigma: float = 0.0
    # sigma of the per-step lognormal jitter
    jitter_sigma: float = 0.0
    # probability / magnitude of transient spikes (worker-step granularity)
    spike_prob: float = 0.0
    spike_factor: float = 1.0

    def describe(self) -> str:
        return (f"{self.name}(slowdown_sigma={self.slowdown_sigma}, "
                f"jitter_sigma={self.jitter_sigma}, "
                f"spikes={self.spike_prob}x{self.spike_factor})")


PROFILES = {
    "none": StragglerProfile("none"),
    # a realistic shared-cluster pod: ±15% persistent skew, small jitter,
    # 2% of worker-steps hit a 3x pause
    "mild": StragglerProfile("mild", slowdown_sigma=0.15, jitter_sigma=0.05,
                             spike_prob=0.02, spike_factor=3.0),
    # heterogeneous fleet (mixed generations): heavy persistent skew and
    # frequent long pauses — the regime where lockstep exchange collapses
    "heavy": StragglerProfile("heavy", slowdown_sigma=0.4, jitter_sigma=0.1,
                              spike_prob=0.05, spike_factor=6.0),
}


def get_profile(name: str) -> StragglerProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown straggler profile {name!r}; "
            f"choose from {sorted(PROFILES)}") from None


def worker_slowdowns(profile: StragglerProfile, M: int,
                     seed: int = 0) -> np.ndarray:
    """Persistent per-worker slowdown factors, median-normalized to keep
    the homogeneous compute budget comparable across profiles. Shape (M,)."""
    if profile.slowdown_sigma == 0.0:
        return np.ones(M)
    rs = np.random.RandomState(seed)
    s = np.exp(profile.slowdown_sigma * rs.randn(M))
    return s / np.median(s)


def step_times(profile: StragglerProfile, M: int, steps: int,
               seed: int = 0, base: float = 1.0) -> np.ndarray:
    """(steps, M) per-step per-worker compute times, fully determined by
    (profile, M, steps, seed). `base` is the homogeneous per-worker
    step time (seconds)."""
    rs = np.random.RandomState(seed + 1)
    t = np.full((steps, M), float(base))
    t *= worker_slowdowns(profile, M, seed)[None, :]
    if profile.jitter_sigma:
        t *= np.exp(profile.jitter_sigma * rs.randn(steps, M))
    if profile.spike_prob:
        spikes = rs.rand(steps, M) < profile.spike_prob
        t *= np.where(spikes, profile.spike_factor, 1.0)
    return t
