"""repro.sched — the execution-schedule runtime (DESIGN.md §5, §8).

Decouples "compute a step" from "exchange gradients":

  schedule.py      : ExchangeSchedule — every_step | local_k | delayed(τ).
  server.py        : versioned parameter server — bounded-staleness
                     push/pull semantics + event-driven wall-clock sim.
  participation.py : count-exact partial worker participation per round,
                     with EF accumulation for the workers sitting out.
  straggler.py     : seeded per-worker heterogeneity profiles.
  clock.py         : simulated wall clock composing schedule dataflow,
                     straggler compute times and comm.ledger wire bytes.

`core.dqgan` implements the in-step dataflow for each schedule (state
under `DQState.sched`; delayed(τ) carries a τ-deep pending ring buffer
and a per-worker version vector); `launch.train` drives the host-side
cadence and telemetry; `benchmarks.run --only sched` sweeps schedule ×
compressor × workers under stragglers — plus the τ∈{1,2,4,8}
convergence-vs-staleness-vs-wall-clock frontier — into
experiments/sched.json.
"""
from .clock import (  # noqa: F401
    LinkModel,
    baseline_mean_step,
    simulate,
    speedup_vs_M,
    time_per_step,
)
from .participation import (  # noqa: F401
    host_round_participants,
    n_participants,
    round_count,
    round_key,
    round_mask,
)
from .schedule import (  # noqa: F401
    SCHEDULES,
    ExchangeSchedule,
    get,
    seeded_tau_vector,
)
from .server import (  # noqa: F401
    StalenessBoundExceeded,
    VersionedServer,
    simulate_push_pull,
)
from .straggler import (  # noqa: F401
    PROFILES,
    StragglerProfile,
    get_profile,
    step_times,
    worker_slowdowns,
)
