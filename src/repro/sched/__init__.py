"""repro.sched — the execution-schedule runtime (DESIGN.md §5).

Decouples "compute a step" from "exchange gradients":

  schedule.py      : ExchangeSchedule — every_step | local_k | delayed.
  participation.py : count-exact partial worker participation per round,
                     with EF accumulation for the workers sitting out.
  straggler.py     : seeded per-worker heterogeneity profiles.
  clock.py         : simulated wall clock composing schedule dataflow,
                     straggler compute times and comm.ledger wire bytes.

`core.dqgan` implements the in-step dataflow for each schedule (state
under `DQState.sched`); `launch.train` drives the host-side cadence and
telemetry; `benchmarks.run --only sched` sweeps schedule × compressor ×
workers under stragglers into experiments/sched.json.
"""
from .clock import LinkModel, simulate, speedup_vs_M, time_per_step  # noqa: F401
from .participation import (  # noqa: F401
    host_round_participants,
    n_participants,
    round_key,
    round_mask,
)
from .schedule import SCHEDULES, ExchangeSchedule, get  # noqa: F401
from .straggler import (  # noqa: F401
    PROFILES,
    StragglerProfile,
    get_profile,
    step_times,
    worker_slowdowns,
)
