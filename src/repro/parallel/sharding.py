"""PartitionSpec rules for every parameter/cache/batch tensor.

Two layouts (DESIGN.md §4):

  mode "dp"   (Mode A): params replicated over the data axes, tensor-
               parallel over 'model'. Used when the DQGAN worker axes are
               ('data',) or ('pod','data') — the paper's per-worker
               extrapolation requires replicated parameters.
  mode "fsdp" (Mode B): params sharded over 'data' (ZeRO-3 style) AND
               'model'; DQGAN workers are pods only ('pod',). XLA inserts
               the FSDP all-gathers; the quantized exchange crosses pods.

Rules are by parameter path name:
  column-parallel (output dim on 'model'): q k v gate up in_x in_gate z x
      B C dt W_a W_i unembed fc
  row-parallel (input dim on 'model'):     o down out
  expert-parallel (expert dim on 'model'): gate_proj up_proj down_proj
  vocab-sharded:                           embed
  replicated:                              norms, biases of row-parallel,
                                           conv, scalars, router
Stacked layer params (under 'scan') get a leading None for the L axis —
which is also the two_phase exchange's favourite chunk axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

COL = {"q", "k", "v", "gate", "up", "in_x", "in_gate", "z", "x", "B", "C",
       "dt", "W_a", "W_i", "unembed", "fc"}
ROW = {"o", "down", "out"}
EXPERT = {"gate_proj", "up_proj", "down_proj"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(f"[{p.idx}]")
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return tuple(names)


def param_spec(path, leaf, mode: str) -> P:
    names = _path_names(path)
    in_scan = "scan" in names
    ndim = leaf.ndim
    fsdp = mode == "fsdp"

    def lead(spec_tail):
        """Pad with Nones so the spec has one entry per dim."""
        pad = ndim - len(spec_tail)
        return P(*([None] * pad + list(spec_tail)))

    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gp = names[-3] if len(names) >= 3 else ""

    # --- embeddings -------------------------------------------------------- #
    if name == "embed":
        return P("model", None)
    if name == "pos":
        return P(None, None)
    if parent == "unembed" and name == "w":
        return P(None, "model")

    # --- small/replicated -------------------------------------------------- #
    if name in ("scale", "bias", "lam", "A_log", "D", "dt_bias"):
        return lead(())
    if parent in ("conv", "router") or name == "conv":
        return lead(())

    # --- experts ------------------------------------------------------------ #
    if parent in EXPERT or name in EXPERT:
        which = name if name in EXPERT else parent
        if which == "down_proj":  # (E, ff, d)
            return lead(("model", None, "data" if fsdp else None))
        return lead(("model", "data" if fsdp else None, None))  # (E, d, ff)

    # --- linears {w, b} ------------------------------------------------------ #
    if name == "w":
        if parent in COL:
            return lead(("data" if fsdp else None, "model"))
        if parent in ROW:
            return lead(("model", "data" if fsdp else None))
        return lead(())  # router/fc-like fallback: replicated
    if name == "b":
        if parent in COL:
            return lead(("model",))
        return lead(())

    return lead(())


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop any sharded axis that does not evenly divide its dimension."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ent in zip(shape, entries):
        if ent is None:
            out.append(None)
            continue
        axes = ent if isinstance(ent, tuple) else (ent,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ent if dim % n == 0 else None)
    return P(*out)


def param_specs(params, cfg, mode: str, mesh=None):
    """Spec tree mirroring `params` (arrays or ShapeDtypeStructs)."""
    del cfg

    def one(path, leaf):
        spec = param_spec(path, leaf, mode)
        if mesh is not None:
            spec = sanitize_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------- #
# batches and caches
# --------------------------------------------------------------------------- #
def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh, batch_size: int) -> P:
    axes = batch_axes(mesh)
    n = 1
    chosen = []
    for a in axes:
        if batch_size % (n * mesh.shape[a]) == 0:
            chosen.append(a)
            n *= mesh.shape[a]
    return P(tuple(chosen)) if chosen else P(None)


def cache_spec(path, leaf, mesh, batch_size: int,
               kv_layout: str = "hd_model") -> P:
    """Decode caches: batch over data axes when divisible; KV sharded over
    'model' on head_dim ("hd_model", default — cache holds post-RoPE K so
    this is elementwise-safe, the q·k contraction psums tiny score tensors,
    and the layout feeds the row-parallel output projection directly) or on
    the sequence axis ("seq_model" — the naive layout; XLA replicates the
    cache to reshard it for the einsum, see EXPERIMENTS.md §Perf
    hillclimb 2). Rest replicated."""
    names = _path_names(path)
    in_scan = "scan" in names
    bspec = batch_spec(mesh, batch_size)
    b_axes = bspec[0] if bspec and bspec[0] is not None else None
    model_n = mesh.shape.get("model", 1)
    name = names[-1]
    ndim = leaf.ndim
    off = 1 if in_scan else 0  # leading stacked-period axis

    def build(entries):
        pad = ndim - off - len(entries)
        return P(*([None] * off + list(entries) + [None] * pad))

    if name == "pos" or ndim - off == 0:
        return P(*([None] * ndim))
    if name in ("k", "v"):                     # (B, S, K, hd)
        seq = leaf.shape[off + 1]
        hd = leaf.shape[off + 3]
        if kv_layout == "hd_model" and hd % model_n == 0:
            return build([b_axes, None, None, "model"])
        seq_ax = "model" if seq % model_n == 0 else None
        return build([b_axes, seq_ax])
    if name == "h" and ndim - off == 4:        # ssd state (B, H, P, N)
        heads = leaf.shape[off + 1]
        return build([b_axes, "model" if heads % model_n == 0 else None])
    if name == "h":                            # rglru state (B, w)
        w = leaf.shape[off + 1]
        return build([b_axes, "model" if w % model_n == 0 else None])
    if name == "conv":                          # (B, width-1, C)
        ch = leaf.shape[off + 2]
        return build([b_axes, None, "model" if ch % model_n == 0 else None])
    if name == "enc_out":                       # (B, Se, d)
        return build([b_axes, None, None])
    return P(*([None] * ndim))


def cache_specs(caches, mesh, batch_size: int, kv_layout: str = "hd_model"):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh, batch_size,
                                      kv_layout), caches
    )


def shardings(tree_of_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
