"""jax SPMD API compatibility shims.

The repo targets the modern surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.make_mesh(..., axis_types=...)``); older jax (< 0.5, e.g. a 0.4.x
CPU CI image) spells these ``jax.experimental.shard_map.shard_map`` (with
``auto=`` instead of ``axis_names=``), mesh-as-context-manager, and
meshes without axis types. Import from here instead of feature-detecting
at each call site.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # noqa: F401  (jax >= 0.5)
    _HAS_AXIS_TYPES = True
except ImportError:
    _HAS_AXIS_TYPES = False

    class AxisType:  # minimal stand-in; only the names are consumed
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(shape, axes, axis_types=None):
    """jax.make_mesh that tolerates missing axis_types support."""
    if _HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager making `mesh` ambient. New jax: jax.set_mesh; old
    jax: the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def key_across_boundary(key):
    """(key_to_pass, was_converted). On old jax, typed PRNG keys (extended
    dtype, u32[2] data) fail XLA's sharding validation when crossing a
    partial-auto shard_map boundary; raw uint32 data passes. The body must
    jax.random.wrap_key_data the converted key back."""
    import jax.numpy as jnp

    if hasattr(jax, "shard_map"):
        return key, False
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key), True
    return key, False


def shard_map(f, mesh, in_specs, out_specs, axis_names):
    """shard_map manual over `axis_names`, auto over the rest, replication
    checking off (our worker bodies mix collectives with auto-sharded
    compute, which the checker cannot type)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axis_names),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
