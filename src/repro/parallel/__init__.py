from .sharding import (  # noqa: F401
    batch_axes,
    batch_spec,
    cache_specs,
    param_specs,
    shardings,
)
