"""Step profiler: the measured side of the wall clock (DESIGN.md §12.1).

`sched.clock` *models* the step time; this module *measures* it. A
`StepProfiler` watches the first ``--profile-steps N`` training steps:

* **step walls** — the launcher already brackets every step with
  ``jax.block_until_ready`` (PR 6's honest-timing fix), so the per-step
  wall it hands to `record_step` is a real device-synced measurement,
  not dispatch latency. The profiler keeps the whole window and reports
  mean/min/max/p50 (min ≈ the no-jitter compute+comm floor the
  calibration fit leans on).
* **host phases** — `phase(name)` contexts accumulate wall time per
  host-side phase, keyed by the same canonical span names `tracing`
  uses (``data`` / ``step`` / ``eval``), so a profile event and a
  captured profiler trace name phases identically.
* **device phases** — with spans on, the compiled step's optimized HLO
  carries ``repro.obs/<phase>`` scope names in op metadata;
  `launch.hlo_analysis.scope_costs` turns that into per-phase op counts
  and result bytes (compress / exchange / apply), a device-side cost
  attribution that needs no hardware profiler and runs on host CI.
* **trace capture** — an optional ``jax.profiler.trace`` directory
  brackets the window for TensorBoard-grade attribution on real
  hardware.

The window closes after N recorded steps and `emit` writes ONE
versioned ``profile`` event (schema v2) into the run sink. Everything
here is host-side: profiling on/off cannot perturb the compiled step,
which is why `Observability.profile` stays outside `short_hash()` and
the bit-exactness tests pin the HLO equal either way.
"""
from __future__ import annotations

import time
from contextlib import contextmanager, nullcontext
from typing import Dict, List, Optional

from .tracing import DEVICE_PHASES, HOST_PHASES, PREFIX

DEFAULT_WINDOW = 32


def _stats(xs: List[float]) -> Dict[str, float]:
    ordered = sorted(xs)
    return {
        "mean": sum(xs) / len(xs),
        "min": ordered[0],
        "max": ordered[-1],
        "p50": ordered[len(ordered) // 2],
        "n": len(xs),
    }


def overlap_ratio(walls_on, walls_off, exchange_s: Optional[float] = None
                  ) -> Dict[str, float]:
    """Measured overlap from a paired A/B run: the same strategy timed
    with ``exchange.overlap`` on and off (DESIGN.md §13). ``hidden_s``
    is the step wall the split-phase lowering removed (p50-off minus
    p50-on, clamped at 0 — medians so one compile/jitter outlier cannot
    fake an overlap win). With ``exchange_s`` — the exposed exchange
    wall of the *off* run, e.g. ``t_off - t_compute`` from a
    calibration fit — the result also carries ``hidden_frac`` (the
    fraction of the exchange the scheduler hid; the CI smoke's gate)
    and ``exposed_s`` (what still sits on the critical path).

    Inputs are either full per-step wall lists (profile windows) or
    scalar means (timing events); pure function, no profiler state."""
    walls_on = [float(walls_on)] if isinstance(
        walls_on, (int, float)) else [float(w) for w in walls_on]
    walls_off = [float(walls_off)] if isinstance(
        walls_off, (int, float)) else [float(w) for w in walls_off]
    if not walls_on or not walls_off:
        raise ValueError("overlap_ratio: need at least one step wall "
                         "on each side of the A/B pair")
    t_on = _stats(walls_on)["p50"]
    t_off = _stats(walls_off)["p50"]
    hidden = max(t_off - t_on, 0.0)
    out = {"t_on_s": t_on, "t_off_s": t_off, "hidden_s": hidden}
    if exchange_s is not None and exchange_s > 0:
        out["exchange_s"] = float(exchange_s)
        out["exposed_s"] = max(float(exchange_s) - hidden, 0.0)
        out["hidden_frac"] = min(hidden / float(exchange_s), 1.0)
    return out


class StepProfiler:
    """Collects one profiled window of a training run.

    Life cycle: the launcher calls ``phase(name)`` around its host
    phases and ``record_step(step, step_s, exchanged)`` once per step;
    after ``window`` recorded steps the profiler is `done` and further
    calls are no-ops. `emit(sink, hlo_text=...)` writes the window as a
    single ``profile`` event."""

    def __init__(self, window: int = DEFAULT_WINDOW, trace_dir: str = ""):
        if window < 1:
            raise ValueError(f"profile window must be >= 1, got {window}")
        self.window = int(window)
        self.trace_dir = trace_dir
        self.step_walls: List[float] = []
        self.first_step: Optional[int] = None
        self.exchange_steps = 0
        self.phase_s: Dict[str, List[float]] = {}   # name -> [total_s, n]
        self._tracing = False
        self._emitted = False

    # ------------------------------------------------------------------ #
    @property
    def active(self) -> bool:
        return len(self.step_walls) < self.window and not self._emitted

    @property
    def done(self) -> bool:
        return not self.active

    def phase(self, name: str):
        """Wall-time accumulation context for a host phase (canonical
        names: tracing.HOST_PHASES), open only while the window is."""
        if not self.active:
            return nullcontext()
        return self._timed(name)

    @contextmanager
    def _timed(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            rec = self.phase_s.setdefault(name, [0.0, 0])
            rec[0] += time.perf_counter() - t0
            rec[1] += 1

    def record_step(self, step: int, step_s: float,
                    exchanged: bool = True) -> None:
        """One synced per-step wall time. Starts the optional
        jax.profiler trace on the first recorded step and stops it when
        the window fills."""
        if not self.active:
            return
        if self.first_step is None:
            self.first_step = int(step)
            if self.trace_dir:
                import jax
                jax.profiler.start_trace(self.trace_dir)
                self._tracing = True
        self.step_walls.append(float(step_s))
        self.exchange_steps += bool(exchanged)
        if len(self.step_walls) >= self.window:
            self._stop_trace()

    def _stop_trace(self) -> None:
        if self._tracing:
            import jax
            jax.profiler.stop_trace()
            self._tracing = False

    # ------------------------------------------------------------------ #
    def device_phase_costs(self, hlo_text: str) -> Dict[str, dict]:
        """Per-phase device cost attribution from the compiled step's
        optimized HLO — op counts + result bytes per `repro.obs/` scope
        (spans must have been on when the step was traced, or the
        metadata is absent and this returns {})."""
        from repro.launch.hlo_analysis import scope_costs
        known = set(DEVICE_PHASES)
        return {k: v for k, v in scope_costs(hlo_text, PREFIX).items()
                if k in known}

    def summary(self, hlo_text: str = "") -> Optional[dict]:
        """The window as a `profile` event payload, or None if no step
        was recorded."""
        if not self.step_walls:
            return None
        out = {
            "step0": self.first_step,
            "n_steps": len(self.step_walls),
            "exchange_steps": self.exchange_steps,
            "step_s": _stats(self.step_walls),
            "step_walls_s": [round(s, 6) for s in self.step_walls],
            "host_phases": {
                name: {"total_s": round(tot, 6), "n": n}
                for name, (tot, n) in sorted(self.phase_s.items())
            },
        }
        if hlo_text:
            dev = self.device_phase_costs(hlo_text)
            if dev:
                out["device_phases"] = dev
        if self.trace_dir:
            out["trace_dir"] = self.trace_dir
        return out

    def emit(self, sink, hlo_text: str = "") -> Optional[dict]:
        """Close the window (stopping any live trace) and write it as
        one schema-v2 ``profile`` event. Idempotent."""
        self._stop_trace()
        if self._emitted:
            return None
        payload = self.summary(hlo_text)
        if payload is None:
            return None
        self._emitted = True
        return sink.emit("profile", **payload)


class NullStepProfiler:
    """The off switch: same surface, every call a no-op — so the
    launcher's hot loop carries no conditionals."""

    window = 0
    active = False
    done = True
    step_walls: List[float] = []

    def phase(self, name: str):
        return nullcontext()

    def record_step(self, step: int, step_s: float,
                    exchanged: bool = True) -> None:
        pass

    def device_phase_costs(self, hlo_text: str) -> Dict[str, dict]:
        return {}

    def summary(self, hlo_text: str = "") -> Optional[dict]:
        return None

    def emit(self, sink, hlo_text: str = "") -> Optional[dict]:
        return None


def make_profiler(enabled: bool, window: int = 0, trace_dir: str = ""):
    """Launcher factory: `StepProfiler` when profiling is on (via the
    Observability.profile strategy field or an explicit --profile-steps),
    else the no-op `NullStepProfiler`."""
    if not enabled:
        return NullStepProfiler()
    return StepProfiler(window=window or DEFAULT_WINDOW,
                        trace_dir=trace_dir)


# re-exported so profile consumers need not import tracing for the names
__all__ = [
    "DEFAULT_WINDOW",
    "DEVICE_PHASES",
    "HOST_PHASES",
    "NullStepProfiler",
    "StepProfiler",
    "make_profiler",
    "overlap_ratio",
]
