"""repro.obs — on-device telemetry, structured run sinks, phase tracing
(DESIGN.md §11).

Three parts:

- `metrics` — a jit-static `MetricSpec` lattice ("off" ⊂ "wire" ⊂
  "full") collecting per-bucket gradient moments, empirical δ (from the
  already-materialized EF residual), EF norms and staleness histograms
  inside the jitted step, into fixed-shape buffers. ``off`` is
  contractually bit-identical to a build without this package.
- `sink` — a versioned JSONL event schema keyed by
  `Strategy.short_hash()`, with stdout / file / null backends
  (``--obs-sink`` on launch.train and benchmarks.run).
- `report` — ``python -m repro.obs report run.jsonl`` renders per-phase
  timing, the δ̂-vs-assumed-δ gap, bytes-vs-budget utilization and
  EF-residual growth from a sink file.

PR 7 adds the measured-vs-modeled layer (DESIGN.md §12):

- `profile` — a host-side `StepProfiler` turning the launcher's synced
  step walls + host/device phase attribution into schema-v2 ``profile``
  events (``--obs-profile`` / ``--profile-steps``).
- `hlo` — structural verification of the compiled step: collective
  ops/bytes from optimized HLO vs the `CommLedger`'s analytic bytes,
  plus schedule-shaped structure assertions.
- `calibrate` — ``python -m repro.obs calibrate run.jsonl`` fits
  `sched.clock` LinkModel + compute constants from recorded events and
  gates on modeled-vs-measured drift.
"""
from .metrics import (  # noqa: F401
    METRIC_SPECS,
    Collector,
    MetricSpec,
    NullCollector,
    ef_norms_sq,
    finalize,
    metric_keys,
    staleness_hist,
)
from .sink import (  # noqa: F401
    EVENT_KINDS,
    SCHEMA_VERSION,
    JsonlFileSink,
    NullSink,
    SchemaError,
    Sink,
    StdoutSink,
    TeeSink,
    make_sink,
    read_events,
    validate_event,
)
from .profile import (  # noqa: F401
    DEFAULT_WINDOW,
    NullStepProfiler,
    StepProfiler,
    make_profiler,
)
from .tracing import device_span, host_span  # noqa: F401
