"""Phase tracing: named spans for the jax profiler (DESIGN.md §11).

Two span flavors, both gated on `Observability.spans` so the default
build leaves the step graph and the host loop untouched:

- `host_span` — `jax.profiler.TraceAnnotation` around host-side phases
  (``data`` / ``step`` / ``eval``), visible in a captured profiler trace
  and as wall-time attribution in TensorBoard.
- `device_span` — `jax.named_scope` around in-jit phases (``exchange`` /
  ``apply`` / ``field``), which names the HLO ops so profiler traces and
  HLO dumps attribute device time to the phase. Disabled spans return a
  `nullcontext`, keeping the traced graph byte-identical.

Span names are namespaced ``repro.obs/<phase>`` so they are greppable in
profiles next to user scopes.
"""
from __future__ import annotations

from contextlib import nullcontext

import jax

PREFIX = "repro.obs/"

# the canonical phase names (DESIGN.md §11 span naming)
HOST_PHASES = ("data", "step", "eval")
DEVICE_PHASES = ("compress", "exchange", "apply", "field")


def host_span(name: str, enabled: bool = True):
    """TraceAnnotation context for a host-side phase (no-op when off)."""
    if not enabled:
        return nullcontext()
    return jax.profiler.TraceAnnotation(PREFIX + name)


def device_span(name: str, enabled: bool = True):
    """named_scope context for an in-jit phase (no-op when off)."""
    if not enabled:
        return nullcontext()
    return jax.named_scope(PREFIX + name)
