"""Wall-clock calibration: fit the modeled clock to measured runs
(DESIGN.md §12.3).

    PYTHONPATH=src python -m repro.obs calibrate run.jsonl [run2.jsonl ...]

`sched.clock` prices a run with three constants it has no way to know:
the per-worker compute time, the link bandwidth and the per-collective
latency. This module recovers them from recorded sink files — the
``timing``/``profile`` events are device-synced measurements, the
``run_meta`` strategy tells the cost model which dataflow produced them,
and the ``comm_summary`` wire bytes price the exchange — then reports
how far the calibrated model drifts from what was measured.

The fit: for the *linear* schedules the modeled mean step time is

    t̄ = g·t_c + n_ex·(latency + B·inv_bw)

where ``g`` is the schedule×straggler compute-gate factor (simulated
with unit compute and zero comm — deterministic in the strategy),
``n_ex`` the exchanges per step (1 for every_step, 1/K for local_k, 0
for W=1) and ``B`` the per-worker wire bytes of one exchange. Runs at
different schedules / byte counts give a least-squares system in
(t_c, latency, inv_bw). ``delayed`` overlaps comm under compute
(max(), not +) — nonlinear, so it is excluded from the fit but included
in the drift evaluation through the full `sched.clock.simulate`.

Degenerate inputs degrade explicitly (the ``method`` field says which
path fired): 3 independent rows → full ``lstsq3``; rank 2 → latency
pinned at the `LinkModel` default (``fixed_latency``); a single run →
compute floor from the minimum step wall and bandwidth from the mean's
residual (``residual`` — coarse, but enough for a smoke drift gate).

The output JSON is simultaneously a schema-v2 ``calibration`` event and
the file `sched.clock.load_calibration` consumes. All runs being fit
together are assumed to share one compute workload (same arch / batch /
device class); calibrate per-arch otherwise.
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.obs import cli
from repro.obs.sink import SCHEMA_VERSION, validate_event

# cap on simulated steps for gate/drift evaluation — the cost models are
# O(steps·M) numpy; beyond a few hundred steps the gate factor has
# converged for every profile the repo ships
_SIM_STEPS_CAP = 512


# --------------------------------------------------------------------------- #
# extraction: sink events -> run samples
# --------------------------------------------------------------------------- #
@dataclass
class RunSample:
    """One recorded run, reduced to what the cost model speaks."""
    strategy_json: dict
    n_workers: int
    steps: int
    measured_step_s: float       # robust (trimmed-mean) per-step wall
    measured_min_s: float        # the no-jitter floor
    wire_bytes: float            # per-worker bytes of ONE exchange
    n_samples: int
    source: str                  # "profile" | "timing"

    # derived lazily (need repro.strategy / sched imports)
    def schedule(self):
        from repro.strategy import Strategy
        return Strategy.from_dict(self.strategy_json)

    def cost_inputs(self):
        """(ExchangeSchedule, StragglerProfile, participation)."""
        strat = self.schedule()
        return (strat.schedule.runtime(), strat.participation.profile(),
                strat.participation.fraction)


def _trimmed_mean(walls: List[float]) -> float:
    """Mean of the samples excluding gross outliers (> 3× median) — one
    compile-step wall in the window must not poison the calibration."""
    med = float(np.median(walls))
    kept = [w for w in walls if w <= 3.0 * med] or list(walls)
    return float(np.mean(kept))


def extract_runs(events: List[dict]) -> List[RunSample]:
    """Split a sink event stream at each ``run_meta`` and reduce every
    complete run to a `RunSample`. Runs without a strategy or without
    any measured step are dropped."""
    runs: List[RunSample] = []
    segment: List[dict] = []
    for ev in events:
        if ev.get("kind") == "run_meta" and segment:
            s = _reduce(segment)
            if s is not None:
                runs.append(s)
            segment = []
        segment.append(ev)
    if segment:
        s = _reduce(segment)
        if s is not None:
            runs.append(s)
    return runs


def _reduce(segment: List[dict]) -> Optional[RunSample]:
    meta = next((e for e in segment if e.get("kind") == "run_meta"), None)
    if meta is None or not isinstance(meta.get("strategy_json"), dict):
        return None
    walls: List[float] = []
    source = "timing"
    profiles = [e for e in segment if e.get("kind") == "profile"]
    if profiles:
        # the profiled window holds every per-step wall — the richest
        # measurement; fall through to sparse timing samples without it
        walls = [float(w) for p in profiles
                 for w in p.get("step_walls_s", [])]
        source = "profile"
    if not walls:
        walls = [float(e["step_s"]) for e in segment
                 if e.get("kind") == "timing"]
    if not walls:
        return None
    comm = next((e for e in reversed(segment)
                 if e.get("kind") == "comm_summary"), None)
    W = int(meta.get("n_workers", 1) or 1)
    wire = float(comm.get("wire_bytes_per_step", 0.0)) if comm else 0.0
    return RunSample(
        strategy_json=meta["strategy_json"],
        n_workers=W,
        steps=int(meta.get("steps", len(walls)) or len(walls)),
        measured_step_s=_trimmed_mean(walls),
        measured_min_s=float(min(walls)),
        wire_bytes=wire if W > 1 else 0.0,
        n_samples=len(walls),
        source=source,
    )


# --------------------------------------------------------------------------- #
# the fit
# --------------------------------------------------------------------------- #
def _sim_steps(run: RunSample) -> int:
    return int(min(max(run.steps, 8), _SIM_STEPS_CAP))


def gate_factor(run: RunSample, seed: int = 0) -> float:
    """Schedule×straggler compute-gate multiplier: mean simulated step
    at unit compute and zero comm. 1.0 for a homogeneous lockstep run;
    > 1 under stragglers (the barrier waits for the max)."""
    from repro.sched import clock as sclock
    from repro.sched import straggler as strag
    sched, profile, particip = run.cost_inputs()
    times = strag.step_times(profile, max(run.n_workers, 1),
                             _sim_steps(run), seed, base=1.0)
    return float(sclock.simulate(sched, times, 0.0, particip,
                                 seed)["mean_step_s"])


def _row(run: RunSample, seed: int = 0) -> Optional[tuple]:
    """(g, n_ex, B) for the linear model, or None when this schedule's
    clock is nonlinear in the constants (delayed: comm hides under
    compute via max())."""
    sched, _, _ = run.cost_inputs()
    if sched.name == "delayed" and run.n_workers > 1:
        return None
    g = gate_factor(run, seed)
    n_ex = (1.0 / sched.period) if run.n_workers > 1 else 0.0
    return (g, n_ex, run.wire_bytes)


def fit(runs: List[RunSample], seed: int = 0) -> dict:
    """Recover (t_compute_s, latency_s, bandwidth_Bps) from run samples.
    Returns the constants plus the ``method`` that produced them."""
    from repro.sched.clock import LinkModel
    default = LinkModel()
    rows, ts = [], []
    for r in runs:
        lin = _row(r, seed)
        if lin is not None:
            rows.append(lin)
            ts.append(r.measured_step_s)
    if not rows:
        raise ValueError(
            "calibrate: no linear-schedule runs to fit (delayed-only "
            "input) — record at least one every_step or local_k run")
    A = np.array([[g, n, n * b] for g, n, b in rows])
    b = np.array(ts)
    method = None
    t_c = lat = inv_bw = 0.0
    if np.linalg.matrix_rank(A) >= 3:
        x = np.linalg.lstsq(A, b, rcond=None)[0]
        t_c, lat, inv_bw = (float(x[0]), max(float(x[1]), 0.0),
                            max(float(x[2]), 0.0))
        method = "lstsq3"
    if method is None:
        # rank 2: pin latency at the default, solve (t_c, inv_bw)
        A2 = A[:, [0, 2]]
        if np.linalg.matrix_rank(A2) >= 2:
            lat = default.latency_s
            b2 = b - A[:, 1] * lat
            x = np.linalg.lstsq(A2, b2, rcond=None)[0]
            t_c, inv_bw = float(x[0]), max(float(x[1]), 0.0)
            method = "fixed_latency"
    if method is None:
        # single/degenerate run: compute floor from the minimum wall,
        # bandwidth from the residual of the most comm-heavy run
        lat = default.latency_s
        t_c = min(r.measured_min_s for r in runs)
        heavy = max(zip(rows, ts), key=lambda rt: rt[0][1] * rt[0][2])
        (g, n, B), t_meas = heavy
        inv_bw = (max(t_meas - g * t_c - n * lat, 0.0) / (n * B)
                  if n * B > 0 else 0.0)
        method = "residual"
    if t_c <= 0:
        # a negative compute intercept means the inputs contradict the
        # model; clamp to the observed floor rather than emit nonsense
        t_c = min(r.measured_min_s for r in runs)
        method += "+tc_floor"
    bw = (1.0 / inv_bw) if inv_bw > 0 else default.bandwidth_Bps
    return {"t_compute_s": t_c, "latency_s": lat, "bandwidth_Bps": bw,
            "method": method, "n_fit_runs": len(rows)}


# --------------------------------------------------------------------------- #
# drift: calibrated model vs every measured run
# --------------------------------------------------------------------------- #
def modeled_step_s(run: RunSample, t_compute_s: float, link,
                   seed: int = 0) -> float:
    """Mean step the calibrated `sched.clock` predicts for this run —
    the FULL simulate (delayed's overlap included), not the linear fit
    surrogate."""
    from repro.sched import clock as sclock
    from repro.sched import straggler as strag
    sched, profile, particip = run.cost_inputs()
    W = max(run.n_workers, 1)
    times = strag.step_times(profile, W, _sim_steps(run), seed,
                             base=t_compute_s)
    t_ex = link.exchange_time(run.wire_bytes) if W > 1 else 0.0
    return float(sclock.simulate(sched, times, t_ex, particip,
                                 seed)["mean_step_s"])


def calibrate(runs: List[RunSample], seed: int = 0) -> dict:
    """fit + per-run drift. The returned dict is a valid schema-v2
    ``calibration`` event AND the `sched.clock.load_calibration` file
    format."""
    from repro.sched.clock import LinkModel
    constants = fit(runs, seed)
    link = LinkModel(bandwidth_Bps=constants["bandwidth_Bps"],
                     latency_s=constants["latency_s"])
    rows = []
    drifts = []
    for r in runs:
        modeled = modeled_step_s(r, constants["t_compute_s"], link, seed)
        drift = (modeled / r.measured_step_s - 1.0
                 if r.measured_step_s else 0.0)
        drifts.append(abs(drift))
        sched, _, _ = r.cost_inputs()
        rows.append({
            "schedule": sched.describe(),
            "n_workers": r.n_workers,
            "wire_bytes": r.wire_bytes,
            "n_samples": r.n_samples,
            "source": r.source,
            "measured_step_s": round(r.measured_step_s, 6),
            "modeled_step_s": round(modeled, 6),
            "drift": round(drift, 4),
        })
    out = {"v": SCHEMA_VERSION, "kind": "calibration"}
    out.update(constants)
    out["n_runs"] = len(runs)
    out["runs"] = rows
    out["max_abs_drift"] = round(max(drifts), 4) if drifts else 0.0
    validate_event(out)
    return out


# --------------------------------------------------------------------------- #
def render(cal: dict) -> str:
    lines = [
        f"calibrated constants ({cal['method']}, "
        f"{cal['n_fit_runs']}/{cal['n_runs']} runs in fit):",
        f"  t_compute  {cal['t_compute_s'] * 1e3:10.3f} ms/step",
        f"  latency    {cal['latency_s'] * 1e6:10.1f} us/collective",
        f"  bandwidth  {cal['bandwidth_Bps'] / 1e9:10.3f} GB/s",
        "",
        "measured vs modeled (mean step):",
    ]
    for r in cal["runs"]:
        lines.append(
            f"  {r['schedule']:<18} W={r['n_workers']:<3} "
            f"{r['wire_bytes'] / 1e6:8.3f}MB/ex  "
            f"measured {r['measured_step_s'] * 1e3:8.2f}ms  "
            f"modeled {r['modeled_step_s'] * 1e3:8.2f}ms  "
            f"drift {r['drift'] * 100:+6.1f}%")
    lines.append("")
    lines.append(f"max |drift| = {cal['max_abs_drift'] * 100:.1f}%")
    return "\n".join(lines)


DESCRIPTION = ("fit sched.clock LinkModel + compute constants from "
               "recorded run-sink files and report modeled-vs-measured "
               "drift")


def add_args(ap: argparse.ArgumentParser) -> None:
    """Mount the calibrate arguments (shared IO contract: repro.obs.cli)."""
    ap.add_argument("paths", nargs="+",
                    help="sink JSONL file(s) written by --obs-sink PATH "
                         "(fit jointly — same arch/batch assumed)")
    ap.add_argument("--max-drift", type=float, default=0.0, metavar="F",
                    help="fail (exit 3) when max |drift| exceeds this "
                         "fraction, e.g. 0.5 = 50%% (0 = report only)")
    cli.add_io_args(ap, out_help="write the calibration JSON here (a "
                                 "schema-v2 calibration event; "
                                 "sched.clock.load_calibration reads it)")


def run(args: argparse.Namespace) -> int:
    events = cli.read_paths(args.paths, validate=not args.no_validate)
    runs = extract_runs(events)
    if not runs:
        print("calibrate: no complete runs (run_meta + timing/profile "
              "events) in input")
        return 2
    cal = calibrate(runs)
    cli.emit(args, cal, render(cal))
    if args.max_drift and cal["max_abs_drift"] > args.max_drift:
        print(f"calibrate: DRIFT GATE FAILED — max |drift| "
              f"{cal['max_abs_drift']:.3f} > {args.max_drift:.3f}")
        return 3
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs calibrate",
                                 description=DESCRIPTION)
    add_args(ap)
    return run(ap.parse_args(argv))


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    raise SystemExit(main())
