"""Structural verification of the *compiled* step (DESIGN.md §12.2).

`comm.CommLedger` models what the exchange should move; this module
checks what the compiled program actually lowers. Built on
`launch.hlo_analysis` (the while-loop-aware optimized-HLO walker), it is
pure text analysis — runnable on host CI devices, no hardware profiler:

* `compiled_text(jitfn, *args)` — lower + compile to optimized
  (post-SPMD, per-device) HLO text.
* `collective_summary(txt)` — per-category collective op counts and
  result bytes (all-reduce / reduce-scatter / all-gather / ...).
* `byte_gap(txt, ledger)` — the measured-vs-modeled byte gap: HLO
  collective result bytes against the ledger's analytic per-step wire
  and carried bytes (per bucket rows included). The ledger's transport
  accounting bills a ring all-reduce at 2·(W−1)/W × payload
  (send+receive); an HLO collective's *result* materializes the payload
  once — `modeled_result_bytes` divides the transport factor back out
  so the two sides are commensurable.
* `check_schedule_structure(...)` — schedule-shaped assertions: an
  exchange step lowers all-reduce-class collectives; a `local_k`
  mid-round step lowers NO gradient-payload collective (nothing close
  to the bucket payload on the wire between rounds); `delayed(τ)`
  carries the τ-deep pending ring through the step's loop state (ring
  parameters visible in the entry signature).

The live checks need a multi-device lowering (collectives only appear
when W > 1); CI runs them on 8 forced host devices, while the committed
HLO fixture (tests/fixtures/) keeps the extraction logic covered on
every tier.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import HLOAnalysis, _TYPE_RE

# collectives that implement a gradient averaging step ("all-reduce
# class"): a plain all-reduce, or its decomposed reduce-scatter +
# all-gather pair (two_phase), all count as exchange structure.
ALL_REDUCE_CLASS = ("all-reduce", "reduce-scatter", "all-gather")


# --------------------------------------------------------------------------- #
def compiled_text(jitfn, *args) -> str:
    """Optimized (post-SPMD, per-device) HLO text of a jitted callable
    on the given (possibly abstract) arguments."""
    return jitfn.lower(*args).compile().as_text()


def collective_summary(txt: str) -> dict:
    """{category: {count, bytes, int8_bytes}} from optimized HLO text
    (loop-trip-corrected — a collective inside a scanned body counts
    once per trip)."""
    return HLOAnalysis(txt).summary()["collectives"]


def _class_totals(colls: dict) -> dict:
    ops = sum(v["count"] for k, v in colls.items() if k in ALL_REDUCE_CLASS)
    byts = sum(v["bytes"] for k, v in colls.items() if k in ALL_REDUCE_CLASS)
    i8 = sum(v["int8_bytes"] for k, v in colls.items()
             if k in ALL_REDUCE_CLASS)
    return {"ops": ops, "bytes": byts, "int8_bytes": i8}


# --------------------------------------------------------------------------- #
def byte_gap(txt: str, ledger, participants: Optional[int] = None) -> dict:
    """Measured-vs-modeled bytes: what the compiled step's collectives
    materialize vs what the `CommLedger` bills one exchange round at.

    Returns a report dict; ``gap_ratio`` is measured / modeled_result − 1
    (≈ 0 when the compiled wire format matches the carried-bytes model;
    positive = the program moves more than modeled)."""
    colls = collective_summary(txt)
    measured = float(sum(v["bytes"] for v in colls.values()))
    wire, carried = ledger.round_bytes(participants)
    W = max(ledger.n_workers, 2)
    transport = 2.0 * (W - 1) / W
    modeled_result = carried / transport if transport else carried
    return {
        "hlo_collectives": colls,
        "hlo_bytes": measured,
        "hlo_int8_bytes": float(sum(v["int8_bytes"]
                                    for v in colls.values())),
        "modeled_wire_bytes": wire,
        "modeled_carried_bytes": carried,
        "modeled_result_bytes": modeled_result,
        "gap_ratio": (measured / modeled_result - 1.0
                      if modeled_result else None),
        "per_bucket": ledger.per_bucket(participants),
    }


# --------------------------------------------------------------------------- #
# schedule-shaped structure
# --------------------------------------------------------------------------- #
_PARAM_LINE = re.compile(r"=\s*[\w\[\],{}\s/*]*?parameter\(\d+\)")


def entry_parameter_shapes(txt: str) -> List[tuple]:
    """Dim tuples of every ENTRY-computation parameter (the step's
    carried state + inputs as the compiled program sees them)."""
    entry_started = False
    shapes: List[tuple] = []
    for line in txt.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            entry_started = True
            continue
        if not entry_started:
            continue
        if s.startswith("}"):
            break
        if "parameter(" not in s or not _PARAM_LINE.search(s):
            continue
        lhs = s.split("parameter(", 1)[0]
        for _, dims in _TYPE_RE.findall(lhs):
            shapes.append(tuple(int(d) for d in dims.split(",") if d))
    return shapes


def ring_parameters(txt: str, tau: int) -> List[tuple]:
    """Entry parameters that look like τ-deep pending-ring slots: a dim
    equal to τ in the first two axes of a ≥2-D shape (the per-device
    ring is (τ, *leaf) or (W_local, τ, *leaf) depending on sharding)."""
    if tau < 2:
        return []
    out = []
    for shp in entry_parameter_shapes(txt):
        if len(shp) >= 2 and tau in shp[:2]:
            out.append(shp)
    return out


def check_schedule_structure(schedule, exchange_txt: str,
                             midround_txt: Optional[str] = None,
                             n_param_leaves: Optional[int] = None) -> dict:
    """Schedule-shaped assertions over compiled HLO text.

    ``schedule`` is a `repro.strategy.Schedule` (kind/k/tau);
    ``exchange_txt`` the optimized HLO of the do_exchange=True step
    variant, ``midround_txt`` (local_k only) the do_exchange=False
    variant. Returns {"ok": bool, "violations": [...], ...evidence};
    `assert_schedule_structure` raises on violations."""
    violations: List[str] = []
    ex_colls = collective_summary(exchange_txt)
    ex_cls = _class_totals(ex_colls)
    report: Dict[str, object] = {
        "schedule": f"{schedule.kind}(k={schedule.k},tau={schedule.tau})",
        "exchange_collectives": ex_colls,
        "exchange_class_totals": ex_cls,
    }

    # every schedule's exchange step moves the message through at least
    # one all-reduce-class collective
    if ex_cls["ops"] < 1:
        violations.append(
            f"exchange step lowers no all-reduce-class collective "
            f"(got {sorted(ex_colls)})")

    # every_step needs nothing beyond the collective presence above:
    # every compiled step IS the exchange step. (A negative "no ring
    # state" probe is not reliable — small data dims collide with small
    # τ values in the shape scan.)
    if schedule.kind == "local_k":
        if midround_txt is None:
            violations.append(
                "local_k structure check needs the do_exchange=False "
                "(mid-round) variant's HLO")
        else:
            mid_colls = collective_summary(midround_txt)
            mid_cls = _class_totals(mid_colls)
            report["midround_collectives"] = mid_colls
            report["midround_class_totals"] = mid_cls
            # mid-round steps accumulate locally: no gradient payload on
            # the wire. Scalar metric reductions (loss/grad_norm psums)
            # are allowed; the payload-class bytes must collapse.
            if mid_cls["int8_bytes"] > 0:
                violations.append(
                    f"mid-round step moves quantized payload "
                    f"({mid_cls['int8_bytes']:.0f} int8 bytes)")
            if ex_cls["bytes"] and \
                    mid_cls["bytes"] >= 0.5 * ex_cls["bytes"]:
                violations.append(
                    f"mid-round collective bytes "
                    f"({mid_cls['bytes']:.0f}) not < half the exchange "
                    f"step's ({ex_cls['bytes']:.0f}) — the accumulator "
                    f"is leaking onto the wire between rounds")
    elif schedule.kind == "delayed":
        if schedule.tau >= 2:
            rings = ring_parameters(exchange_txt, schedule.tau)
            report["ring_parameters"] = rings
            need = n_param_leaves or 1
            if len(rings) < need:
                violations.append(
                    f"delayed(tau={schedule.tau}) carries "
                    f"{len(rings)} tau-deep ring parameter(s) through "
                    f"loop state, expected >= {need}")
    report["ok"] = not violations
    report["violations"] = violations
    return report


def assert_schedule_structure(schedule, exchange_txt: str,
                              midround_txt: Optional[str] = None,
                              n_param_leaves: Optional[int] = None) -> dict:
    report = check_schedule_structure(schedule, exchange_txt, midround_txt,
                                      n_param_leaves)
    if not report["ok"]:
        raise AssertionError(
            f"schedule structure violated for {report['schedule']}: "
            + "; ".join(report["violations"]))
    return report
