"""Structural verification of the *compiled* step (DESIGN.md §12.2).

`comm.CommLedger` models what the exchange should move; this module
checks what the compiled program actually lowers. Built on
`launch.hlo_analysis` (the while-loop-aware optimized-HLO walker), it is
pure text analysis — runnable on host CI devices, no hardware profiler:

* `compiled_text(jitfn, *args)` — lower + compile to optimized
  (post-SPMD, per-device) HLO text.
* `collective_summary(txt)` — per-category collective op counts and
  result bytes (all-reduce / reduce-scatter / all-gather / ...).
* `byte_gap(txt, ledger)` — the measured-vs-modeled byte gap: HLO
  collective result bytes against the ledger's analytic per-step wire
  and carried bytes (per bucket rows included). The ledger's transport
  accounting bills a ring all-reduce at 2·(W−1)/W × payload
  (send+receive); an HLO collective's *result* materializes the payload
  once — `modeled_result_bytes` divides the transport factor back out
  so the two sides are commensurable.
* `check_schedule_structure(...)` — schedule-shaped assertions: an
  exchange step lowers all-reduce-class collectives; a `local_k`
  mid-round step lowers NO gradient-payload collective (nothing close
  to the bucket payload on the wire between rounds); `delayed(τ)`
  carries the τ-deep pending ring through the step's loop state (ring
  parameters visible in the entry signature); with ``overlap=True`` the
  exchange collectives are additionally DAG-independent of the field
  compute ("collective N overlaps compute region R", DESIGN.md §13).
* `check_fsdp_structure(...)` — ZeRO-shaped assertions: the exchange
  step lowers reduce-scatter/all-to-all + all-gather (not whole-payload
  all-reduce); with ``compressed=True`` the wire payload is int8.
  Modern shard_map lowerings only — the legacy psum_scatter emulation
  lowers everything to all-reduce (guard on
  ``core.exchange._HAS_MODERN_SHARD_MAP``).
* `exchange_field_independence(txt)` — the overlap invariant on any
  backend: no exchange-scoped collective transitively consumes a
  field-scoped op, so the scheduler is FREE to run wire and compute
  concurrently. Pure dataflow, works on XLA:CPU (which lowers sync
  collectives).
* `async_collective_pairs(txt)` — on backends whose scheduler has
  already committed to overlap (GPU/TPU with async collectives +
  latency hiding), the -start/-done pairs and the non-trivial compute
  scheduled between them.

The live checks need a multi-device lowering (collectives only appear
when W > 1); CI runs them on 8 forced host devices, while the committed
HLO fixture (tests/fixtures/) keeps the extraction logic covered on
every tier.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

from repro.core.exchange import transport_factor
from repro.launch.hlo_analysis import (HLOAnalysis, _COLL_OPS, _OP_NAME,
                                       _TYPE_RE, parse_computations)

# collectives that implement a gradient averaging step ("all-reduce
# class"): a plain all-reduce, or its decomposed reduce-scatter +
# all-gather pair (two_phase), all count as exchange structure.
ALL_REDUCE_CLASS = ("all-reduce", "reduce-scatter", "all-gather")


# --------------------------------------------------------------------------- #
def compiled_text(jitfn, *args) -> str:
    """Optimized (post-SPMD, per-device) HLO text of a jitted callable
    on the given (possibly abstract) arguments."""
    return jitfn.lower(*args).compile().as_text()


def collective_summary(txt: str) -> dict:
    """{category: {count, bytes, int8_bytes}} from optimized HLO text
    (loop-trip-corrected — a collective inside a scanned body counts
    once per trip)."""
    return HLOAnalysis(txt).summary()["collectives"]


def _class_totals(colls: dict) -> dict:
    ops = sum(v["count"] for k, v in colls.items() if k in ALL_REDUCE_CLASS)
    byts = sum(v["bytes"] for k, v in colls.items() if k in ALL_REDUCE_CLASS)
    i8 = sum(v["int8_bytes"] for k, v in colls.items()
             if k in ALL_REDUCE_CLASS)
    return {"ops": ops, "bytes": byts, "int8_bytes": i8}


# --------------------------------------------------------------------------- #
def byte_gap(txt: str, ledger, participants: Optional[int] = None) -> dict:
    """Measured-vs-modeled bytes: what the compiled step's collectives
    materialize vs what the `CommLedger` bills one exchange round at.

    Returns a report dict; ``gap_ratio`` is measured / modeled_result − 1
    (≈ 0 when the compiled wire format matches the carried-bytes model;
    positive = the program moves more than modeled)."""
    colls = collective_summary(txt)
    measured = float(sum(v["bytes"] for v in colls.values()))
    wire, carried = ledger.round_bytes(participants)
    transport = transport_factor(max(ledger.n_workers, 2))
    modeled_result = carried / transport if transport else carried
    return {
        "hlo_collectives": colls,
        "hlo_bytes": measured,
        "hlo_int8_bytes": float(sum(v["int8_bytes"]
                                    for v in colls.values())),
        "modeled_wire_bytes": wire,
        "modeled_carried_bytes": carried,
        "modeled_result_bytes": modeled_result,
        "gap_ratio": (measured / modeled_result - 1.0
                      if modeled_result else None),
        "per_bucket": ledger.per_bucket(participants),
    }


# --------------------------------------------------------------------------- #
# schedule-shaped structure
# --------------------------------------------------------------------------- #
_PARAM_LINE = re.compile(r"=\s*[\w\[\],{}\s/*]*?parameter\(\d+\)")


def entry_parameter_shapes(txt: str) -> List[tuple]:
    """Dim tuples of every ENTRY-computation parameter (the step's
    carried state + inputs as the compiled program sees them)."""
    entry_started = False
    shapes: List[tuple] = []
    for line in txt.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            entry_started = True
            continue
        if not entry_started:
            continue
        if s.startswith("}"):
            break
        if "parameter(" not in s or not _PARAM_LINE.search(s):
            continue
        lhs = s.split("parameter(", 1)[0]
        for _, dims in _TYPE_RE.findall(lhs):
            shapes.append(tuple(int(d) for d in dims.split(",") if d))
    return shapes


def ring_parameters(txt: str, tau: int) -> List[tuple]:
    """Entry parameters that look like τ-deep pending-ring slots: a dim
    equal to τ in the first two axes of a ≥2-D shape (the per-device
    ring is (τ, *leaf) or (W_local, τ, *leaf) depending on sharding)."""
    if tau < 2:
        return []
    out = []
    for shp in entry_parameter_shapes(txt):
        if len(shp) >= 2 and tau in shp[:2]:
            out.append(shp)
    return out


# --------------------------------------------------------------------------- #
# overlap structure (DESIGN.md §13)
# --------------------------------------------------------------------------- #
# ops that are pure data plumbing: compute "between" an async start and
# its done must be more than these to count as hidden work
_FREE_OPS = frozenset((
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "opt-barrier", "copy", "partition-id", "replica-id",
))
_INSTR = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=")
# the result type left of the op name may be a parenthesized tuple
# (async -start ops, multi-output fusions) — skip it explicitly
_OPNAME_OF = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[\w\[\],{}\s/*]*?([\w\-]+)\(")
_COLL_RE = re.compile(r"\b(" + "|".join(_COLL_OPS) + r")(-start)?\(")


def _instr_table(lines: List[str]):
    """[(name, opname, operand-names, scope-op_name)] for one computation,
    in program order. Operands are the %tokens of the call argument list
    (metadata and computation references stripped)."""
    out = []
    for ln in lines:
        m = _INSTR.match(ln)
        if m is None or "=" not in ln:
            continue
        name = m.group(1)
        rhs = ln.split("=", 1)[1]
        om = _OPNAME_OF.search(ln)
        opname = om.group(1) if om else ""
        meta = _OP_NAME.search(ln)
        body = rhs.split(", metadata=")[0]
        # computation references are attributes, not dataflow operands
        body = re.sub(r"(?:calls|to_apply|condition|body)=%?[\w.\-]+", "",
                      body)
        body = re.sub(r"branch_computations=\{[^}]*\}", "", body)
        ops = re.findall(r"%([\w.\-]+)", body)
        out.append((name, opname, ops, meta.group(1) if meta else ""))
    return out


def async_collective_pairs(txt: str) -> dict:
    """Async -start/-done pairing evidence from optimized HLO.

    Backends that lower async collectives (GPU/TPU with the
    latency-hiding scheduler; see launch.mesh.enable_overlap_flags)
    print each overlapped collective as a `<op>-start` whose result a
    later `<op>-done` consumes; everything scheduled between the pair
    runs concurrently with the wire transfer. Returns per-pair non-free
    op counts; XLA:CPU (sync collectives only) legitimately reports
    ``pairs == 0`` — use `exchange_field_independence` for the
    backend-agnostic overlap invariant."""
    pairs = []
    unmatched = 0
    for comp, lines in parse_computations(txt).items():
        tab = _instr_table(lines)
        for i, (name, opname, _, _) in enumerate(tab):
            if not opname.endswith("-start") or not any(
                    opname == c + "-start" for c in _COLL_OPS):
                continue
            done_idx = None
            for j in range(i + 1, len(tab)):
                if tab[j][1] == opname[:-len("-start")] + "-done" and \
                        name in tab[j][2]:
                    done_idx = j
                    break
            if done_idx is None:
                unmatched += 1
                continue
            between = sum(1 for k in range(i + 1, done_idx)
                          if tab[k][1] not in _FREE_OPS)
            pairs.append({"computation": comp, "op": opname[:-6],
                          "start": name, "compute_between": between})
    return {
        "pairs": len(pairs),
        "unmatched_starts": unmatched,
        "min_compute_between": (min(p["compute_between"] for p in pairs)
                                if pairs else None),
        "detail": pairs,
    }


def exchange_field_independence(txt: str,
                                prefix: str = "repro.obs/") -> dict:
    """The backend-agnostic overlap invariant: every collective carrying
    the `repro.obs/exchange` scope must be DAG-independent of all
    `repro.obs/field`-scoped ops — its transitive operand closure inside
    its computation touches no field op. That is precisely the property
    that lets a latency-hiding scheduler run the wire transfer during
    the field compute; a blocking lowering whose message depends on this
    round's gradients (every_step/local_k) fails it by construction.

    Needs a lowering with spans on (`Observability(spans=True)`) so the
    scope metadata survives into the HLO; reports
    ``spans_present=False`` otherwise. Works on XLA:CPU, where async
    -start/-done pairs never appear but the dataflow freedom is the
    same."""
    exch_tag = prefix + "exchange"
    field_tag = prefix + "field"
    n_exch_colls = 0
    tainted: List[str] = []
    spans_present = False
    for comp, lines in parse_computations(txt).items():
        tab = _instr_table(lines)
        if not any(t[3] for t in tab):
            continue
        by_name = {t[0]: t for t in tab}
        if any(exch_tag in t[3] or field_tag in t[3] for t in tab):
            spans_present = True
        for name, opname, _, scope in tab:
            if exch_tag not in scope or not _COLL_RE.search(" " + opname
                                                           + "("):
                continue
            n_exch_colls += 1
            seen = set()
            stack = [name]
            hit = None
            while stack:
                cur = stack.pop()
                if cur in seen:
                    continue
                seen.add(cur)
                t = by_name.get(cur)
                if t is None:
                    continue
                if field_tag in t[3]:
                    hit = cur
                    break
                stack.extend(t[2])
            if hit is not None:
                tainted.append(f"{comp}::{name} depends on field op {hit}")
    return {
        "spans_present": spans_present,
        "exchange_collectives": n_exch_colls,
        "tainted": tainted,
        "ok": spans_present and n_exch_colls > 0 and not tainted,
    }


def check_schedule_structure(schedule, exchange_txt: str,
                             midround_txt: Optional[str] = None,
                             n_param_leaves: Optional[int] = None,
                             overlap: bool = False) -> dict:
    """Schedule-shaped assertions over compiled HLO text.

    ``schedule`` is a `repro.strategy.Schedule` (kind/k/tau);
    ``exchange_txt`` the optimized HLO of the do_exchange=True step
    variant, ``midround_txt`` (local_k only) the do_exchange=False
    variant. ``overlap=True`` (delayed × ExchangePlan.overlap) adds the
    "collective N overlaps compute region R" checks: the exchange
    collectives must be DAG-independent of the field compute
    (`exchange_field_independence`, any backend), and when the backend
    emitted async -start/-done pairs they must be matched with
    non-trivial compute between them (`async_collective_pairs`).
    Returns {"ok": bool, "violations": [...], ...evidence};
    `assert_schedule_structure` raises on violations."""
    violations: List[str] = []
    ex_colls = collective_summary(exchange_txt)
    ex_cls = _class_totals(ex_colls)
    report: Dict[str, object] = {
        "schedule": f"{schedule.kind}(k={schedule.k},tau={schedule.tau})",
        "exchange_collectives": ex_colls,
        "exchange_class_totals": ex_cls,
    }

    # every schedule's exchange step moves the message through at least
    # one all-reduce-class collective
    if ex_cls["ops"] < 1:
        violations.append(
            f"exchange step lowers no all-reduce-class collective "
            f"(got {sorted(ex_colls)})")

    # every_step needs nothing beyond the collective presence above:
    # every compiled step IS the exchange step. (A negative "no ring
    # state" probe is not reliable — small data dims collide with small
    # τ values in the shape scan.)
    if schedule.kind == "local_k":
        if midround_txt is None:
            violations.append(
                "local_k structure check needs the do_exchange=False "
                "(mid-round) variant's HLO")
        else:
            mid_colls = collective_summary(midround_txt)
            mid_cls = _class_totals(mid_colls)
            report["midround_collectives"] = mid_colls
            report["midround_class_totals"] = mid_cls
            # mid-round steps accumulate locally: no gradient payload on
            # the wire. Scalar metric reductions (loss/grad_norm psums)
            # are allowed; the payload-class bytes must collapse.
            if mid_cls["int8_bytes"] > 0:
                violations.append(
                    f"mid-round step moves quantized payload "
                    f"({mid_cls['int8_bytes']:.0f} int8 bytes)")
            if ex_cls["bytes"] and \
                    mid_cls["bytes"] >= 0.5 * ex_cls["bytes"]:
                violations.append(
                    f"mid-round collective bytes "
                    f"({mid_cls['bytes']:.0f}) not < half the exchange "
                    f"step's ({ex_cls['bytes']:.0f}) — the accumulator "
                    f"is leaking onto the wire between rounds")
    elif schedule.kind == "delayed":
        if schedule.tau >= 2:
            rings = ring_parameters(exchange_txt, schedule.tau)
            report["ring_parameters"] = rings
            need = n_param_leaves or 1
            if len(rings) < need:
                violations.append(
                    f"delayed(tau={schedule.tau}) carries "
                    f"{len(rings)} tau-deep ring parameter(s) through "
                    f"loop state, expected >= {need}")

    if overlap:
        if schedule.kind != "delayed":
            violations.append(
                f"overlap structure is only defined for the delayed "
                f"schedule, not {schedule.kind!r}")
        else:
            indep = exchange_field_independence(exchange_txt)
            report["overlap_independence"] = indep
            if not indep["spans_present"]:
                violations.append(
                    "overlap check needs a lowering with spans on "
                    "(Observability(spans=True)) so exchange/field scope "
                    "metadata survives into the HLO")
            elif indep["exchange_collectives"] < 1:
                violations.append(
                    "overlap step lowers no exchange-scoped collective")
            elif indep["tainted"]:
                violations.append(
                    "exchange collective(s) depend on this round's field "
                    "compute (overlap impossible): "
                    + "; ".join(indep["tainted"][:3]))
            pairs = async_collective_pairs(exchange_txt)
            report["async_pairs"] = pairs
            # async -start/-done only exists where the backend scheduler
            # committed to overlap (GPU/TPU); XLA:CPU lowers sync
            # collectives, so pairs==0 there is reported, not violated —
            # the independence check above is the CPU-tier guarantee.
            if pairs["pairs"] > 0:
                if pairs["unmatched_starts"]:
                    violations.append(
                        f"{pairs['unmatched_starts']} async collective "
                        f"start(s) without a matching -done")
                if (pairs["min_compute_between"] or 0) < 1:
                    violations.append(
                        "async collective pair(s) with no compute "
                        "scheduled between start and done — the wire "
                        "time is not being hidden")
    report["ok"] = not violations
    report["violations"] = violations
    return report


def assert_schedule_structure(schedule, exchange_txt: str,
                              midround_txt: Optional[str] = None,
                              n_param_leaves: Optional[int] = None,
                              overlap: bool = False) -> dict:
    report = check_schedule_structure(schedule, exchange_txt, midround_txt,
                                      n_param_leaves, overlap=overlap)
    if not report["ok"]:
        raise AssertionError(
            f"schedule structure violated for {report['schedule']}: "
            + "; ".join(report["violations"]))
    return report


# --------------------------------------------------------------------------- #
def check_fsdp_structure(exchange_txt: str,
                         compressed: bool = False) -> dict:
    """FSDP-shaped assertions over the compiled exchange step's HLO
    (DESIGN.md §15.4).

    A ZeRO-style step must lower a *scatter* collective (reduce-scatter
    for the exact path, all-to-all for the quantized two_phase path) to
    move each worker's shard in, and an all-gather to broadcast the
    shard update (zero-2) or the updated shard params (zero-3) back
    out. It must NOT fall back to whole-payload all-reduce: the
    all-reduce bytes that remain should be scalar metrics (loss,
    grad_norm psums), small next to the scatter/gather payload. With
    ``compressed=True`` the wire payload must additionally be int8.

    Only meaningful on a modern shard_map lowering — the legacy
    emulation expands psum_scatter to all-reduce + dynamic-slice, so
    callers must guard on ``core.exchange._HAS_MODERN_SHARD_MAP``.
    Returns {"ok": bool, "violations": [...], ...evidence};
    `assert_fsdp_structure` raises on violations."""
    violations: List[str] = []
    colls = collective_summary(exchange_txt)

    def cat(name):
        return colls.get(name, {"count": 0, "bytes": 0, "int8_bytes": 0})

    scatter_ops = cat("reduce-scatter")["count"] + cat("all-to-all")["count"]
    scatter_bytes = cat("reduce-scatter")["bytes"] + cat("all-to-all")["bytes"]
    gather = cat("all-gather")
    ar = cat("all-reduce")
    payload_bytes = scatter_bytes + gather["bytes"]
    report: Dict[str, object] = {
        "collectives": colls,
        "scatter_ops": scatter_ops,
        "scatter_bytes": scatter_bytes,
        "all_gather_ops": gather["count"],
        "all_gather_bytes": gather["bytes"],
        "all_reduce_bytes": ar["bytes"],
    }

    if scatter_ops < 1:
        violations.append(
            f"fsdp exchange step lowers no scatter collective "
            f"(reduce-scatter or all-to-all); got {sorted(colls)}")
    if gather["count"] < 1:
        violations.append(
            "fsdp exchange step lowers no all-gather (the shard "
            "update/params never return to the other workers)")
    # whole-payload all-reduce means the sharded path silently degraded
    # to replicated DDP; scalar metric psums are a few bytes each.
    if payload_bytes and ar["bytes"] >= 0.5 * payload_bytes:
        violations.append(
            f"all-reduce bytes ({ar['bytes']:.0f}) not < half the "
            f"scatter+gather payload ({payload_bytes:.0f}) — the fsdp "
            f"step is moving whole-payload all-reduces")
    if compressed:
        i8 = sum(v["int8_bytes"] for v in colls.values())
        report["int8_bytes"] = i8
        if i8 <= 0:
            violations.append(
                "compressed fsdp step moves no int8 payload on the wire")
    report["ok"] = not violations
    report["violations"] = violations
    return report


def assert_fsdp_structure(exchange_txt: str, compressed: bool = False) -> dict:
    report = check_fsdp_structure(exchange_txt, compressed=compressed)
    if not report["ok"]:
        raise AssertionError("fsdp structure violated: "
                             + "; ".join(report["violations"]))
    return report
