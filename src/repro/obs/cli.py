"""Shared argparse plumbing for the ``python -m repro.obs`` subcommands.

Both subcommands (report, calibrate) speak the same IO contract:

* positional sink file(s) written by ``--obs-sink PATH``;
* ``--json``   — print the computed payload as JSON instead of text;
* ``--out P``  — additionally write that JSON payload to P;
* ``--no-validate`` — skip schema validation when reading.

`obs.__main__` mounts each subcommand's ``add_args``/``run`` pair on
one subparser tree; the standalone ``main()`` entry points build the
same parser for direct module invocation. This module holds the shared
pieces so neither CLI re-spells the contract.
"""
from __future__ import annotations

import argparse
import json
from typing import List

from repro.obs.sink import read_events


def add_io_args(ap: argparse.ArgumentParser, out_help: str) -> None:
    """The shared --json/--out/--no-validate trio."""
    ap.add_argument("--json", action="store_true",
                    help="print the computed payload as JSON instead of "
                         "the text rendering")
    ap.add_argument("--out", default="", metavar="PATH", help=out_help)
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation when reading")


def read_paths(paths: List[str], validate: bool) -> List[dict]:
    """Concatenate the events of one or more sink files."""
    events: List[dict] = []
    for p in paths:
        events.extend(read_events(p, validate=validate))
    return events


def emit(args: argparse.Namespace, payload: dict, text: str) -> None:
    """Honor the IO contract: --out writes the JSON payload; stdout gets
    JSON under --json, else the text rendering."""
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
    print(json.dumps(payload, indent=2) if args.json else text)
