"""`python -m repro.obs {report,calibrate}` — the run-sink CLIs."""
from __future__ import annotations

import sys

_USAGE = (
    "usage: python -m repro.obs SUBCOMMAND ...\n\n"
    "subcommands:\n"
    "  report     render a run-sink JSONL file (repro.obs.report)\n"
    "  calibrate  fit sched.clock constants from recorded runs and\n"
    "             report modeled-vs-measured drift (repro.obs.calibrate)"
)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE)
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        from repro.obs import report
        return report.main(rest)
    if cmd == "calibrate":
        from repro.obs import calibrate
        return calibrate.main(rest)
    print(f"unknown subcommand {cmd!r} (have: report, calibrate)",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
