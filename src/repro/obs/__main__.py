"""``python -m repro.obs {report,calibrate}`` — the run-sink CLIs.

One argparse subparser tree; each subcommand contributes its arguments
via its ``add_args`` hook and runs via its ``run`` hook, and both share
the ``--json`` / ``--out`` / ``--no-validate`` IO contract
(`repro.obs.cli`).
"""
from __future__ import annotations

import argparse
from typing import List, Optional


def build_parser() -> argparse.ArgumentParser:
    from repro.obs import calibrate, report
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="run-sink observability CLIs (DESIGN.md §11-§13)")
    sub = ap.add_subparsers(dest="subcommand", metavar="SUBCOMMAND")
    for name, mod in (("report", report), ("calibrate", calibrate)):
        p = sub.add_parser(name, help=mod.DESCRIPTION,
                           description=mod.DESCRIPTION)
        mod.add_args(p)
        p.set_defaults(func=mod.run)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    func = getattr(args, "func", None)
    if func is None:
        ap.print_help()
        return 2
    return func(args)


if __name__ == "__main__":
    raise SystemExit(main())
