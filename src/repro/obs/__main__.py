"""`python -m repro.obs report PATH` — the run-sink report CLI."""
from __future__ import annotations

import sys

from repro.obs import report


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs report PATH [--json]\n\n"
              "subcommands:\n"
              "  report   render a run-sink JSONL file "
              "(see repro.obs.report)")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd != "report":
        print(f"unknown subcommand {cmd!r} (only: report)", file=sys.stderr)
        return 2
    return report.main(rest)


if __name__ == "__main__":
    raise SystemExit(main())
