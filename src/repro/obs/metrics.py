"""On-device telemetry collection (DESIGN.md §11).

A `MetricSpec` is the jit-static description of WHAT the train step
measures; the three registry levels form a lattice::

    off  ⊂  wire (empirical δ + EF residual norms)
         ⊂  full (adds per-bucket gradient moments + staleness histogram)

The collection discipline keeps the bit-exactness contract cheap to
verify: `metrics="off"` hands the step a `NullCollector` whose record
methods are pure-python no-ops — the traced graph is *identical* to a
build without the obs subsystem (enforced by HLO comparison in
tests/test_obs.py). Enabled levels accumulate fixed-shape per-worker
sums inside the jitted step (no host callbacks), the SPMD caller reduces
them across workers (psum under shard_map, axis-0 sum after vmap), and
`finalize()` turns the reduced sums into the metric dict that rides out
of the step under ``metrics["obs"]``.

Empirical δ is read off quantities the step already materializes: the
compression operand m = message + e_prev and the fresh residual
e_new = m − Q(m), so δ̂ = 1 − Σ‖e_new‖² / Σ‖m‖² costs two dot products
per bucket and no extra compressor call. The Σ runs over the fleet
(psum of both numerator and denominator), so workers sitting a
participation round out (masked to m = 0, e_new = 0) drop out of the
ratio instead of biasing it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

_TINY = 1e-30


# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MetricSpec:
    """Jit-static switchboard of on-device metric groups. Frozen and
    hashable so it can ride in jit-static closures."""

    name: str
    moments: bool = False    # per-bucket + aggregate message mean/var
    delta: bool = False      # empirical δ̂ per bucket + aggregate
    ef_norms: bool = False   # ‖e1‖, ‖e2‖ fleet-wide residual norms
    staleness: bool = False  # staleness histogram (delayed schedules)

    @property
    def on(self) -> bool:
        return self.moments or self.delta or self.ef_norms or self.staleness


METRIC_SPECS: Dict[str, MetricSpec] = {
    "off": MetricSpec("off"),
    "wire": MetricSpec("wire", delta=True, ef_norms=True),
    "full": MetricSpec("full", moments=True, delta=True, ef_norms=True,
                       staleness=True),
}


def metric_keys(spec: MetricSpec, n_buckets: int) -> Tuple[str, ...]:
    """The keys of the finalized ``metrics["obs"]`` dict, in emission
    order — shared by `finalize` and the shard_map out_specs builder so
    the two can never drift."""
    keys: List[str] = []
    if spec.moments:
        keys += ["msg_mean", "msg_var"]
        if n_buckets:
            keys += ["bucket_mean", "bucket_var"]
    if spec.delta:
        keys += ["delta_hat"]
        if n_buckets:
            keys += ["bucket_delta"]
    if spec.ef_norms:
        keys += ["ef_e1_norm", "ef_e2_norm"]
    if spec.staleness:
        keys += ["staleness_hist"]
    return tuple(keys)


# --------------------------------------------------------------------------- #
class NullCollector:
    """The `metrics="off"` collector: every record method is a pure-python
    no-op, so the traced step graph is bit-identical to a build without
    the obs subsystem."""

    enabled = False
    n_buckets = 0

    def bucket(self, bid, raw, op, err):
        pass

    def leaf(self, raw, op, err):
        pass

    def sums(self) -> dict:
        return {}

    def counts(self) -> dict:
        return {"agg": 0, "bucket": []}


class Collector:
    """Accumulates per-worker metric sums during one step trace.

    `bucket(bid, raw, op, err)` records one comm bucket: ``raw`` the
    packed gradient message, ``op`` the compression operand
    (raw + e_prev) and ``err`` the fresh residual e_new = op − Q(op).
    `leaf(raw, op, err)` records a non-bucketed tensor (skipped sharded
    leaves, per-tensor strategies, the vmap path) into the aggregate
    slots only. Element counts are jit-static (bucket sizes and tensor
    shapes are), so `counts` never touches the device."""

    enabled = True

    def __init__(self, spec: MetricSpec, n_buckets: int):
        self.spec = spec
        self.n_buckets = n_buckets
        z = jnp.zeros(())
        self._agg = {"msg_sum": z, "msg_sq": z, "op_sq": z, "err_sq": z}
        self._bkt = {k: [jnp.zeros(())] * n_buckets
                     for k in ("msg_sum", "msg_sq", "op_sq", "err_sq")}
        self._n_agg = 0
        self._n_bkt = [0] * n_buckets

    # ---- record ------------------------------------------------------ #
    def _agg_add(self, raw, op, err):
        s = self.spec
        if s.moments:
            r = raw.astype(jnp.float32)
            self._agg["msg_sum"] = self._agg["msg_sum"] + jnp.sum(r)
            self._agg["msg_sq"] = self._agg["msg_sq"] + jnp.sum(r * r)
            self._n_agg += raw.size
        if s.delta:
            o = op.astype(jnp.float32)
            e = err.astype(jnp.float32)
            self._agg["op_sq"] = self._agg["op_sq"] + jnp.sum(o * o)
            self._agg["err_sq"] = self._agg["err_sq"] + jnp.sum(e * e)
            if not s.moments:
                self._n_agg += raw.size

    def bucket(self, bid: int, raw, op, err):
        s = self.spec
        if s.moments:
            r = raw.astype(jnp.float32)
            self._bkt["msg_sum"][bid] = jnp.sum(r)
            self._bkt["msg_sq"][bid] = jnp.sum(r * r)
        if s.delta:
            o = op.astype(jnp.float32)
            e = err.astype(jnp.float32)
            self._bkt["op_sq"][bid] = jnp.sum(o * o)
            self._bkt["err_sq"][bid] = jnp.sum(e * e)
        self._n_bkt[bid] = raw.size
        self._agg_add(raw, op, err)

    def leaf(self, raw, op, err):
        self._agg_add(raw, op, err)

    # ---- export ------------------------------------------------------ #
    def sums(self) -> dict:
        """The fixed-shape per-worker sums: scalar aggregates plus
        (n_buckets,) stacks. The SPMD caller reduces this dict across
        workers before `finalize`."""
        out = dict(self._agg)
        if self.n_buckets:
            for k, vals in self._bkt.items():
                out["b_" + k] = jnp.stack(vals)
        return out

    def counts(self) -> dict:
        return {"agg": self._n_agg, "bucket": list(self._n_bkt)}


def staleness_hist(st, bins: int):
    """Fixed-shape staleness histogram: bin i counts workers at
    staleness i, the last bin is the overflow (staleness > τ happens
    under partial participation — a sitting worker's version keeps
    aging). `st` is this worker's staleness scalar (shard_map) or the
    (W,) staleness vector (vmap / single worker); the caller psums or
    has already summed over workers."""
    idx = jnp.clip(jnp.round(st).astype(jnp.int32), 0, bins - 1)
    oh = jax.nn.one_hot(idx, bins, dtype=jnp.float32)
    if oh.ndim > 1:
        oh = jnp.sum(oh, axis=tuple(range(oh.ndim - 1)))
    return oh


def ef_norms_sq(new_ef) -> Tuple[jax.Array, jax.Array]:
    """(Σ‖e1‖², Σ‖e2‖²) over a post-exchange EF tree — handles both the
    per-tensor layout (tree of {"e1": ..} dicts) and the bucketed
    {"leaf": .., "bucket": ..} layout. Zeros when the slot is absent."""
    e1_sq = jnp.zeros(())
    e2_sq = jnp.zeros(())
    if new_ef is None:
        return e1_sq, e2_sq

    def is_ef(x):
        return isinstance(x, dict) and ("e1" in x or "e2" in x)

    for d in jax.tree.leaves(new_ef, is_leaf=is_ef):
        if not is_ef(d):
            continue
        if "e1" in d:
            v = d["e1"].astype(jnp.float32)
            e1_sq = e1_sq + jnp.sum(v * v)
        if "e2" in d:
            v = d["e2"].astype(jnp.float32)
            e2_sq = e2_sq + jnp.sum(v * v)
    return e1_sq, e2_sq


def finalize(spec: MetricSpec, sums: dict, counts: dict, n_workers: int,
             n_buckets: int) -> dict:
    """Reduced fleet sums → the ``metrics["obs"]`` dict (keys exactly
    `metric_keys(spec, n_buckets)`).

    `sums` must already be reduced across workers (psum / axis-sum);
    `counts` are the per-worker static element counts, so the fleet
    denominator is count × n_workers. A zero denominator (mid-round
    local_k step, or an all-masked round) yields mean/var 0 and δ̂ 1."""
    out = {}
    W = max(n_workers, 1)
    n_agg = counts["agg"] * W
    if spec.moments:
        mean = sums["msg_sum"] / max(n_agg, 1)
        out["msg_mean"] = mean
        out["msg_var"] = jnp.maximum(
            sums["msg_sq"] / max(n_agg, 1) - mean * mean, 0.0)
        if n_buckets:
            nb = jnp.asarray(
                [max(c * W, 1) for c in counts["bucket"]], jnp.float32)
            bmean = sums["b_msg_sum"] / nb
            out["bucket_mean"] = bmean
            out["bucket_var"] = jnp.maximum(
                sums["b_msg_sq"] / nb - bmean * bmean, 0.0)
    if spec.delta:
        out["delta_hat"] = 1.0 - sums["err_sq"] / jnp.maximum(
            sums["op_sq"], _TINY)
        if n_buckets:
            out["bucket_delta"] = 1.0 - sums["b_err_sq"] / jnp.maximum(
                sums["b_op_sq"], _TINY)
    if spec.ef_norms:
        out["ef_e1_norm"] = jnp.sqrt(sums["e1_sq"])
        out["ef_e2_norm"] = jnp.sqrt(sums["e2_sq"])
    if spec.staleness:
        out["staleness_hist"] = sums["staleness_hist"]
    return out
