"""Structured run sinks: a versioned JSONL event schema (DESIGN.md §11, §12).

Every event is one JSON object::

    {"v": 2, "kind": "...", "strategy": "<short_hash>", ...payload}

``v`` is the schema version (bump on any incompatible field change;
readers must ignore unknown fields so additive changes don't bump it),
``kind`` names the event type, ``strategy`` is `Strategy.short_hash()` —
the structural identity every event is keyed by, so a report can join a
sink file against regression baselines and checkpoints.

Version history: v1 is the PR 6 schema (run_meta / train_log / timing /
obs_metrics / comm_summary / bench_row). v2 adds the measured-vs-modeled
kinds — ``profile`` (step-profiler windows, DESIGN.md §12.1) and
``calibration`` (fitted LinkModel constants + drift, §12.3). Writers
stamp v2; readers accept BOTH versions (a v1 file validates unchanged),
but refuse a v2-only kind claiming ``v: 1`` — that is a mislabeled
writer, not an old file.

Backends: `StdoutSink` renders events in the pre-obs stdout format
(train_log rows as bare JSON lines, everything else as ``# obs[...]``
comment rows) so default output is unchanged; `JsonlFileSink` writes the
full event stream; `NullSink` drops it; `TeeSink` fans out. `make_sink`
maps the ``--obs-sink`` CLI spelling to a backend.
"""
from __future__ import annotations

import json
from typing import Any, Dict, IO, Optional, Sequence

SCHEMA_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)

# kind -> required payload fields (beyond the envelope). Readers must
# tolerate extra fields; writers must provide at least these.
EVENT_KINDS: Dict[str, tuple] = {
    "run_meta": ("steps",),          # run header: arch, strategy json, ...
    "train_log": ("step", "loss"),   # the per-log-step training row
    "timing": ("step", "step_s", "interval_s"),  # synced wall-times
    "obs_metrics": ("step",),        # on-device telemetry (repro.obs)
    "comm_summary": (),              # CommLedger.summary() payload
    "bench_row": ("name", "us"),     # one benchmarks.run CSV row
    # ---- v2: the measured side (DESIGN.md §12) ---- #
    "profile": ("step0", "n_steps", "step_s"),   # one profiled window
    "calibration": ("bandwidth_Bps", "latency_s"),  # fitted constants
}

# kinds that did not exist in schema v1 — a v1 event may not carry them
V2_KINDS = ("profile", "calibration")


class SchemaError(ValueError):
    """An event that does not conform to the sink schema."""


def validate_event(ev: Any) -> None:
    """Raise `SchemaError` unless `ev` is a valid schema event (any
    supported version — v1 files stay readable after the v2 bump)."""
    if not isinstance(ev, dict):
        raise SchemaError(f"event: expected an object, got "
                          f"{type(ev).__name__}")
    v = ev.get("v")
    if v not in SUPPORTED_VERSIONS:
        raise SchemaError(f"event: schema version {v!r} not in supported "
                          f"{SUPPORTED_VERSIONS}")
    kind = ev.get("kind")
    if kind not in EVENT_KINDS:
        raise SchemaError(f"event: unknown kind {kind!r}; have "
                          f"{sorted(EVENT_KINDS)}")
    if v < 2 and kind in V2_KINDS:
        raise SchemaError(f"event: kind {kind!r} requires schema v2, "
                          f"got v={v}")
    missing = [f for f in EVENT_KINDS[kind] if f not in ev]
    if missing:
        raise SchemaError(f"event kind={kind!r}: missing field(s) "
                          f"{missing}")


def _jsonable(x):
    """Best-effort conversion of numpy/jax scalars and arrays."""
    if hasattr(x, "tolist"):
        return x.tolist()
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, float):
        return x
    return x


class Sink:
    """Base sink. `emit(kind, **payload)` stamps the envelope
    (schema version + strategy hash), validates, and hands the event to
    the backend's `write`."""

    def __init__(self, strategy_hash: Optional[str] = None):
        self.strategy_hash = strategy_hash

    def emit(self, kind: str, **payload) -> dict:
        ev = {"v": SCHEMA_VERSION, "kind": kind}
        if self.strategy_hash is not None:
            ev["strategy"] = self.strategy_hash
        ev.update({k: _jsonable(v) for k, v in payload.items()})
        validate_event(ev)
        self.write(ev)
        return ev

    def write(self, ev: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullSink(Sink):
    def write(self, ev: dict) -> None:
        pass


class StdoutSink(Sink):
    """Renders the event stream in the pre-obs stdout format: train_log
    rows print as bare JSON (byte-compatible with the old ad-hoc
    ``print(json.dumps(rec))`` rows — the envelope fields are stripped).
    Other kinds render as ``# obs[kind]: {...}`` comment rows only when
    ``verbose`` (the explicit ``--obs-sink stdout`` spelling); the quiet
    default drops them, keeping default stdout byte-identical to the
    pre-obs launcher."""

    def __init__(self, strategy_hash: Optional[str] = None,
                 verbose: bool = False):
        super().__init__(strategy_hash)
        self.verbose = verbose

    def write(self, ev: dict) -> None:
        body = {k: v for k, v in ev.items()
                if k not in ("v", "kind", "strategy")}
        if ev["kind"] == "train_log":
            print(json.dumps(body), flush=True)
        elif self.verbose:
            print(f"# obs[{ev['kind']}]: "
                  f"{json.dumps(body, sort_keys=True)}", flush=True)


class JsonlFileSink(Sink):
    def __init__(self, path: str, strategy_hash: Optional[str] = None):
        super().__init__(strategy_hash)
        self.path = path
        self._fh: Optional[IO[str]] = open(path, "w")

    def write(self, ev: dict) -> None:
        assert self._fh is not None, "sink already closed"
        self._fh.write(json.dumps(ev) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class TeeSink(Sink):
    def __init__(self, sinks: Sequence[Sink],
                 strategy_hash: Optional[str] = None):
        super().__init__(strategy_hash)
        self.sinks = list(sinks)
        for s in self.sinks:
            s.strategy_hash = strategy_hash

    def write(self, ev: dict) -> None:
        for s in self.sinks:
            s.write(ev)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def make_sink(spec: str, strategy_hash: Optional[str] = None,
              tee_stdout: bool = False) -> Sink:
    """``--obs-sink`` spelling → backend: "" → quiet StdoutSink (the
    pre-obs default rendering), "stdout" → verbose StdoutSink,
    "null" → NullSink, anything else is a JSONL file path (tee'd with
    quiet stdout when `tee_stdout`, so log rows stay visible)."""
    if spec == "":
        return StdoutSink(strategy_hash, verbose=False)
    if spec == "stdout":
        return StdoutSink(strategy_hash, verbose=True)
    if spec == "null":
        return NullSink(strategy_hash)
    file_sink = JsonlFileSink(spec, strategy_hash)
    if tee_stdout:
        return TeeSink([StdoutSink(), file_sink], strategy_hash)
    return file_sink


def read_events(path: str, validate: bool = True):
    """Parse a sink file back into events (report CLI + tests)."""
    out = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise SchemaError(f"{path}:{i + 1}: invalid JSON ({e})")
            if validate:
                validate_event(ev)
            out.append(ev)
    return out
