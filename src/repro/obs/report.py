"""Render a run-sink JSONL file (DESIGN.md §11).

    PYTHONPATH=src python -m repro.obs report experiments/run_sink.jsonl

Sections (each skipped cleanly when its events are absent):

* **timing** — synced per-step wall times from ``timing`` events: the
  per-step series (mean / min / max) and interval throughput. These are
  real device-synced times (train.py blocks on the step output every
  step), not dispatch latencies.
* **empirical δ vs assumed δ** — joins the last ``obs_metrics`` event's
  per-bucket δ̂ against the analytic per-bucket δ the planner assumed
  (``comm_summary.per_bucket[*].delta``); the gap says how conservative
  the δ-budget plan really is on this gradient stream.
* **bytes vs budget** — payload utilization against the effective byte
  budget, overall and per bucket.
* **EF residual growth** — the fleet ‖e1‖ / ‖e2‖ series across the run;
  unbounded growth here is the classic sign of a divergent
  error-feedback loop (paper Thm. 2 needs it bounded).
* **profile** — the step-profiler window (schema v2 ``profile``
  events): per-window step-wall stats, host-phase split and, when spans
  were on, the HLO-derived device-phase attribution (DESIGN.md §12.1).
* **measured vs modeled** — when the file holds enough to calibrate
  (run_meta + timing/profile + comm_summary), the report runs
  `repro.obs.calibrate` on its own events and prints the fitted
  constants plus per-run drift (DESIGN.md §12.3).
* **overlap** — when the file holds paired runs whose strategies differ
  only in ``exchange.overlap`` (the split-phase A/B, DESIGN.md §13),
  each pair is reduced by `obs.profile.overlap_ratio`: the step wall
  the overlap lowering hid, and — when the calibration fit supplied a
  compute floor — what fraction of the off-run's exposed exchange wall
  that is.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.obs import cli


def _series(events: List[dict], kind: str) -> List[dict]:
    return [e for e in events if e.get("kind") == kind]


def _stats(xs: List[float]) -> Dict[str, float]:
    return {"mean": sum(xs) / len(xs), "min": min(xs), "max": max(xs),
            "n": len(xs)}


def summarize(events: List[dict]) -> dict:
    """The report's data model: pure function of the event list so tests
    can assert on it and ``--json`` can dump it."""
    out: dict = {}
    meta = _series(events, "run_meta")
    if meta:
        out["run"] = {k: meta[-1].get(k) for k in
                      ("strategy", "arch", "steps", "n_workers",
                       "obs_metrics")}

    timing = _series(events, "timing")
    if timing:
        steps_s = [e["step_s"] for e in timing]
        out["timing"] = {
            "step_s": _stats(steps_s),
            "intervals": [{"step": e["step"],
                           "interval_s": e["interval_s"],
                           "steps": e.get("steps_in_interval", 1)}
                          for e in timing],
        }

    obs = _series(events, "obs_metrics")
    comm = _series(events, "comm_summary")
    if obs:
        last = obs[-1]
        ef1 = [e["ef_e1_norm"] for e in obs if "ef_e1_norm" in e]
        ef2 = [e["ef_e2_norm"] for e in obs if "ef_e2_norm" in e]
        o: dict = {"last_step": last.get("step"),
                   "delta_hat": last.get("delta_hat")}
        if ef1:
            o["ef_e1"] = {"first": ef1[0], "last": ef1[-1],
                          "growth": (ef1[-1] / ef1[0]
                                     if ef1[0] else None)}
        if ef2:
            o["ef_e2"] = {"first": ef2[0], "last": ef2[-1]}
        if "staleness_hist" in last:
            o["staleness_hist"] = last["staleness_hist"]
        if "msg_var" in last:
            o["msg_mean"] = last.get("msg_mean")
            o["msg_var"] = last["msg_var"]
        out["obs"] = o

        # δ̂ vs the planner's analytic δ, per bucket
        rows = (comm[-1].get("per_bucket") or []) if comm else []
        measured = last.get("bucket_delta")
        if rows and measured is not None:
            out["delta_gap"] = [
                {"bucket": r["bucket"], "compressor": r["compressor"],
                 "assumed": r["delta"], "measured": measured[r["bucket"]],
                 "gap": measured[r["bucket"]] - r["delta"]}
                for r in rows if r["bucket"] < len(measured)]

    if comm:
        last = comm[-1]
        c = {k: last[k] for k in
             ("wire_bytes_per_step", "compression_ratio", "sim_clock_s")
             if k in last}
        if "budget_utilization" in last:
            c["budget_bytes"] = last.get("budget_bytes")
            c["budget_utilization"] = last["budget_utilization"]
        if last.get("per_bucket"):
            c["per_bucket"] = last["per_bucket"]
        out["comm"] = c

    prof = _series(events, "profile")
    if prof:
        out["profile"] = prof[-1]

    # measured-vs-modeled: calibrate on the file's own events (skipped
    # cleanly when the fit has nothing to chew on)
    from repro.obs import calibrate as _cal
    runs = _cal.extract_runs(events)
    if runs:
        try:
            out["calibration"] = _cal.calibrate(runs)
        except (ValueError, KeyError):
            pass  # e.g. delayed-only input: no linear run to fit
        overlap = _overlap_rows(runs, out.get("calibration"))
        if overlap:
            out["overlap"] = overlap
    return out


# --------------------------------------------------------------------------- #
def _sans_overlap(strategy_json: dict) -> str:
    """Pairing key: the strategy JSON with exchange.overlap removed."""
    sj = json.loads(json.dumps(strategy_json))
    if isinstance(sj.get("exchange"), dict):
        sj["exchange"].pop("overlap", None)
    return json.dumps(sj, sort_keys=True)


def _overlap_rows(runs, calibration: Optional[dict]) -> List[dict]:
    """Measured overlap rows (DESIGN.md §13): match recorded runs whose
    strategies differ ONLY in ``exchange.overlap`` and reduce each
    on/off pair with `obs.profile.overlap_ratio`. The exposed exchange
    wall of the off run is estimated as ``t_off - t_compute`` when a
    calibration fit is available; without one the row still reports the
    hidden seconds, just not the fraction."""
    from repro.obs.profile import overlap_ratio
    groups: Dict[tuple, list] = {}
    for r in runs:
        groups.setdefault(
            (_sans_overlap(r.strategy_json), r.n_workers), []).append(r)
    t_c = (calibration or {}).get("t_compute_s")
    rows: List[dict] = []
    for (_, W), grp in sorted(groups.items()):
        def _is_on(r):
            ex = r.strategy_json.get("exchange")
            return bool(isinstance(ex, dict) and ex.get("overlap"))
        on = [r for r in grp if _is_on(r)]
        off = [r for r in grp if not _is_on(r)]
        if not on or not off:
            continue
        a, b = on[-1], off[-1]
        exchange_s = None
        if t_c is not None:
            exchange_s = max(b.measured_step_s - t_c, 0.0) or None
        ratio = overlap_ratio(a.measured_step_s, b.measured_step_s,
                              exchange_s)
        try:
            schedule = a.cost_inputs()[0].describe()
        except Exception:
            schedule = "?"
        rows.append({"schedule": schedule, "n_workers": W,
                     **{k: round(v, 6) for k, v in ratio.items()}})
    return rows


# --------------------------------------------------------------------------- #
def _fmt_bytes(n) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def render(summary: dict) -> str:
    lines: List[str] = []
    run = summary.get("run")
    if run:
        lines.append(f"run {run.get('strategy')}  arch={run.get('arch')}  "
                     f"steps={run.get('steps')}  W={run.get('n_workers')}  "
                     f"obs={run.get('obs_metrics')}")

    t = summary.get("timing")
    if t:
        s = t["step_s"]
        lines.append("")
        lines.append(f"timing (synced): step {s['mean'] * 1e3:.2f}ms mean  "
                     f"[{s['min'] * 1e3:.2f} .. {s['max'] * 1e3:.2f}]  "
                     f"over {s['n']} logged steps")
        for iv in t["intervals"]:
            per = iv["interval_s"] / max(iv["steps"], 1)
            lines.append(f"  step {iv['step']:>6}: interval "
                         f"{iv['interval_s'] * 1e3:8.2f}ms / "
                         f"{iv['steps']} steps = {per * 1e3:.2f}ms/step")

    prof = summary.get("profile")
    if prof:
        s = prof["step_s"]
        lines.append("")
        lines.append(
            f"profile window: steps {prof['step0']}..."
            f"{prof['step0'] + prof['n_steps'] - 1}  "
            f"step {s['mean'] * 1e3:.2f}ms mean  "
            f"[{s['min'] * 1e3:.2f} .. {s['max'] * 1e3:.2f}]  "
            f"p50 {s['p50'] * 1e3:.2f}ms  "
            f"({prof.get('exchange_steps', '?')} exchange steps)")
        for name, rec in (prof.get("host_phases") or {}).items():
            lines.append(f"  host  {name:>9}: {rec['total_s'] * 1e3:8.2f}ms "
                         f"over {rec['n']} calls")
        for name, rec in (prof.get("device_phases") or {}).items():
            lines.append(f"  device{name:>9}: {rec['ops']:>5} ops  "
                         f"{_fmt_bytes(rec['bytes'])} result traffic")
        if prof.get("trace_dir"):
            lines.append(f"  trace: {prof['trace_dir']}")

    gap = summary.get("delta_gap")
    if gap:
        lines.append("")
        lines.append("empirical δ̂ vs assumed δ (last logged step):")
        for g in gap:
            lines.append(f"  bucket {g['bucket']:>3} {g['compressor']:>14}: "
                         f"assumed {g['assumed']:.4f}  measured "
                         f"{g['measured']:.4f}  gap {g['gap']:+.4f}")
    obs = summary.get("obs")
    if obs and not gap and obs.get("delta_hat") is not None:
        lines.append("")
        lines.append(f"empirical δ̂ (aggregate, last logged step): "
                     f"{obs['delta_hat']:.4f}")

    comm = summary.get("comm")
    if comm:
        lines.append("")
        if "budget_utilization" in comm:
            lines.append(f"bytes vs budget: "
                         f"{_fmt_bytes(comm['wire_bytes_per_step'])}/step "
                         f"against {_fmt_bytes(comm['budget_bytes'])} "
                         f"budget = {comm['budget_utilization'] * 100:.1f}% "
                         f"utilization")
        else:
            lines.append(f"wire: {_fmt_bytes(comm['wire_bytes_per_step'])}"
                         f"/step  ratio {comm.get('compression_ratio')}x")
        for r in comm.get("per_bucket", []):
            share = (f"  {r['budget_share'] * 100:5.1f}% of budget"
                     if "budget_share" in r else "")
            bits = f"{r['bits']}b" if r.get("bits") else "fp"
            lines.append(f"  bucket {r['bucket']:>3} "
                         f"{r['compressor']:>14} ({bits:>3}): "
                         f"{r['elems']:>9} elems  "
                         f"{_fmt_bytes(r['payload_bytes'])}{share}")

    if obs:
        ef = obs.get("ef_e1")
        if ef:
            lines.append("")
            growth = (f"  ({ef['growth']:.2f}x over the run)"
                      if ef.get("growth") else "")
            lines.append(f"EF residual ‖e1‖: {ef['first']:.4f} → "
                         f"{ef['last']:.4f}{growth}")
            e2 = obs.get("ef_e2")
            if e2 and (e2["first"] or e2["last"]):
                lines.append(f"EF residual ‖e2‖: {e2['first']:.4f} → "
                             f"{e2['last']:.4f}")
        if "staleness_hist" in obs:
            hist = obs["staleness_hist"]
            cells = "  ".join(f"τ={i}:{int(c)}" for i, c in enumerate(hist))
            lines.append(f"staleness histogram (last logged step): {cells}")
        if "msg_var" in obs:
            lines.append(f"message moments (aggregate): mean "
                         f"{obs['msg_mean']:.3e}  var {obs['msg_var']:.3e}")

    ov = summary.get("overlap")
    if ov:
        lines.append("")
        lines.append("overlap (paired exchange.overlap on/off runs):")
        for r in ov:
            row = (f"  {r['schedule']:<18} W={r['n_workers']:<3} "
                   f"step on {r['t_on_s'] * 1e3:8.2f}ms / "
                   f"off {r['t_off_s'] * 1e3:8.2f}ms  "
                   f"hidden {r['hidden_s'] * 1e3:.2f}ms")
            if "hidden_frac" in r:
                row += (f" of {r['exchange_s'] * 1e3:.2f}ms exchange "
                        f"({r['hidden_frac'] * 100:.0f}% hidden, "
                        f"{r['exposed_s'] * 1e3:.2f}ms exposed)")
            lines.append(row)

    cal = summary.get("calibration")
    if cal:
        from repro.obs import calibrate as _cal
        lines.append("")
        lines.append(_cal.render(cal))

    if not lines:
        lines.append("no renderable events (is this a sink file?)")
    return "\n".join(lines)


DESCRIPTION = "render a repro.obs run-sink JSONL file"


def add_args(ap: argparse.ArgumentParser) -> None:
    """Mount the report arguments (shared IO contract: repro.obs.cli)."""
    ap.add_argument("path", help="sink file written by --obs-sink PATH")
    cli.add_io_args(ap, out_help="write the summary JSON here")


def run(args: argparse.Namespace) -> int:
    events = cli.read_paths([args.path], validate=not args.no_validate)
    summary = summarize(events)
    cli.emit(args, summary, render(summary))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs report",
                                 description=DESCRIPTION)
    add_args(ap)
    return run(ap.parse_args(argv))


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    raise SystemExit(main())
