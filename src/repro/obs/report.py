"""Render a run-sink JSONL file (DESIGN.md §11).

    PYTHONPATH=src python -m repro.obs report experiments/run_sink.jsonl

Sections (each skipped cleanly when its events are absent):

* **timing** — synced per-step wall times from ``timing`` events: the
  per-step series (mean / min / max) and interval throughput. These are
  real device-synced times (train.py blocks on the step output every
  step), not dispatch latencies.
* **empirical δ vs assumed δ** — joins the last ``obs_metrics`` event's
  per-bucket δ̂ against the analytic per-bucket δ the planner assumed
  (``comm_summary.per_bucket[*].delta``); the gap says how conservative
  the δ-budget plan really is on this gradient stream.
* **bytes vs budget** — payload utilization against the effective byte
  budget, overall and per bucket.
* **EF residual growth** — the fleet ‖e1‖ / ‖e2‖ series across the run;
  unbounded growth here is the classic sign of a divergent
  error-feedback loop (paper Thm. 2 needs it bounded).
* **profile** — the step-profiler window (schema v2 ``profile``
  events): per-window step-wall stats, host-phase split and, when spans
  were on, the HLO-derived device-phase attribution (DESIGN.md §12.1).
* **measured vs modeled** — when the file holds enough to calibrate
  (run_meta + timing/profile + comm_summary), the report runs
  `repro.obs.calibrate` on its own events and prints the fitted
  constants plus per-run drift (DESIGN.md §12.3).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.obs.sink import read_events


def _series(events: List[dict], kind: str) -> List[dict]:
    return [e for e in events if e.get("kind") == kind]


def _stats(xs: List[float]) -> Dict[str, float]:
    return {"mean": sum(xs) / len(xs), "min": min(xs), "max": max(xs),
            "n": len(xs)}


def summarize(events: List[dict]) -> dict:
    """The report's data model: pure function of the event list so tests
    can assert on it and ``--json`` can dump it."""
    out: dict = {}
    meta = _series(events, "run_meta")
    if meta:
        out["run"] = {k: meta[-1].get(k) for k in
                      ("strategy", "arch", "steps", "n_workers",
                       "obs_metrics")}

    timing = _series(events, "timing")
    if timing:
        steps_s = [e["step_s"] for e in timing]
        out["timing"] = {
            "step_s": _stats(steps_s),
            "intervals": [{"step": e["step"],
                           "interval_s": e["interval_s"],
                           "steps": e.get("steps_in_interval", 1)}
                          for e in timing],
        }

    obs = _series(events, "obs_metrics")
    comm = _series(events, "comm_summary")
    if obs:
        last = obs[-1]
        ef1 = [e["ef_e1_norm"] for e in obs if "ef_e1_norm" in e]
        ef2 = [e["ef_e2_norm"] for e in obs if "ef_e2_norm" in e]
        o: dict = {"last_step": last.get("step"),
                   "delta_hat": last.get("delta_hat")}
        if ef1:
            o["ef_e1"] = {"first": ef1[0], "last": ef1[-1],
                          "growth": (ef1[-1] / ef1[0]
                                     if ef1[0] else None)}
        if ef2:
            o["ef_e2"] = {"first": ef2[0], "last": ef2[-1]}
        if "staleness_hist" in last:
            o["staleness_hist"] = last["staleness_hist"]
        if "msg_var" in last:
            o["msg_mean"] = last.get("msg_mean")
            o["msg_var"] = last["msg_var"]
        out["obs"] = o

        # δ̂ vs the planner's analytic δ, per bucket
        rows = (comm[-1].get("per_bucket") or []) if comm else []
        measured = last.get("bucket_delta")
        if rows and measured is not None:
            out["delta_gap"] = [
                {"bucket": r["bucket"], "compressor": r["compressor"],
                 "assumed": r["delta"], "measured": measured[r["bucket"]],
                 "gap": measured[r["bucket"]] - r["delta"]}
                for r in rows if r["bucket"] < len(measured)]

    if comm:
        last = comm[-1]
        c = {k: last[k] for k in
             ("wire_bytes_per_step", "compression_ratio", "sim_clock_s")
             if k in last}
        if "budget_utilization" in last:
            c["budget_bytes"] = last.get("budget_bytes")
            c["budget_utilization"] = last["budget_utilization"]
        if last.get("per_bucket"):
            c["per_bucket"] = last["per_bucket"]
        out["comm"] = c

    prof = _series(events, "profile")
    if prof:
        out["profile"] = prof[-1]

    # measured-vs-modeled: calibrate on the file's own events (skipped
    # cleanly when the fit has nothing to chew on)
    from repro.obs import calibrate as _cal
    runs = _cal.extract_runs(events)
    if runs:
        try:
            out["calibration"] = _cal.calibrate(runs)
        except (ValueError, KeyError):
            pass  # e.g. delayed-only input: no linear run to fit
    return out


# --------------------------------------------------------------------------- #
def _fmt_bytes(n) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def render(summary: dict) -> str:
    lines: List[str] = []
    run = summary.get("run")
    if run:
        lines.append(f"run {run.get('strategy')}  arch={run.get('arch')}  "
                     f"steps={run.get('steps')}  W={run.get('n_workers')}  "
                     f"obs={run.get('obs_metrics')}")

    t = summary.get("timing")
    if t:
        s = t["step_s"]
        lines.append("")
        lines.append(f"timing (synced): step {s['mean'] * 1e3:.2f}ms mean  "
                     f"[{s['min'] * 1e3:.2f} .. {s['max'] * 1e3:.2f}]  "
                     f"over {s['n']} logged steps")
        for iv in t["intervals"]:
            per = iv["interval_s"] / max(iv["steps"], 1)
            lines.append(f"  step {iv['step']:>6}: interval "
                         f"{iv['interval_s'] * 1e3:8.2f}ms / "
                         f"{iv['steps']} steps = {per * 1e3:.2f}ms/step")

    prof = summary.get("profile")
    if prof:
        s = prof["step_s"]
        lines.append("")
        lines.append(
            f"profile window: steps {prof['step0']}..."
            f"{prof['step0'] + prof['n_steps'] - 1}  "
            f"step {s['mean'] * 1e3:.2f}ms mean  "
            f"[{s['min'] * 1e3:.2f} .. {s['max'] * 1e3:.2f}]  "
            f"p50 {s['p50'] * 1e3:.2f}ms  "
            f"({prof.get('exchange_steps', '?')} exchange steps)")
        for name, rec in (prof.get("host_phases") or {}).items():
            lines.append(f"  host  {name:>9}: {rec['total_s'] * 1e3:8.2f}ms "
                         f"over {rec['n']} calls")
        for name, rec in (prof.get("device_phases") or {}).items():
            lines.append(f"  device{name:>9}: {rec['ops']:>5} ops  "
                         f"{_fmt_bytes(rec['bytes'])} result traffic")
        if prof.get("trace_dir"):
            lines.append(f"  trace: {prof['trace_dir']}")

    gap = summary.get("delta_gap")
    if gap:
        lines.append("")
        lines.append("empirical δ̂ vs assumed δ (last logged step):")
        for g in gap:
            lines.append(f"  bucket {g['bucket']:>3} {g['compressor']:>14}: "
                         f"assumed {g['assumed']:.4f}  measured "
                         f"{g['measured']:.4f}  gap {g['gap']:+.4f}")
    obs = summary.get("obs")
    if obs and not gap and obs.get("delta_hat") is not None:
        lines.append("")
        lines.append(f"empirical δ̂ (aggregate, last logged step): "
                     f"{obs['delta_hat']:.4f}")

    comm = summary.get("comm")
    if comm:
        lines.append("")
        if "budget_utilization" in comm:
            lines.append(f"bytes vs budget: "
                         f"{_fmt_bytes(comm['wire_bytes_per_step'])}/step "
                         f"against {_fmt_bytes(comm['budget_bytes'])} "
                         f"budget = {comm['budget_utilization'] * 100:.1f}% "
                         f"utilization")
        else:
            lines.append(f"wire: {_fmt_bytes(comm['wire_bytes_per_step'])}"
                         f"/step  ratio {comm.get('compression_ratio')}x")
        for r in comm.get("per_bucket", []):
            share = (f"  {r['budget_share'] * 100:5.1f}% of budget"
                     if "budget_share" in r else "")
            bits = f"{r['bits']}b" if r.get("bits") else "fp"
            lines.append(f"  bucket {r['bucket']:>3} "
                         f"{r['compressor']:>14} ({bits:>3}): "
                         f"{r['elems']:>9} elems  "
                         f"{_fmt_bytes(r['payload_bytes'])}{share}")

    if obs:
        ef = obs.get("ef_e1")
        if ef:
            lines.append("")
            growth = (f"  ({ef['growth']:.2f}x over the run)"
                      if ef.get("growth") else "")
            lines.append(f"EF residual ‖e1‖: {ef['first']:.4f} → "
                         f"{ef['last']:.4f}{growth}")
            e2 = obs.get("ef_e2")
            if e2 and (e2["first"] or e2["last"]):
                lines.append(f"EF residual ‖e2‖: {e2['first']:.4f} → "
                             f"{e2['last']:.4f}")
        if "staleness_hist" in obs:
            hist = obs["staleness_hist"]
            cells = "  ".join(f"τ={i}:{int(c)}" for i, c in enumerate(hist))
            lines.append(f"staleness histogram (last logged step): {cells}")
        if "msg_var" in obs:
            lines.append(f"message moments (aggregate): mean "
                         f"{obs['msg_mean']:.3e}  var {obs['msg_var']:.3e}")

    cal = summary.get("calibration")
    if cal:
        from repro.obs import calibrate as _cal
        lines.append("")
        lines.append(_cal.render(cal))

    if not lines:
        lines.append("no renderable events (is this a sink file?)")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs report",
        description="render a repro.obs run-sink JSONL file")
    ap.add_argument("path", help="sink file written by --obs-sink PATH")
    ap.add_argument("--json", action="store_true",
                    help="dump the computed summary as JSON instead of "
                         "the text rendering")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation when reading")
    args = ap.parse_args(argv)
    events = read_events(args.path, validate=not args.no_validate)
    summary = summarize(events)
    if args.json:
        print(json.dumps(summary, indent=2))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    raise SystemExit(main())
