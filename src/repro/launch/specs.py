"""ShapeDtypeStruct input stand-ins for every (architecture × input-shape)
combination — weak-type-correct, shardable, no device allocation. This is
what the multi-pod dry-run lowers against."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import build
from repro.parallel import sharding as shd


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh):
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(mesh, B)
    batch = {
        "tokens": _sds((B, S), jnp.int32, mesh, bspec),
        "targets": _sds((B, S), jnp.int32, mesh, bspec),
    }
    if cfg.is_encdec:
        batch["enc_embeds"] = _sds(
            (B, cfg.encdec.enc_seq, cfg.d_model),
            jnp.dtype(cfg.param_dtype), mesh, bspec,
        )
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(mesh, B)
    args = [_sds((B, S), jnp.int32, mesh, bspec)]
    if cfg.is_encdec:
        args.append(_sds((B, cfg.encdec.enc_seq, cfg.d_model),
                         jnp.dtype(cfg.param_dtype), mesh, bspec))
    else:
        args.append(None)
    return tuple(args)


def decode_input_specs(cfg: ModelConfig, shape: InputShape, mesh,
                       kv_layout: str = "hd_model"):
    """(tokens, caches) stand-ins for serve_step: ONE new token against a
    KV/state cache of shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    bundle = build(cfg)
    caches = jax.eval_shape(lambda: bundle.init_cache(B, S))
    specs = shd.cache_specs(caches, mesh, B, kv_layout)
    caches_sds = jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, mesh, s),
        caches, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    bspec = shd.batch_spec(mesh, B)
    tokens = _sds((B, 1), jnp.int32, mesh, bspec)
    return tokens, caches_sds


def abstract_params(cfg: ModelConfig, mesh, layout: str, max_seq: int):
    """Parameter ShapeDtypeStructs with the layout's shardings attached."""
    bundle = build(cfg)
    params = jax.eval_shape(lambda: bundle.init(jax.random.key(0), max_seq))
    pspecs = shd.param_specs(params, cfg, layout, mesh)
    return jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, mesh, s),
        params, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    ), pspecs


def key_spec():
    return jax.eval_shape(lambda: jax.random.key(0))
