import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove the sharding config is coherent, and dump the
roofline ingredients (FLOPs, bytes, per-category collective bytes, memory
analysis) to experiments/dryrun/*.json.

Single combo:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single
Full sweep (subprocess per combo, cached by output file):
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import re
import subprocess
import sys
import time

# v5e roofline constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_TYPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                      r"\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (partitioned,
    per-device) optimized HLO, keyed by op kind and element type."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*([^=]*?)\s*(" + "|".join(_COLL_OPS) +
                      r")(-start)?\(", line)
        if not m or "-done(" in line:
            continue
        result_types, op = m.group(1), m.group(2)
        nbytes = 0
        int8 = 0
        for dt, dims in _TYPE_RE.findall(result_types):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            b = n * _DTYPE_BYTES[dt]
            nbytes += b
            if dt in ("s8", "u8", "pred"):
                int8 += b
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "int8_bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["int8_bytes"] += int8
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only; N active for
    MoE. D = tokens processed per step (whole job, all chips)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def attention_flops(cfg, shape) -> float:
    """Analytic attention FLOPs (not covered by 6·N·D): 4·tokens·Keff·H·hd
    per attention layer forward (QKᵀ + PV), ×3 with backward for training.
    Keff = average attended keys (causal ≈ S/2, bounded by the window)."""
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_pattern[i % len(cfg.layer_pattern)] == "attn")
    if cfg.is_encdec:
        n_attn += cfg.encdec.enc_layers + cfg.num_layers  # enc self + cross
    if n_attn == 0:
        return 0.0
    S = shape.seq_len
    H, hd = cfg.num_heads, cfg.head_dim
    win = cfg.attention_window
    if shape.kind == "decode":
        keff = min(S, win) if win else S
        tokens = shape.global_batch
        mult = 1.0
    else:
        keff = min(S / 2, win) if win else S / 2
        tokens = shape.global_batch * S
        mult = 3.0 if shape.kind == "train" else 1.0
    return mult * 4.0 * tokens * keff * H * hd * n_attn


def analytic_flops(cfg, shape) -> float:
    return model_flops(cfg, shape) + attention_flops(cfg, shape)


def applicable(cfg, shape) -> tuple:
    """(runs?, reason)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic attention (DESIGN.md §long_500k)")
    return True, ""


# --------------------------------------------------------------------------- #
def run_combo(arch: str, shape_name: str, multi_pod: bool, *,
              exchange: str, compressor: str, optimizer: str,
              extrapolation: str, layout: str = "auto",
              out_path: str = None, tag: str = "",
              lower_only: bool = False, moe_dispatch: str = "",
              remat: str = "", ef_dtype: str = "bfloat16",
              kv_layout: str = "hd_model", mesh_override: str = "") -> dict:
    import jax
    import jax.numpy as jnp

    import repro.configs as cfgs
    from repro.configs.base import DQConfig, SHAPES
    from repro.core.dqgan import DQGAN
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh, worker_axes_for
    from repro.models import build
    from repro.parallel import sharding as shd

    import dataclasses as _dc

    cfg = cfgs.get(arch)
    if moe_dispatch and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch=moe_dispatch))
    if remat:
        cfg = _dc.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "exchange": exchange, "compressor": compressor,
        "optimizer": optimizer, "layout": layout, "tag": tag,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not ok:
        rec.update(status="skip", reason=reason)
        return _finish(rec, out_path)

    if layout == "auto":
        layout = "fsdp" if cfg.param_count() > 10e9 else "dp"
        rec["layout"] = layout

    mesh = make_production_mesh(multi_pod=multi_pod, override=mesh_override)
    if mesh_override:
        rec["mesh"] = mesh_override.replace(",", "x")
    n_chips = mesh.size
    rec["chips"] = n_chips
    t0 = time.time()
    try:
        from repro.parallel.compat import set_mesh
        with set_mesh(mesh):
            max_seq = shape.seq_len if not cfg.use_rope else 0
            params_sds, pspecs = S.abstract_params(cfg, mesh, layout,
                                                   max_seq or 8)
            bundle = build(cfg)
            if shape.kind == "train":
                waxes = worker_axes_for(layout, multi_pod)
                spmd = "shard_map"
                if layout == "fsdp" and multi_pod:
                    # XLA's SPMD partitioner CHECK-fails on shard_map manual
                    # over 'pod' with FSDP auto axes inside (DESIGN.md §2);
                    # the vmap worker formulation is semantics-identical.
                    spmd = "vmap"
                    exchange = "sim"
                    rec["exchange"] = "sim(vmap)"
                dq = DQConfig(
                    compressor=compressor, exchange=exchange,
                    optimizer=optimizer, extrapolation=extrapolation,
                    worker_axes=waxes, ef_dtype=ef_dtype, spmd=spmd,
                )
                # shard_map manual specs use worker axes only; jit-level
                # batch sharding spans all data axes.
                manual_bspec = jax.sharding.PartitionSpec(waxes) if waxes \
                    else jax.sharding.PartitionSpec()
                trainer = DQGAN(field_fn=bundle.field_fn, dq=dq, mesh=mesh,
                                param_specs=pspecs, batch_spec=manual_bspec)
                state_sds = trainer.init_abstract(params_sds)
                batch_sds = S.train_batch_specs(cfg, shape, mesh)
                rec["n_workers"] = trainer.n_workers
                lowered = jax.jit(trainer.step).lower(
                    state_sds, batch_sds, S.key_spec())
            elif shape.kind == "prefill":
                args = S.prefill_input_specs(cfg, shape, mesh)
                lowered = jax.jit(bundle.prefill).lower(params_sds, *args)
            else:  # decode
                rec["kv_layout"] = kv_layout
                tokens, caches = S.decode_input_specs(cfg, shape, mesh,
                                                      kv_layout)
                lowered = jax.jit(bundle.decode_step).lower(
                    params_sds, tokens, caches)
            rec["lower_s"] = round(time.time() - t0, 2)
            if lower_only:
                rec["status"] = "lowered"
                return _finish(rec, out_path)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

            ca = compiled.cost_analysis() or {}
            rec["flops"] = float(ca.get("flops", 0.0))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
            try:
                ma = compiled.memory_analysis()
                if ma is not None:
                    rec["memory_analysis"] = {
                        k: int(getattr(ma, k))
                        for k in ("argument_size_in_bytes",
                                  "output_size_in_bytes",
                                  "temp_size_in_bytes",
                                  "generated_code_size_in_bytes")
                        if hasattr(ma, k)
                    }
            except Exception as e:  # pragma: no cover
                rec["memory_analysis_error"] = str(e)
            hlo = compiled.as_text()
            rec["collectives"] = parse_collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
            # while-trip-corrected accounting (cost_analysis counts loop
            # bodies once — see EXPERIMENTS.md §Dry-run/validity)
            try:
                from repro.launch.hlo_analysis import analyze
                rec["corrected"] = analyze(hlo)
            except Exception as e:  # pragma: no cover
                rec["corrected_error"] = str(e)[:500]

            # ---- roofline terms (per-chip; see benchmarks/roofline.py) --- #
            rec["mf"] = model_flops(cfg, shape)
            rec["analytic_flops"] = analytic_flops(cfg, shape)
            corr = rec.get("corrected") or {}
            coll = corr.get("collectives") or rec["collectives"]
            coll_bytes = sum(v["bytes"] for v in coll.values())
            mem_bytes = corr.get("traffic_result_bytes",
                                 rec["bytes_accessed"])
            rec["roofline"] = {
                # analytic per-chip FLOPs: cost_analysis undercounts scan
                # bodies; raw value kept in rec["flops"] for reference
                "compute_s": rec["analytic_flops"] / n_chips / PEAK_FLOPS,
                "memory_s": mem_bytes / HBM_BW,
                "collective_s": coll_bytes / ICI_BW,
            }
            dom = max(rec["roofline"], key=rec["roofline"].get)
            rec["bottleneck"] = dom.replace("_s", "")
            rec["status"] = "ok"
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000],
                   elapsed_s=round(time.time() - t0, 2))
    return _finish(rec, out_path)


def _finish(rec, out_path):
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "status", "bottleneck",
                       "compile_s", "reason", "error")}))
    return rec


# --------------------------------------------------------------------------- #
def all_combos(mesh_arg: str):
    import repro.configs as cfgs
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[mesh_arg]
    for arch in list(cfgs.ASSIGNED) + ["gemma-2b-swa"]:
        for sh in shapes:
            for mp in meshes:
                yield arch, sh, mp


def driver(args):
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)
    combos = list(all_combos(args.mesh))
    todo = []
    for arch, sh, mp in combos:
        name = f"{arch}__{sh}__{'multi' if mp else 'single'}"
        if args.tag:
            name += f"__{args.tag}"
        path = os.path.join(outdir, name + ".json")
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skip"):
                    continue
        todo.append((arch, sh, mp, path))
    print(f"{len(combos)} combos, {len(todo)} to run", flush=True)
    procs: list = []
    for arch, sh, mp, path in todo:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", sh,
               "--mesh", "multi" if mp else "single",
               "--exchange", args.exchange, "--compressor", args.compressor,
               "--optimizer", args.optimizer,
               "--extrapolation", args.extrapolation,
               "--layout", args.layout, "--out", path, "--tag", args.tag]
        while len([p for p in procs if p.poll() is None]) >= args.jobs:
            time.sleep(5)
        procs = [p for p in procs if p.poll() is None]
        print("RUN", arch, sh, "multi" if mp else "single", flush=True)
        procs.append(subprocess.Popen(cmd))
    for p in procs:
        p.wait()
    # summary
    ok = err = skip = 0
    for fn in sorted(os.listdir(outdir)):
        if fn.endswith(".json"):
            with open(os.path.join(outdir, fn)) as f:
                st = json.load(f).get("status")
            ok += st == "ok"
            err += st == "error"
            skip += st == "skip"
    print(f"done: ok={ok} skip={skip} error={err}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--exchange", default="two_phase")
    ap.add_argument("--compressor", default="qsgd8_linf")
    ap.add_argument("--optimizer", default="omd")
    ap.add_argument("--extrapolation", default="local")
    ap.add_argument("--layout", default="auto")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--moe-dispatch", default="")
    ap.add_argument("--remat", default="")
    ap.add_argument("--ef-dtype", default="bfloat16")
    ap.add_argument("--kv-layout", default="hd_model")
    ap.add_argument("--mesh-override", default="")
    args = ap.parse_args()
    if args.all:
        driver(args)
        return
    out = args.out
    if out is None:
        name = f"{args.arch}__{args.shape}__{args.mesh}"
        if args.tag:
            name += f"__{args.tag}"
        out = os.path.join(args.outdir, name + ".json")
    run_combo(args.arch, args.shape, args.mesh == "multi",
              exchange=args.exchange, compressor=args.compressor,
              optimizer=args.optimizer, extrapolation=args.extrapolation,
              layout=args.layout, out_path=out, tag=args.tag,
              lower_only=args.lower_only, moe_dispatch=args.moe_dispatch,
              remat=args.remat, ef_dtype=args.ef_dtype,
              kv_layout=args.kv_layout, mesh_override=args.mesh_override)


if __name__ == "__main__":
    main()
