"""While-loop-aware analysis of optimized (post-SPMD, per-device) HLO text.

XLA's `compiled.cost_analysis()` on CPU counts each while-loop *body once*,
which understates FLOPs/bytes/collectives for scan-over-layers models by
~num_layers (verified in EXPERIMENTS.md §Dry-run). This module re-walks the
HLO call graph with loop-trip multipliers:

  * computations are parsed from the text;
  * `while` ops contribute body+condition costs × trip count, where the
    trip count is recovered from the comparison constant in the condition
    computation (lax.scan lowers to `iv < constant`);
  * `fusion`/`call`/`conditional` recurse into their called computations
    (conditional branches counted once — upper bound of one branch);
  * per-instruction cost = result-shape bytes (traffic proxy) and, for
    collective ops, collective bytes by category;
  * dot/convolution FLOPs are NOT re-derived here (operand shapes are not
    printed in optimized HLO) — the roofline compute term instead uses the
    analytic MODEL_FLOPS counter (launch.dryrun.model_flops + attention
    terms), with raw cost_analysis FLOPs reported alongside.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_TYPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|s4|u4"
    r"|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_COMMENT = re.compile(r"/\*.*?\*/")
_CALLED = re.compile(
    r"(?:calls=|condition=|body=|to_apply=|branch_computations=\{)"
    r"\s*%?([\w.\-]+(?:\s*,\s*%?[\w.\-]+)*)")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(txt: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in txt.splitlines():
        stripped = _COMMENT.sub("", line).strip()
        if not stripped:
            continue
        if ("->" in stripped and "{" in stripped and "=" not in
                stripped.split("->")[0]):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped.startswith("}"):
            # keep cur so stray ROOT lines don't crash; next header resets
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _constants(lines: List[str]) -> Dict[str, int]:
    out = {}
    for ln in lines:
        m = re.match(r"%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)",
                     ln)
        if m:
            out[m.group(1)] = int(m.group(2))
    return out


def _trip_count(cond_lines: List[str]) -> int:
    """lax.scan condition: compare(iv, const) direction=LT."""
    consts = _constants(cond_lines)
    for ln in cond_lines:
        if "compare(" in ln:
            args = re.search(r"compare\(([^)]*)\)", ln)
            if not args:
                continue
            for a in args.group(1).split(","):
                name = a.strip().lstrip("%")
                if name in consts:
                    return max(consts[name], 1)
    if consts:
        return max(consts.values())
    return 1


class HLOAnalysis:
    def __init__(self, txt: str):
        self.comps = parse_computations(txt)
        self.entry = None
        for line in txt.splitlines():
            if line.strip().startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip()[len("ENTRY"):].strip())
                if m:
                    self.entry = m.group(1)
        if self.entry is None and self.comps:
            self.entry = list(self.comps)[-1]
        self.collectives: Dict[str, dict] = {}
        self.traffic_bytes = 0.0
        self.while_trips: List[int] = []
        self._walk(self.entry, 1.0, set())

    def _walk(self, comp: str, mult: float, stack: frozenset):
        lines = self.comps.get(comp)
        if lines is None or comp in stack:
            return
        stack = set(stack) | {comp}
        for ln in lines:
            if "=" not in ln:
                continue
            lhs, rhs = ln.split("=", 1)
            op_m = re.match(r"\s*\(?[\w\[\],{}\s/*]*?\)?\s*([\w\-]+)\(",
                            rhs.strip())
            opname = op_m.group(1) if op_m else ""
            # the result-type segment: everything left of the op name.
            # `rhs.split("(")[0]` would truncate tuple-typed results
            # (async -start ops, multi-output fusions) at the tuple's
            # own paren and count zero bytes for them.
            if op_m:
                result_seg = rhs.strip()[:op_m.start(1)]
            else:
                result_seg = rhs.split("(")[0]
            # no-cost ops: data-movement bookkeeping and loop plumbing.
            # `fusion` IS counted (its result is the one real HBM write of
            # the whole fused chain) but NOT recursed into — fused
            # elementwise internals stay in registers/VMEM.
            free = opname in (
                "tuple", "get-tuple-element", "parameter", "constant",
                "while", "conditional", "call", "bitcast",
                "after-all", "opt-barrier",
            )
            result_bytes = _shape_bytes(lhs + "=" + result_seg)
            if not free:
                self.traffic_bytes += mult * result_bytes
            cm = re.search(r"\b(" + "|".join(_COLL_OPS) + r")(-start)?\(", rhs)
            if cm and "-done(" not in rhs:
                op = cm.group(1)
                rec = self.collectives.setdefault(
                    op, {"count": 0, "bytes": 0.0, "int8_bytes": 0.0})
                rec["count"] += mult
                entries = _TYPE_RE.findall(lhs + "=" + result_seg)
                if cm.group(2):
                    # async pair: the -start result is a tuple aliasing
                    # the operand(s) alongside the destination buffer(s),
                    # and the matching -done re-prints the destination.
                    # Count the destination half once here (the -done
                    # line is excluded above), not operand + destination.
                    entries = entries[len(entries) // 2:]
                for dt, dims in entries:
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nb = n * _DTYPE_BYTES[dt]
                    rec["bytes"] += mult * nb
                    if dt in ("s8", "u8", "pred", "s4", "u4"):
                        rec["int8_bytes"] += mult * nb
            if "while(" in rhs:
                called = dict(
                    re.findall(r"(condition|body)=%?([\w.\-]+)", rhs))
                body = called.get("body")
                cond = called.get("condition")
                trips = _trip_count(self.comps.get(cond, [])) if cond else 1
                self.while_trips.append(trips)
                if body:
                    self._walk(body, mult * trips, frozenset(stack))
                if cond:
                    self._walk(cond, mult * trips, frozenset(stack))
            else:
                # recurse into real control flow only: fusion computations
                # and reduce to_apply bodies are VMEM/register-resident
                # (their single HBM write is the caller's result, counted
                # above); collectives never appear inside them.
                if opname == "call":
                    for m in re.finditer(r"to_apply=%?([\w.\-]+)", rhs):
                        self._walk(m.group(1), mult, frozenset(stack))
                bm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if bm:
                    for b in bm.group(1).split(","):
                        self._walk(b.strip().lstrip("%"), mult,
                                   frozenset(stack))

    def summary(self) -> dict:
        return {
            "collectives": {
                k: {"count": round(v["count"], 1),
                    "bytes": int(v["bytes"]),
                    "int8_bytes": int(v["int8_bytes"])}
                for k, v in self.collectives.items()
            },
            "traffic_result_bytes": int(self.traffic_bytes),
            "while_trip_counts": sorted(set(self.while_trips), reverse=True),
        }


def analyze(txt: str) -> dict:
    return HLOAnalysis(txt).summary()


# --------------------------------------------------------------------------- #
# named-scope attribution (repro.obs spans → HLO op metadata)
# --------------------------------------------------------------------------- #
_OP_NAME = re.compile(r'op_name="([^"]*)"')


def scope_costs(txt: str, prefix: str = "repro.obs/") -> Dict[str, dict]:
    """Per-scope op counts and result bytes from HLO op metadata.

    `jax.named_scope(prefix + phase)` (obs.device_span) survives into the
    optimized HLO as ``metadata={op_name="...<prefix><phase>/..."}`` on
    every op traced under the scope — so a compiled step lowered with
    spans on can attribute its device-side cost (op count + result-shape
    bytes, the same HBM-traffic proxy `HLOAnalysis` uses) to the
    compress/exchange/apply phases without running a profiler. Fused ops
    carry the scope of their representative op; attribution is therefore
    a proxy, not a cycle count — good enough to rank phases and to feed
    the profile events' per-phase split (DESIGN.md §12.1).

    Returns {phase: {"ops": int, "bytes": int}} for every scope name
    found under `prefix` (the segment right after it)."""
    out: Dict[str, dict] = {}
    for line in txt.splitlines():
        m = _OP_NAME.search(line)
        if not m or prefix not in m.group(1):
            continue
        tail = m.group(1).split(prefix, 1)[1]
        phase = tail.split("/", 1)[0].split('"', 1)[0]
        if not phase:
            continue
        stripped = _COMMENT.sub("", line).strip()
        # the result type sits after `=` and before the op's paren:
        #   %name = f32[8,128]{1,0} fusion(...), metadata={op_name=...}
        if "=" in stripped:
            seg = stripped.split("=", 1)[1].split("(", 1)[0]
        else:
            seg = ""
        rec = out.setdefault(phase, {"ops": 0, "bytes": 0})
        rec["ops"] += 1
        rec["bytes"] += _shape_bytes(seg)
    return out
