"""Serving launcher: thin CLI over the repro.serve continuous-batching
engine (paged KV cache, floor-bucket prefill, optional quantized weights).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16

The old launcher re-jitted prefill and decode inside every generate()
call (and re-derived the cache length per call as S + gen_steps + 1);
the engine compiles each shape exactly once — pass --assert-single-trace
to make the process fail if a decode retrace ever happens.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

import repro.configs as cfgs
from repro.models import model as lm
from repro.serve import (
    Engine,
    Request,
    SequentialGenerator,
    ServeConfig,
    cdiv,
)
from repro.strategy.components import Compression


def build_serve_config(prompt_len: int, gen: int, batch: int) -> ServeConfig:
    """Shapes sized to the workload: enough blocks for `batch` concurrent
    requests of this prompt/gen length, buckets no larger than the prompt
    (floor-bucket prefill)."""
    bs = 16
    need = max(prompt_len + gen - 1, 1)
    mbps = max(cdiv(need, bs), 1)
    buckets = tuple(b for b in (16, 32, 64, 128, 256, 512)
                    if b <= max(prompt_len, 16))
    return ServeConfig(
        max_batch=batch,
        block_size=bs,
        num_blocks=batch * mbps + 2,
        max_blocks_per_seq=mbps,
        prompt_buckets=buckets,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="requests (and engine decode slots)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sequential", action="store_true",
                    help="batch-1 baseline instead of the batching engine")
    ap.add_argument("--quantize-weights", default=None, metavar="COMPRESSOR",
                    help="serve quantized weights, e.g. qsgd8_linf")
    ap.add_argument("--weight-plan", default="none",
                    help="per-bucket bit plan: none|uniform|size_tiered|"
                         "delta_budget")
    ap.add_argument("--weight-budget-mb", type=float, default=0.0)
    ap.add_argument("--assert-single-trace", action="store_true",
                    help="fail if the decode step compiled more than once")
    args = ap.parse_args(argv)

    cfg = cfgs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    params = lm.init(key, cfg, 0)
    scfg = build_serve_config(args.prompt_len, args.gen, args.batch)

    compression = None
    if args.quantize_weights:
        compression = Compression(compressor=args.quantize_weights,
                                  plan=args.weight_plan,
                                  budget_mb=args.weight_budget_mb)

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    requests = [Request(rid=i, prompt=prompts[i].tolist(), max_new=args.gen,
                        temperature=args.temperature)
                for i in range(args.batch)]

    if args.sequential:
        runner = SequentialGenerator(cfg, scfg, params,
                                     compression=compression, seed=args.seed)
        t0 = time.time()
        outputs = {r.rid: runner.generate(list(r.prompt), r.max_new,
                                          rid=r.rid,
                                          temperature=r.temperature)
                   for r in requests}
    else:
        runner = Engine(cfg, scfg, params, compression=compression,
                        seed=args.seed)
        t0 = time.time()
        outputs = runner.run(requests)
    dt = time.time() - t0

    stats = runner.stats()
    if args.assert_single_trace:
        assert stats["decode_traces"] == 1, stats
    total = sum(len(v) for v in outputs.values())
    print(json.dumps({
        "arch": cfg.name,
        "mode": "sequential" if args.sequential else "engine",
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "generated": total,
        "tokens_per_s": round(total / max(dt, 1e-9), 1),
        "decode_traces": stats["decode_traces"],
        "weights": stats["weights"],
        "sample_tokens": outputs[0][:8],
    }))
    return outputs


if __name__ == "__main__":
    main()
