"""Serving launcher: batched prefill + autoregressive decode for any
registered arch (greedy or temperature sampling).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

import repro.configs as cfgs
from repro.models import build


def generate(bundle, params, prompt_tokens, gen_steps, key,
             temperature=0.0, enc_embeds=None):
    """prompt_tokens: (B, S). Returns (B, gen_steps) sampled tokens."""
    cfg = bundle.cfg
    B, S = prompt_tokens.shape
    logits, caches = jax.jit(bundle.prefill, static_argnums=3)(
        params, prompt_tokens, enc_embeds, S + gen_steps + 1)

    decode = jax.jit(bundle.decode_step)

    def sample(logits, k):
        if temperature <= 0:
            return jnp.argmax(logits, -1)
        return jax.random.categorical(k, logits / temperature, axis=-1)

    toks = []
    tok = sample(logits, key)
    for i in range(gen_steps):
        toks.append(tok)
        logits, caches = decode(params, tok[:, None].astype(jnp.int32), caches)
        tok = sample(logits, jax.random.fold_in(key, i))
    return jnp.stack(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = cfgs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    bundle = build(cfg)
    key = jax.random.key(args.seed)
    max_seq = args.prompt_len + args.gen + 1
    params = bundle.init(key, max_seq=max(max_seq, 64))
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encdec.enc_seq, cfg.d_model))
    t0 = time.time()
    out = generate(bundle, params, prompts, args.gen, key,
                   temperature=args.temperature, enc_embeds=enc)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "prompt_len": args.prompt_len,
        "generated": args.gen, "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "sample_tokens": out[0, :8].tolist(),
    }))
    return out


if __name__ == "__main__":
    main()
