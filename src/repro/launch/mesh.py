"""Production meshes + the XLA overlap-flag helper. Functions, not
module constants — importing this module never touches jax device
state."""
from __future__ import annotations

import os
import warnings

from repro.parallel.compat import AxisType, make_mesh as _make_mesh

# Per-platform XLA flags that let the compiler overlap the split-phase
# exchange (DESIGN.md §13) with field compute. Only flags verified to
# exist in the pinned jaxlib are listed — XLA aborts the process on an
# unknown --xla_* flag, so this table is allow-list, not wish-list.
#
#  gpu : async collectives are on by default; the latency-hiding
#        scheduler + a high-priority async stream make the -start/-done
#        pairs actually span the field compute.
#  cpu : XLA:CPU has NO async-collective lowering (collectives stay
#        sync thunks); the thunk runtime + concurrency-optimized
#        scheduler are the closest knobs — they let independent thunks
#        (which the delayed exchange's collectives are, see
#        obs.hlo.exchange_field_independence) run on the thread pool.
#  tpu : overlap is default XLA:TPU behavior; nothing to set.
OVERLAP_XLA_FLAGS = {
    "gpu": (
        "--xla_gpu_enable_latency_hiding_scheduler=true",
        "--xla_gpu_enable_highest_priority_async_stream=true",
    ),
    "cpu": (
        "--xla_cpu_use_thunk_runtime=true",
        "--xla_cpu_enable_concurrency_optimized_scheduler=true",
    ),
    "tpu": (),
}


def enable_overlap_flags(platform: str = "cpu") -> tuple:
    """Append the platform's overlap flags to ``XLA_FLAGS`` (the
    `set_platform` idiom: call BEFORE the first jax operation — XLA
    parses the env var once at backend init). Idempotent; returns the
    flags added. A no-op with a warning if the jax backend is already
    initialized, since the flags could no longer take effect."""
    flags = OVERLAP_XLA_FLAGS.get(platform)
    if flags is None:
        raise ValueError(
            f"unknown platform {platform!r}; have "
            f"{sorted(OVERLAP_XLA_FLAGS)}")
    import jax
    monitoring = getattr(jax, "_src", None)
    backends = getattr(getattr(monitoring, "xla_bridge", None),
                       "_backends", None)
    if backends:
        warnings.warn(
            "enable_overlap_flags called after jax backend init — "
            "XLA_FLAGS already parsed; set the flags before the first "
            "jax call (or in the launch environment) for them to apply",
            stacklevel=2)
        return ()
    current = os.environ.get("XLA_FLAGS", "")
    added = tuple(f for f in flags if f not in current)
    if added:
        os.environ["XLA_FLAGS"] = " ".join(filter(None, (current,) + added))
    return added


def make_production_mesh(*, multi_pod: bool = False, override: str = ""):
    """Single pod: (16,16) ('data','model') = 256 chips (v5e pod).
    Multi pod:  (2,16,16) ('pod','data','model') = 512 chips.
    `override` ("64,4" / "2,32,8") re-splits the same chips across the
    data/model axes — a §Perf sharding-scheme knob."""
    if override:
        shape = tuple(int(x) for x in override.split(","))
        assert len(shape) in (2, 3)
        axes = (("pod",) if len(shape) == 3 else ()) + ("data", "model")
        expected = 512 if multi_pod else 256
        assert (len(shape) == 3) == multi_pod
        total = 1
        for x in shape:
            total *= x
        assert total == expected, (shape, expected)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return _make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def worker_axes_for(layout: str, multi_pod: bool):
    """DQGAN worker axes by parameter layout (DESIGN.md §4):
    dp   -> every data-parallel rank is a paper-worker;
    fsdp -> each pod is a paper-worker (params sharded inside)."""
    if layout == "dp":
        return ("pod", "data") if multi_pod else ("data",)
    if layout == "fsdp":
        return ("pod",) if multi_pod else ()
    raise ValueError(layout)
