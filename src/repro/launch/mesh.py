"""Production meshes. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

from repro.parallel.compat import AxisType, make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False, override: str = ""):
    """Single pod: (16,16) ('data','model') = 256 chips (v5e pod).
    Multi pod:  (2,16,16) ('pod','data','model') = 512 chips.
    `override` ("64,4" / "2,32,8") re-splits the same chips across the
    data/model axes — a §Perf sharding-scheme knob."""
    if override:
        shape = tuple(int(x) for x in override.split(","))
        assert len(shape) in (2, 3)
        axes = (("pod",) if len(shape) == 3 else ()) + ("data", "model")
        expected = 512 if multi_pod else 256
        assert (len(shape) == 3) == multi_pod
        total = 1
        for x in shape:
            total *= x
        assert total == expected, (shape, expected)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return _make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def worker_axes_for(layout: str, multi_pod: bool):
    """DQGAN worker axes by parameter layout (DESIGN.md §4):
    dp   -> every data-parallel rank is a paper-worker;
    fsdp -> each pod is a paper-worker (params sharded inside)."""
    if layout == "dp":
        return ("pod", "data") if multi_pod else ("data",)
    if layout == "fsdp":
        return ("pod",) if multi_pod else ()
    raise ValueError(layout)
