"""Training launcher: end-to-end DQGAN training of any registered arch on
the local device set (CPU smoke / real TPU alike).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 100 --compressor qsgd8_linf --exchange sim

Distribution strategy (repro.strategy, DESIGN.md §9): the strategy flags
below are auto-generated from the typed component schemas; start from a
preset or a serialized strategy and override per flag:

    # the paper's setting by name:
    ... --preset paper_dqgan

    # a preset with one axis overridden:
    ... --preset ssp_server --staleness-tau 2

    # an exact strategy from a checkpoint / experiments JSON:
    ... --strategy-json '{"schedule": {"kind": "delayed", "tau": 4}}'

Communication planning (repro.comm, DESIGN.md §3): pass ``--comm-plan`` to
bucket the gradient pytree into flat worker-divisible buckets and assign a
compressor per bucket; each log line then carries the wire-telemetry
fields ``wire_mb_step`` / ``cum_wire_mb`` / ``comm_ratio``:

    # DDP-style bucketing, one compressor everywhere (paper semantics):
    ... --comm-plan uniform --exchange two_phase --compressor qsgd8_linf

    # keep small buckets (biases/norms) full precision:
    ... --comm-plan size_tiered --bucket-mb 4

    # fit a byte budget by per-bucket bit-width descent:
    ... --comm-plan delta_budget --comm-budget-mb 2.5

    # round-adaptive PlanFamily: when only n of M workers report, the
    # absent workers' budget buys the participants finer bits
    # (DESIGN.md §10; log rows gain ``participants``):
    ... --preset adaptive_budget --participation 0.5

Execution schedule (repro.sched, DESIGN.md §5, §8): ``--schedule`` picks
when workers exchange; log rows then carry ``round`` and the simulated
wall clock (``sim_clock_s``) from the straggler-aware cost model:

    # exchange every 4 steps, message accumulates between rounds:
    ... --schedule local_k --local-k 4

    # one-step-stale exchange overlapping compute, heterogeneous workers:
    ... --schedule delayed --straggler-profile mild

    # bounded staleness τ=4: the parameter-server push/pull pipeline —
    # log rows gain per-step max/mean staleness from the version vector:
    ... --schedule delayed --staleness-tau 4

    # each round only half the workers report; the rest accumulate EF:
    ... --participation 0.5

Observability (repro.obs, DESIGN.md §11): ``--obs-metrics wire|full``
turns on on-device telemetry (empirical δ, EF residual norms, per-bucket
gradient moments, staleness histograms) with a bit-exactness guarantee —
the trajectory is identical to ``--obs-metrics off``. ``--obs-sink
PATH`` writes the versioned JSONL event stream (run meta, log rows,
synced step/interval timings, obs metrics, comm summaries) for
``python -m repro.obs report PATH``; the default sink renders log rows
on stdout exactly as before. ``--obs-spans`` adds named profiler spans
(compress/exchange/apply on device, data/step/eval on the host):

    ... --preset adaptive_budget --obs-metrics full --obs-sink run.jsonl

Checkpointing: ``--checkpoint PATH`` saves the FULL ``DQState`` (params,
optimizer moments, prev_grad, EF residuals incl. comm-plan bucket
entries, schedule buffers) at the end and every ``--checkpoint-every N``
steps; ``--resume PATH`` restores it and continues from the saved step.

For the paper's own experiment (DCGAN), use examples/train_gan_images.py
which adds the WGAN weight clipping + evaluation metrics.
"""
from __future__ import annotations

import argparse
import time
import zipfile

import jax
import jax.numpy as jnp

import repro.configs as cfgs
from repro import checkpoint
from repro import obs as obs_api
from repro import strategy as strategy_api
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.data import lm_batch_iterator
from repro.models import build
from repro.parallel import sharding as shd
from repro.parallel.compat import set_mesh
from repro.sched import clock as sclock
from repro.sched import straggler as sstrag


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="oadam")
    # the distribution-strategy surface is generated from the
    # repro.strategy component schemas (one definition for the dataclass,
    # the JSON schema and these flags) — includes --preset/--strategy-json
    # and the legacy spellings (--compressor, --schedule, ...).
    strategy_api.add_strategy_args(ap)
    ap.add_argument("--checkpoint", default="",
                    help="save the full DQState here (end of run + "
                         "--checkpoint-every). A path ending in .npz "
                         "uses the single-archive format; anything else "
                         "is a per-host sharded directory (manifest + "
                         "one shard file per host, DESIGN.md §15.5)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="also save every N steps (0 = only at the end)")
    ap.add_argument("--checkpoint-shards", type=int, default=0,
                    help="shard-file count for the sharded checkpoint "
                         "format (0 = one per host)")
    ap.add_argument("--resume", default="",
                    help="restore a full DQState checkpoint (either "
                         "format; sharded checkpoints reshard onto this "
                         "run's device count) and continue")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--obs-sink", default="", metavar="PATH",
                    help="run-sink backend: '' (quiet stdout, the "
                         "default rendering), 'stdout' (verbose), "
                         "'null', or a JSONL file path for "
                         "`python -m repro.obs report`")
    ap.add_argument("--profile-steps", type=int, default=0, metavar="N",
                    help="profile a window of N steps and emit one "
                         "schema-v2 `profile` event into the sink "
                         "(repro.obs.profile; implies profiling on — "
                         "--obs-profile alone uses the default window)")
    ap.add_argument("--profile-trace-dir", default="", metavar="DIR",
                    help="also capture a jax.profiler trace of the "
                         "profiled window into DIR (TensorBoard)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = cfgs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    bundle = build(cfg)

    n_dev = jax.device_count()
    mesh = None
    worker_axes = ()
    pspecs = None
    bspec = None
    if n_dev > 1:
        worker_axes = ("data",)

    try:
        strat = strategy_api.strategy_from_args(args,
                                                worker_axes=worker_axes)
    except strategy_api.StrategyError as e:
        ap.error(str(e))
    sched = strat.schedule.runtime()

    if n_dev > 1:
        from jax.sharding import PartitionSpec as P

        from repro.parallel.compat import make_mesh
        # fsdp shards optimizer state over the data axis and needs every
        # leaf in a flat bucket — tensor ('model') parallelism would
        # leave sharded leaves outside the bucketing, so it keeps a pure
        # data mesh (DESIGN.md §15.1)
        model_n = (2 if n_dev % 2 == 0 and n_dev > 2
                   and not strat.exchange.fsdp else 1)
        mesh = make_mesh((n_dev // model_n, model_n), ("data", "model"))
        bspec = P(("data",))

    dq = DQConfig.from_strategy(
        strat, optimizer=args.optimizer, lr=args.lr,
        message="update" if args.optimizer == "omd" else "grad",
    )
    key = jax.random.key(args.seed)
    params = bundle.init(key, max_seq=args.seq)
    if mesh is not None:
        pspecs = shd.param_specs(params, cfg, "dp", mesh)
        shards = shd.shardings(pspecs, mesh)
        params = jax.tree.map(jax.device_put, params, shards)

    trainer = DQGAN(field_fn=bundle.field_fn, dq=dq, mesh=mesh,
                    param_specs=pspecs, batch_spec=bspec)

    def state_shardings():
        if mesh is None:
            return None
        from jax.sharding import NamedSharding
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            trainer.state_specs(params))

    def save_ckpt(path, st, step):
        meta = {"strategy": strat.to_json()}
        if path.endswith(".npz"):
            checkpoint.save(path, st, step=step, meta=meta)
        else:
            checkpoint.save_sharded(path, st, step=step, meta=meta,
                                    mesh=mesh,
                                    n_shards=args.checkpoint_shards or None)

    start = 0
    state = trainer.init(params)
    if args.resume:
        try:
            checkpoint.verify_strategy(args.resume, strat)
            if checkpoint.is_sharded(args.resume):
                saved_mesh = checkpoint.read_manifest(
                    args.resume).get("mesh")
                cur = (None if mesh is None else
                       {"axis_names": [str(a) for a in mesh.axis_names],
                        "shape": [int(mesh.shape[a])
                                  for a in mesh.axis_names]})
                if saved_mesh != cur:
                    print(f"# resume: resharding {saved_mesh} -> {cur}",
                          flush=True)
                state = checkpoint.restore_sharded(args.resume, state,
                                                   state_shardings())
            else:
                state = checkpoint.restore(args.resume, state,
                                           state_shardings())
        except (ValueError, OSError, zipfile.BadZipFile) as e:
            # strategy/shape mismatch, missing file, or corrupt archive —
            # all refuse cleanly instead of a restore-time traceback
            raise SystemExit(f"--resume refused:\n{e}") from None
        start = int(jax.device_get(state.step))
        print(f"# resumed from {args.resume} at step {start}", flush=True)
    step = jax.jit(trainer.step, static_argnums=(3,), donate_argnums=(0,))

    ledger = trainer.comm_ledger(params)
    sk_n, sk_bytes = ledger.skipped_leaves()
    if sk_n:
        # sharded leaves that bypassed the flat-bucket pipeline ride the
        # (slower, per-tensor) path — surface it once, loudly
        print(f"# comm: WARNING {sk_n} sharded leaf(s) bypass bucketing "
              f"({sk_bytes / 1e6:.2f} MB/step on the per-tensor path)",
              flush=True)
    if strat.compression.bucketing:
        layout, cplan = trainer._comm(params)
        print(f"# comm: {layout.describe()}", flush=True)
        print(f"# comm: {cplan.describe()}", flush=True)
        family = trainer._family(params)
        if family is not None:
            print(f"# comm: {family.describe()}", flush=True)
    # count-exact participation: the per-round participant count is a
    # static function of (fraction, W) — the ledger bills each round at
    # the bytes the reporting workers actually move (selected-plan
    # payload under an adaptive family, DESIGN.md §10.3)
    from repro.sched import n_participants
    n_part = (n_participants(strat.participation.fraction,
                             trainer.n_workers)
              if trainer.n_workers > 1 and strat.participation.partial
              else None)
    profile = strat.participation.profile()
    link = sclock.LinkModel()
    W = max(trainer.n_workers, 1)
    # price the modeled exchange at what a round actually moves — under
    # partial participation that is the selected family member's payload
    # (round_bytes), not the full-M plan
    t_ex = (link.exchange_time(ledger.round_bytes(n_part)[0])
            if W > 1 else 0.0)
    print(f"# strategy: {strat.describe()} [{strat.short_hash()}]",
          flush=True)

    # structured run sink (repro.obs): every log/timing/telemetry row is
    # one schema event keyed by the strategy's structural identity; the
    # default backend renders log rows on stdout exactly as before
    sink = obs_api.make_sink(args.obs_sink, strategy_hash=strat.short_hash(),
                             tee_stdout=True)
    obs_spans = strat.observability.spans
    # host-side step profiler (repro.obs.profile, DESIGN.md §12.1) — a
    # NullStepProfiler when off, so the hot loop carries no conditionals
    # and the compiled step is untouched either way (bit-exactness test)
    profiler = obs_api.make_profiler(
        strat.observability.profile or args.profile_steps > 0,
        window=args.profile_steps, trace_dir=args.profile_trace_dir)
    sink.emit("run_meta", steps=args.steps, arch=args.arch,
              smoke=bool(args.smoke), n_workers=W, start_step=start,
              strategy_json=strat.to_dict(),
              obs_metrics=strat.observability.metrics)

    if getattr(cfg, "arch_type", "") == "gan":
        it = gan_batch_iterator(args.seed, args.batch, cfg)
    else:
        enc_shape = ((cfg.encdec.enc_seq, cfg.d_model) if cfg.is_encdec
                     else None)
        it = lm_batch_iterator(args.seed, args.batch, args.seq,
                               cfg.vocab_size, enc_shape)
    for _ in range(start):  # keep the data stream aligned across resumes
        next(it)

    history = []
    t0 = time.time()
    wall_series = None
    warm_variants = set()  # do_exchange values whose jit variant compiled
    interval_s = 0.0       # synced wall time since the last timing event
    interval_n = 0
    ctx = set_mesh(mesh) if mesh is not None else _null()
    with ctx:
        for i in range(start, args.steps):
            with obs_api.host_span("data", obs_spans), \
                    profiler.phase("data"):
                batch = next(it)
            do_exchange = sched.is_exchange_step(i)
            # every step is timed against a device sync — an unsynced
            # perf_counter delta only measures dispatch, so without this
            # the reported step time was only meaningful on the handful
            # of steps that happened to block (the old wall-series seed)
            it_t0 = time.perf_counter()
            with obs_api.host_span("step", obs_spans), \
                    profiler.phase("step"):
                out = step(state, batch, key, do_exchange)
                state = out.state
                jax.block_until_ready(out.metrics)
            step_s = time.perf_counter() - it_t0
            profiler.record_step(i, step_s, do_exchange)
            interval_s += step_s
            interval_n += 1
            if wall_series is None and (do_exchange in warm_variants
                                        or i == args.steps - 1):
                # base compute time from the first step whose jit variant
                # already compiled (holds across resumes too); feeds the
                # simulated (straggler-aware) wall-clock series
                times = sstrag.step_times(profile, W, args.steps, args.seed,
                                          base=step_s)
                wall_series = sclock.simulate(
                    sched, times, t_ex, strat.participation.fraction,
                    args.seed)["per_step_s"]
                if i > start:  # backfill the steps already run
                    ledger.tick(0, wall_s=float(wall_series[start:i].sum()))
            warm_variants.add(do_exchange)
            wall = float(wall_series[i]) if wall_series is not None else 0.0
            ledger.tick(exchanged=do_exchange, wall_s=wall,
                        participants=n_part)
            if i % args.log_every == 0 or i == args.steps - 1:
                with obs_api.host_span("eval", obs_spans), \
                        profiler.phase("eval"):
                    m = jax.device_get(out.metrics)
                rec = {"step": i, "round": sched.round_index(i),
                       **({"participants": n_part}
                          if n_part is not None else {}),
                       "loss": float(m["loss"]),
                       "grad_norm": float(m["grad_norm"]),
                       "error_norm": float(m["error_norm"]),
                       **({"staleness_max": float(m["staleness_max"]),
                           "staleness_mean": round(
                               float(m["staleness_mean"]), 2)}
                          if strat.schedule.kind == "delayed" else {}),
                       "wire_mb_step": round(
                           ledger.wire_bytes_per_step / 1e6, 3),
                       "cum_wire_mb": round(
                           ledger.cumulative_wire_bytes / 1e6, 2),
                       "comm_ratio": round(ledger.compression_ratio, 2),
                       "sim_clock_s": round(ledger.sim_clock_s, 3),
                       "elapsed_s": round(time.time() - t0, 1)}
                history.append(rec)
                sink.emit("train_log", **rec)
                sink.emit("timing", step=i, step_s=round(step_s, 6),
                          interval_s=round(interval_s, 6),
                          steps_in_interval=interval_n)
                interval_s = 0.0
                interval_n = 0
                if "obs" in m:
                    sink.emit("obs_metrics", step=i, **m["obs"])
            if (args.checkpoint and args.checkpoint_every
                    and (i + 1) % args.checkpoint_every == 0
                    and i != args.steps - 1):
                save_ckpt(args.checkpoint, state, i + 1)
        if profiler.step_walls:
            # close the profiled window (still under the mesh context —
            # the re-lowering below needs it). With spans on, the
            # optimized HLO carries the repro.obs scope metadata, giving
            # the profile event its device-phase attribution.
            hlo_txt = ""
            if obs_spans:
                hlo_txt = step.lower(state, batch, key,
                                     do_exchange).compile().as_text()
            profiler.emit(sink, hlo_text=hlo_txt)
    sink.emit("comm_summary", **ledger.summary())
    sink.close()
    if args.checkpoint:
        save_ckpt(args.checkpoint, state,
                  int(jax.device_get(state.step)))
        print(f"saved DQState to {args.checkpoint}")
    return history


def gan_batch_iterator(seed, batch, cfg):
    """Procedural-image batches for GANConfig archs (dcgan32)."""
    from repro.data import procedural_images

    key = jax.random.key(seed)
    i = 0
    while True:
        yield {"real": procedural_images(jax.random.fold_in(key, i), batch,
                                         cfg.image_size, cfg.channels)}
        i += 1


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
