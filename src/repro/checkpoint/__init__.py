"""Checkpointing: flatten any pytree (params / DQState) to a flat dict of
numpy arrays in an .npz, with the treedef stored as a path index. Sharded
arrays are gathered to host (process-0 save). Restores into the original
structure, re-placing onto the provided shardings when given."""
from __future__ import annotations

import io
import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
        out.append(("/".join(keys), leaf))
    return out


def save(path: str, tree: Any, step: Optional[int] = None,
         meta: Optional[dict] = None) -> None:
    """Save a pytree; `meta` entries (e.g. the run's serialized
    distribution strategy under "strategy") are embedded in the archive's
    __meta__ record and read back with `read_meta`."""
    reserved = {"step", "names"} & set(meta or {})
    if reserved:
        raise ValueError(
            f"checkpoint meta keys {sorted(reserved)} are reserved for the "
            f"internal __meta__ record")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    named = _paths(tree)
    arrays = {}
    for name, leaf in named:
        if leaf is None:
            continue
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays["__bf16__" + name] = arr.view(np.uint16)
        else:
            arrays[name] = arr
    record = {"step": step, "names": [n for n, _ in named], **(meta or {})}
    with open(path, "wb") as f:
        np.savez(f, __meta__=json.dumps(record), **arrays)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs)."""
    with np.load(path, allow_pickle=False) as z:
        data = {}
        for k in z.files:
            if k == "__meta__":
                continue
            if k.startswith("__bf16__"):
                data[k[len("__bf16__"):]] = z[k].view(jnp.bfloat16)
            else:
                data[k] = z[k]
    named = _paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _paths(shardings)]
    want = {n for n, leaf in named if leaf is not None}
    missing = sorted(want - set(data))
    if missing:
        extra = sorted(set(data) - want)
        raise ValueError(
            f"checkpoint {path!r} does not match the requested state "
            f"structure: missing {missing[:5]}{'...' if len(missing) > 5 else ''}"
            + (f", checkpoint-only {extra[:5]}"
               f"{'...' if len(extra) > 5 else ''}" if extra else "")
            + " — restore with the same config (schedule/comm_plan/"
            "optimizer/...) the checkpoint was saved under")
    out = []
    for i, (name, leaf) in enumerate(named):
        if leaf is None:
            out.append(None)
            continue
        arr = data[name]
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, out)


def read_meta(path: str) -> dict:
    """The checkpoint's __meta__ record (step, names, embedded extras)."""
    with np.load(path, allow_pickle=False) as z:
        if "__meta__" not in z.files:
            raise ValueError(
                f"{path!r} is not a repro checkpoint: no __meta__ record "
                f"in the archive")
        return json.loads(str(z["__meta__"]))


def latest_step(path: str) -> Optional[int]:
    if not os.path.exists(path):
        return None
    return read_meta(path).get("step")


# strategy fields that affect neither the DQState layout nor the
# training semantics, so a resume may change them freely: the host-side
# wall-clock model's straggler profile, and the repro.obs telemetry
# knobs (contractually trajectory-invariant, DESIGN.md §11).
_HOST_ONLY_FIELDS = ("participation.straggler_profile", "observability.")


def verify_strategy(path: str, strategy: Any) -> None:
    """Fail fast when `path` was saved under a different distribution
    strategy than the resuming run's — a mismatched resume would silently
    reinterpret the DQState.sched slots (accum vs pending ring) and EF
    layout. Raises ValueError with the field-level diff (host-only fields
    like the straggler profile are exempt). Checkpoints predating the
    embedded strategy pass with a warning."""
    from repro.strategy import Strategy

    saved_json = read_meta(path).get("strategy")
    if saved_json is None:
        import warnings
        warnings.warn(
            f"checkpoint {path!r} has no embedded strategy (pre-strategy "
            f"format); resume compatibility cannot be verified",
            stacklevel=2)
        return
    saved = Strategy.from_json(saved_json)
    lines = [ln for ln in saved.diff(strategy)
             if not ln.startswith(_HOST_ONLY_FIELDS)]
    if lines:
        raise ValueError(
            f"checkpoint {path!r} was saved under a different strategy "
            f"than this run (saved != current):\n  " + "\n  ".join(lines)
            + "\n— resume with the saved strategy, or start a fresh run")
