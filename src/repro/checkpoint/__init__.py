"""Checkpointing: flatten any pytree (params / DQState) to a flat dict of
numpy arrays in an .npz, with the treedef stored as a path index. Sharded
arrays are gathered to host (process-0 save). Restores into the original
structure, re-placing onto the provided shardings when given."""
from __future__ import annotations

import io
import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
        out.append(("/".join(keys), leaf))
    return out


def save(path: str, tree: Any, step: Optional[int] = None,
         meta: Optional[dict] = None) -> None:
    """Save a pytree; `meta` entries (e.g. the run's serialized
    distribution strategy under "strategy") are embedded in the archive's
    __meta__ record and read back with `read_meta`."""
    reserved = {"step", "names"} & set(meta or {})
    if reserved:
        raise ValueError(
            f"checkpoint meta keys {sorted(reserved)} are reserved for the "
            f"internal __meta__ record")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    named = _paths(tree)
    arrays = {}
    for name, leaf in named:
        if leaf is None:
            continue
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays["__bf16__" + name] = arr.view(np.uint16)
        else:
            arrays[name] = arr
    record = {"step": step, "names": [n for n, _ in named], **(meta or {})}
    with open(path, "wb") as f:
        np.savez(f, __meta__=json.dumps(record), **arrays)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs)."""
    with np.load(path, allow_pickle=False) as z:
        data = {}
        for k in z.files:
            if k == "__meta__":
                continue
            if k.startswith("__bf16__"):
                data[k[len("__bf16__"):]] = z[k].view(jnp.bfloat16)
            else:
                data[k] = z[k]
    named = _paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _paths(shardings)]
    want = {n for n, leaf in named if leaf is not None}
    missing = sorted(want - set(data))
    if missing:
        extra = sorted(set(data) - want)
        raise ValueError(
            f"checkpoint {path!r} does not match the requested state "
            f"structure: missing {missing[:5]}{'...' if len(missing) > 5 else ''}"
            + (f", checkpoint-only {extra[:5]}"
               f"{'...' if len(extra) > 5 else ''}" if extra else "")
            + " — restore with the same config (schedule/comm_plan/"
            "optimizer/...) the checkpoint was saved under")
    out = []
    for i, (name, leaf) in enumerate(named):
        if leaf is None:
            out.append(None)
            continue
        arr = data[name]
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, out)


def read_meta(path: str) -> dict:
    """The checkpoint's __meta__ record (step, names, embedded extras)."""
    if is_sharded(path):
        return read_manifest(path)
    with np.load(path, allow_pickle=False) as z:
        if "__meta__" not in z.files:
            raise ValueError(
                f"{path!r} is not a repro checkpoint: no __meta__ record "
                f"in the archive")
        return json.loads(str(z["__meta__"]))


def latest_step(path: str) -> Optional[int]:
    if not os.path.exists(path):
        return None
    return read_meta(path).get("step")


# --------------------------------------------------------------------------- #
# per-host sharded format (DESIGN.md §15.5): a DIRECTORY holding one
# .npz per host plus a manifest. Leaves whose leading axis divides by the
# shard count are split along axis 0 (the worker axis of fsdp's per-shard
# optimizer state, so each host writes ≈ its own bytes); everything else
# is round-robined whole. Assembly on restore is device-count agnostic —
# chunks concatenate to the full array, then device_put to the target
# shardings — so save-on-8 / restore-on-{1,4} resharding is the default
# behavior, not a special case.
# --------------------------------------------------------------------------- #
_MANIFEST = "manifest.json"
_SHARDED_FORMAT = "repro-sharded-v1"


def _shard_file(i: int, n: int) -> str:
    return f"shard-{i:05d}-of-{n:05d}.npz"


def is_sharded(path: str) -> bool:
    """True when `path` is a sharded-checkpoint directory."""
    return os.path.isdir(path) and os.path.exists(
        os.path.join(path, _MANIFEST))


def save_sharded(path: str, tree: Any, step: Optional[int] = None,
                 meta: Optional[dict] = None, mesh: Any = None,
                 n_shards: Optional[int] = None) -> None:
    """Save a pytree as a sharded-checkpoint directory. `n_shards`
    defaults to the process (host) count; `mesh` (when given) is
    recorded in the manifest for provenance/diagnostics — restoring onto
    a different mesh is allowed (resharding)."""
    reserved = {"step", "names", "format", "n_shards", "leaves",
                "mesh"} & set(meta or {})
    if reserved:
        raise ValueError(
            f"checkpoint meta keys {sorted(reserved)} are reserved for "
            f"the manifest")
    H = int(n_shards or max(jax.process_count(), 1))
    os.makedirs(path, exist_ok=True)
    named = _paths(tree)
    leaves_rec = {}
    shard_data = [dict() for _ in range(H)]
    rr = 0  # round-robin cursor for unsplit leaves
    for name, leaf in named:
        if leaf is None:
            continue
        arr = np.asarray(jax.device_get(leaf))
        split = arr.ndim >= 1 and arr.shape[0] >= H and arr.shape[0] % H == 0
        rec = {"shape": list(arr.shape), "dtype": str(arr.dtype),
               "split": bool(split), "chunks": []}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        if split:
            c = arr.shape[0] // H
            for i in range(H):
                rec["chunks"].append([i, i * c, c])
                shard_data[i][f"{name}@{i * c}"] = arr[i * c:(i + 1) * c]
        else:
            owner = rr % H
            rr += 1
            rec["chunks"].append([owner, 0,
                                  int(arr.shape[0]) if arr.ndim else 0])
            shard_data[owner][f"{name}@0"] = arr
        leaves_rec[name] = rec
    for i in range(H):
        with open(os.path.join(path, _shard_file(i, H)), "wb") as f:
            np.savez(f, **shard_data[i])
    manifest = {
        "format": _SHARDED_FORMAT,
        "step": step,
        "n_shards": H,
        "names": [n for n, _ in named],
        "leaves": leaves_rec,
        "mesh": (None if mesh is None else {
            "axis_names": [str(a) for a in mesh.axis_names],
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        }),
        **(meta or {}),
    }
    tmp = os.path.join(path, _MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, _MANIFEST))


def read_manifest(path: str) -> dict:
    mf = os.path.join(path, _MANIFEST)
    if not os.path.exists(mf):
        raise ValueError(
            f"{path!r} is not a sharded repro checkpoint: no {_MANIFEST}")
    with open(mf) as f:
        manifest = json.load(f)
    if manifest.get("format") != _SHARDED_FORMAT:
        raise ValueError(
            f"{path!r}: unknown sharded checkpoint format "
            f"{manifest.get('format')!r} (want {_SHARDED_FORMAT!r})")
    return manifest


def restore_sharded(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore a sharded-checkpoint directory into the structure of
    `like`, re-placing onto `shardings` when given. The saving and
    restoring meshes/device counts need not match."""
    manifest = read_manifest(path)
    H = manifest["n_shards"]
    missing_files = [f for f in (_shard_file(i, H) for i in range(H))
                     if not os.path.exists(os.path.join(path, f))]
    if missing_files:
        raise ValueError(
            f"sharded checkpoint {path!r} is incomplete: missing shard "
            f"file(s) {missing_files[:4]}"
            f"{'...' if len(missing_files) > 4 else ''}")
    named = _paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _paths(shardings)]
    recs = manifest["leaves"]
    want = {n for n, leaf in named if leaf is not None}
    missing = sorted(want - set(recs))
    if missing:
        extra = sorted(set(recs) - want)
        raise ValueError(
            f"sharded checkpoint {path!r} does not match the requested "
            f"state structure: missing {missing[:5]}"
            f"{'...' if len(missing) > 5 else ''}"
            + (f", checkpoint-only {extra[:5]}"
               f"{'...' if len(extra) > 5 else ''}" if extra else "")
            + " — restore with the same config the checkpoint was saved "
            "under")
    files = {}

    def shard(i):
        if i not in files:
            files[i] = np.load(os.path.join(path, _shard_file(i, H)),
                               allow_pickle=False)
        return files[i]

    bad_shapes = [
        f"{name}: saved {tuple(recs[name]['shape'])} != "
        f"expected {tuple(leaf.shape)}"
        for name, leaf in named
        if leaf is not None and hasattr(leaf, "shape")
        and tuple(recs[name]["shape"]) != tuple(leaf.shape)]
    if bad_shapes:
        raise ValueError(
            f"sharded checkpoint {path!r} leaf shapes do not match the "
            "requested state:\n  " + "\n  ".join(bad_shapes[:6])
            + ("\n  ..." if len(bad_shapes) > 6 else "")
            + "\n— per-worker state (EF residuals, fsdp shard slots) is "
            "laid out by worker count and cannot reshard across a "
            "different mesh; resume on the saved worker count, or "
            "restore the params subtree only")
    out = []
    try:
        for i, (name, leaf) in enumerate(named):
            if leaf is None:
                out.append(None)
                continue
            rec = recs[name]
            parts = [shard(fi)[f"{name}@{start}"]
                     for fi, start, _ in sorted(rec["chunks"],
                                                key=lambda c: c[1])]
            arr = np.concatenate(parts, axis=0) if rec["split"] else parts[0]
            if rec["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            arr = arr.reshape(rec["shape"])
            if shard_leaves is not None and shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jnp.asarray(arr))
    finally:
        for z in files.values():
            z.close()
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, out)


# strategy fields that affect neither the DQState layout nor the
# training semantics, so a resume may change them freely: the host-side
# wall-clock model's straggler profile, and the repro.obs telemetry
# knobs (contractually trajectory-invariant, DESIGN.md §11).
_HOST_ONLY_FIELDS = ("participation.straggler_profile", "observability.")


def verify_strategy(path: str, strategy: Any) -> None:
    """Fail fast when `path` was saved under a different distribution
    strategy than the resuming run's — a mismatched resume would silently
    reinterpret the DQState.sched slots (accum vs pending ring) and EF
    layout. Raises ValueError with the field-level diff (host-only fields
    like the straggler profile are exempt). Checkpoints predating the
    embedded strategy pass with a warning."""
    from repro.strategy import Strategy

    saved_json = read_meta(path).get("strategy")
    if saved_json is None:
        import warnings
        warnings.warn(
            f"checkpoint {path!r} has no embedded strategy (pre-strategy "
            f"format); resume compatibility cannot be verified",
            stacklevel=2)
        return
    saved = Strategy.from_json(saved_json)
    lines = [ln for ln in saved.diff(strategy)
             if not ln.startswith(_HOST_ONLY_FIELDS)]
    if lines:
        raise ValueError(
            f"checkpoint {path!r} was saved under a different strategy "
            f"than this run (saved != current):\n  " + "\n  ".join(lines)
            + "\n— resume with the saved strategy, or start a fresh run")
