"""The paper's core optimization claims, at unit scale:
OMD/extragradient converges on min-max problems where simultaneous GDA
cycles/diverges (paper §2.2, [23]); optimistic Adam behaves likewise."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

# orthogonal A (all singular values 1): isolates the min-max cycling
# phenomenon from conditioning — bilinear GDA spirals out at rate (1+η²)^t/2
# for ANY such A, while OMD contracts at (1-η²)^t/2.
A = jnp.array(np.linalg.qr(np.random.RandomState(3).randn(6, 6))[0],
              jnp.float32)


def bilinear_field(params, batch, rng):
    """min_x max_y x^T A y: F = (A y, -A^T x); saddle at (0, 0)."""
    del batch
    x, y = params["x"], params["y"]
    noise = 0.0 * jax.random.normal(rng, x.shape)
    return ({"x": A @ y + noise, "y": -(A.T @ x) + noise},
            {"loss": x @ A @ y})


def _run(dq, steps=3000, field=bilinear_field):
    tr = DQGAN(field_fn=field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step)
    key = jax.random.key(0)
    for _ in range(steps):
        st = step(st, None, key).state
    return float(jnp.linalg.norm(st.params["x"]) +
                 jnp.linalg.norm(st.params["y"]))


def test_gda_diverges_on_bilinear():
    dist = _run(DQConfig(optimizer="sgd", compressor="identity",
                         exchange="exact", error_feedback=False, lr=0.05,
                         worker_axes=()), steps=1500)
    assert dist > 10.0, f"GDA should drift away, got {dist}"


def test_omd_converges_on_bilinear():
    dist = _run(DQConfig(optimizer="omd", compressor="identity",
                         exchange="exact", error_feedback=False, lr=0.1,
                         worker_axes=()))
    assert dist < 0.05, f"OMD should reach the saddle, got {dist}"


def test_omd_with_quantization_and_ef_converges():
    dist = _run(DQConfig(optimizer="omd", compressor="qsgd8_linf",
                         exchange="sim", error_feedback=True, lr=0.05,
                         worker_axes=()))
    assert dist < 0.2, f"DQGAN single-worker should converge, got {dist}"


def test_omd_global_extrapolation_converges():
    dist = _run(DQConfig(optimizer="omd", compressor="qsgd8_linf",
                         exchange="sim", error_feedback=True, lr=0.05,
                         extrapolation="global", worker_axes=()))
    assert dist < 0.2, f"global-extrapolation variant should converge, got {dist}"


def test_oadam_stays_bounded_on_bilinear():
    """Optimistic Adam orbits near the saddle where GDA at the same step
    size spirals out monotonically ((1+η²)^{t/2} ≈ 6.5 here). Pure-bilinear
    convergence of OAdam needs problem-specific tuning (Daskalakis et al.
    demonstrate it on GANs, not raw bilinear); boundedness is the claim."""
    dist = _run(DQConfig(optimizer="oadam", compressor="identity",
                         exchange="exact", error_feedback=False, lr=0.05,
                         beta1=0.5, beta2=0.9, worker_axes=()), steps=4000)
    assert dist < 2.5, f"optimistic Adam should orbit the saddle, got {dist}"
    gda = _run(DQConfig(optimizer="sgd", compressor="identity",
                        exchange="exact", error_feedback=False, lr=0.05,
                        worker_axes=()), steps=4000)
    assert gda > 2 * dist, (gda, dist)


def test_single_machine_optimizers_minimize_quadratic():
    """Sanity: all optimizer modes minimize a plain strongly-convex loss."""
    def field(params, batch, rng):
        del batch, rng
        g = {"w": 2.0 * params["w"]}
        return g, {"loss": jnp.sum(params["w"] ** 2)}

    for opt in ("sgd", "adam", "oadam", "omd"):
        tr = DQGAN(field_fn=field,
                   dq=DQConfig(optimizer=opt, compressor="identity",
                               exchange="exact", error_feedback=False,
                               lr=0.05, worker_axes=()))
        st = tr.init({"w": jnp.full((4,), 3.0)})
        step = jax.jit(tr.step)
        for _ in range(500):
            st = step(st, None, jax.random.key(0)).state
        assert float(jnp.linalg.norm(st.params["w"])) < 1e-2, opt
