"""Observability (repro.obs, DESIGN.md §11): the bit-exactness contract
(metrics="off" is the exact pre-obs step graph AND trajectory), the
empirical-δ telemetry against the analytic compressor bounds, the sink
schema, the per-bucket ledger accounting, and the report CLI."""
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro import obs
from repro.comm.planner import analytic_delta
from repro.configs.base import DQConfig
from repro.core import compressors as C
from repro.core.dqgan import DQGAN
from repro.core.error_feedback import compress_with_ef
from repro.models.gan import GANConfig, gan_field_fn, mlp_gan_init
from repro.obs import report as obs_report
from repro.strategy import (Compression, Observability, Strategy,
                            StrategyError)

KEY = jax.random.key(0)


# --------------------------------------------------------------------------- #
# MetricSpec registry + Observability component
# --------------------------------------------------------------------------- #
def test_metric_spec_lattice():
    off, wire, full = (obs.METRIC_SPECS[k] for k in ("off", "wire", "full"))
    assert not off.on
    assert wire.on and full.on
    # wire ⊂ full: every group wire measures, full measures too
    for f in ("moments", "delta", "ef_norms", "staleness"):
        assert not getattr(wire, f) or getattr(full, f)
    # metric_keys is the out_specs contract: stable, bucket-aware
    assert obs.metric_keys(off, 0) == ()
    assert obs.metric_keys(wire, 0) == ("delta_hat", "ef_e1_norm",
                                        "ef_e2_norm")
    assert "bucket_delta" in obs.metric_keys(wire, 3)
    assert obs.metric_keys(full, 2) == (
        "msg_mean", "msg_var", "bucket_mean", "bucket_var", "delta_hat",
        "bucket_delta", "ef_e1_norm", "ef_e2_norm", "staleness_hist")


def test_observability_validation():
    with pytest.raises(StrategyError, match="metrics"):
        Observability(metrics="everything")
    # δ̂ reads the materialized EF residual — needs EF on
    with pytest.raises(StrategyError, match="error_feedback"):
        Strategy(compression=Compression(error_feedback=False),
                 observability=Observability(metrics="wire"))
    # off composes with anything
    Strategy(compression=Compression(error_feedback=False))


def test_observability_excluded_from_identity_hash():
    """Turning telemetry on must not shift the structural identity —
    checkpoint guards and CI regression baselines key on short_hash()."""
    base = Strategy()
    for metrics in ("wire", "full"):
        st = Strategy(observability=Observability(metrics=metrics,
                                                  spans=True))
        assert st.short_hash() == base.short_hash()
        assert "observability" not in st.identity_dict()
    # ... but the exact serialization keeps it (round-trip fidelity)
    st = Strategy(observability=Observability(metrics="full"))
    assert Strategy.from_json(st.to_json()) == st
    # pre-obs 4-component JSON still parses (defaults to off)
    old = {k: v for k, v in json.loads(Strategy().to_json()).items()
           if k != "observability"}
    assert Strategy.from_json(json.dumps(old)).observability.metrics == "off"


# --------------------------------------------------------------------------- #
# collector + finalize numerics
# --------------------------------------------------------------------------- #
def test_collector_finalize_matches_numpy():
    spec = obs.METRIC_SPECS["full"]
    col = obs.Collector(spec, n_buckets=2)
    rng = np.random.default_rng(0)
    raws = [rng.normal(size=128).astype(np.float32),
            rng.normal(size=64).astype(np.float32)]
    errs = [0.1 * r for r in raws]
    for bid, (r, e) in enumerate(zip(raws, errs)):
        col.bucket(bid, jnp.asarray(r), jnp.asarray(r), jnp.asarray(e))
    sums = col.sums()
    # the step body supplies these (EF tree walk + schedule state)
    sums["e1_sq"], sums["e2_sq"] = obs.ef_norms_sq(
        {"w": {"e1": jnp.asarray(errs[0])}})
    sums["staleness_hist"] = obs.staleness_hist(jnp.zeros(()), 2)
    out = jax.device_get(obs.finalize(spec, sums, col.counts(),
                                      n_workers=1, n_buckets=2))
    np.testing.assert_allclose(out["ef_e1_norm"],
                               np.linalg.norm(errs[0]), rtol=1e-5)
    cat = np.concatenate(raws)
    np.testing.assert_allclose(out["msg_mean"], cat.mean(), rtol=1e-5)
    np.testing.assert_allclose(out["msg_var"], cat.var(), rtol=1e-4)
    np.testing.assert_allclose(out["bucket_mean"],
                               [r.mean() for r in raws], rtol=1e-5)
    np.testing.assert_allclose(out["bucket_var"],
                               [r.var() for r in raws], rtol=1e-4)
    # err = 0.1·op → δ̂ = 1 − 0.01 everywhere
    np.testing.assert_allclose(out["delta_hat"], 0.99, rtol=1e-5)
    np.testing.assert_allclose(out["bucket_delta"], [0.99, 0.99],
                               rtol=1e-5)


def test_staleness_hist_fixed_shape():
    h = jax.device_get(obs.staleness_hist(jnp.asarray([0., 1., 1., 5.]),
                                          bins=3))
    np.testing.assert_array_equal(h, [1.0, 2.0, 1.0])  # 5 → overflow bin


# --------------------------------------------------------------------------- #
# the bit-exactness contract
# --------------------------------------------------------------------------- #
def _mix_trainer(metrics, bucketed=True):
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    dq = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                  error_feedback=True, lr=1e-2, worker_axes=(),
                  comm_plan="uniform" if bucketed else "none",
                  bucket_mb=0.03, obs_metrics=metrics)
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq), cfg


def test_off_vs_full_trajectory_bit_exact():
    """The contract the whole subsystem hangs on: enabling telemetry
    changes nothing about the trajectory — params AND EF residuals are
    bit-identical after jitted steps."""
    finals = {}
    for metrics in ("off", "full"):
        tr, cfg = _mix_trainer(metrics)
        st = tr.init(mlp_gan_init(KEY, cfg))
        step = jax.jit(tr.step)
        for i in range(5):
            batch = {"real": jax.random.normal(jax.random.fold_in(KEY, i),
                                               (64, 2))}
            out = step(st, batch, jax.random.fold_in(KEY, 100 + i))
            st = out.state
        finals[metrics] = (jax.device_get(st), jax.device_get(out.metrics))
    st_off, m_off = finals["off"]
    st_full, m_full = finals["full"]
    assert "obs" not in m_off and "obs" in m_full
    a, b = jax.tree.leaves(st_off), jax.tree.leaves(st_full)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_off_hlo_identical_to_default_strategy():
    """metrics="off" must not merely be numerically close — the lowered
    step computation is the very graph an obs-free Strategy builds."""
    tr_off, cfg = _mix_trainer("off")
    dq = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                  error_feedback=True, lr=1e-2, worker_axes=(),
                  comm_plan="uniform", bucket_mb=0.03)
    tr_plain = DQGAN(field_fn=gan_field_fn(cfg), dq=dq)
    st = tr_off.init(mlp_gan_init(KEY, cfg))
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    texts = [jax.jit(tr.step).lower(st, batch, KEY).as_text()
             for tr in (tr_off, tr_plain)]
    assert texts[0] == texts[1]


def test_full_metrics_do_not_add_retraces():
    """Telemetry rides inside the same trace: one compile per jit
    variant, whether metrics are on or off."""
    for metrics in ("off", "full"):
        tr, cfg = _mix_trainer(metrics)
        traces = []
        inner = tr.field_fn

        def counted(p, b, k):
            traces.append(1)
            return inner(p, b, k)

        tr = DQGAN(field_fn=counted, dq=tr.dq)
        st = tr.init(mlp_gan_init(KEY, cfg))
        step = jax.jit(tr.step)
        batch = {"real": jax.random.normal(KEY, (64, 2))}
        for i in range(4):
            st = step(st, batch, jax.random.fold_in(KEY, i)).state
        assert len(traces) == 1, (metrics, len(traces))


def test_single_device_obs_metrics_shapes():
    tr, cfg = _mix_trainer("full")
    st = tr.init(mlp_gan_init(KEY, cfg))
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    m = jax.device_get(jax.jit(tr.step)(st, batch, KEY).metrics)
    o = m["obs"]
    B = tr._obs_n_buckets(st.params)
    assert B >= 1
    assert np.shape(o["bucket_var"]) == (B,)
    assert np.shape(o["bucket_delta"]) == (B,)
    assert np.shape(o["staleness_hist"]) == (tr._obs_bins(),)
    assert 0.9 < float(o["delta_hat"]) <= 1.0   # qsgd8 is ~0.9999-contractive
    assert float(o["ef_e1_norm"]) > 0.0
    assert float(o["msg_var"]) > 0.0


# --------------------------------------------------------------------------- #
# empirical δ̂ vs the analytic bounds (satellite d)
# --------------------------------------------------------------------------- #
def _measured_delta(comp, d=4096, rounds=8):
    num = den = 0.0
    for i in range(rounds):
        v = jax.random.normal(jax.random.fold_in(KEY, i), (d,))
        vhat = comp.roundtrip(v, jax.random.fold_in(KEY, 100 + i))
        num += float(jnp.sum((vhat - v) ** 2))
        den += float(jnp.sum(v * v))
    return 1.0 - num / den


def test_empirical_delta_matches_analytic():
    d = 4096
    # contractive quantizers: measured tracks the analytic curve
    assert abs(_measured_delta(C.get("qsgd8_linf"), d)
               - analytic_delta(C.get("qsgd8_linf"), d)) < 5e-3
    assert abs(_measured_delta(C.get("qsgd4_linf"), d)
               - analytic_delta(C.get("qsgd4_linf"), d)) < 0.05
    # sign-mean: δ = 2/π for Gaussian inputs
    assert abs(_measured_delta(C.get("sign"), d) - 2 / math.pi) < 0.02


def test_sign_delta_exact_identity():
    """Q(v) = (‖v‖₁/d)·sign(v) gives ‖v − Q(v)‖² = ‖v‖² − ‖v‖₁²/d
    exactly, so δ̂ = ‖v‖₁² / (d‖v‖²) per vector — the telemetry must
    reproduce the closed form, not just the Gaussian average."""
    comp = C.get("sign")
    v = jax.random.normal(KEY, (2048,))
    vhat = comp.roundtrip(v, KEY)
    measured = 1.0 - float(jnp.sum((vhat - v) ** 2) / jnp.sum(v * v))
    exact = float(jnp.sum(jnp.abs(v)) ** 2 / (v.size * jnp.sum(v * v)))
    assert abs(measured - exact) < 1e-5


def test_low_bit_quantizer_is_not_contractive():
    """qsgd2 (one stochastic level) is unbiased but NOT a δ-contraction —
    measured δ̂ goes negative while the planner's analytic_delta floors at
    1e-3. This gap is exactly what the δ̂ telemetry exists to surface."""
    measured = _measured_delta(C.get("qsgd2_linf"))
    assert measured < 0.0
    assert measured > -2.0                       # still variance-bounded
    assert analytic_delta(C.get("qsgd2_linf"), 4096) == pytest.approx(1e-3)


def test_ef_corrected_stream_error_decays():
    """With error feedback the time-averaged transmitted signal converges
    to the true gradient even under an aggressively biased compressor
    (top-25%); without EF the bias never washes out (satellite d)."""
    comp = C.TopK(frac=0.25)
    g = jax.random.normal(KEY, (512,))

    def stream_err(use_ef, T):
        e = jnp.zeros_like(g)
        tot = jnp.zeros_like(g)
        for t in range(T):
            k = jax.random.fold_in(KEY, t)
            if use_ef:
                _, sent, e = compress_with_ef(comp, g, e, k)
            else:
                sent = comp.roundtrip(g, k)
            tot = tot + sent
        return float(jnp.linalg.norm(tot / T - g) / jnp.linalg.norm(g))

    ef_short, ef_long = stream_err(True, 4), stream_err(True, 32)
    raw_long = stream_err(False, 32)
    assert ef_long < ef_short < raw_long
    assert ef_long < 0.1 and raw_long > 0.4


# --------------------------------------------------------------------------- #
# sink schema + backends (tentpole part 2)
# --------------------------------------------------------------------------- #
def test_schema_validation():
    ok = {"v": obs.SCHEMA_VERSION, "kind": "train_log", "step": 0,
          "loss": 1.0}
    obs.validate_event(ok)
    with pytest.raises(obs.SchemaError, match="version"):
        obs.validate_event({**ok, "v": 99})
    with pytest.raises(obs.SchemaError, match="unknown kind"):
        obs.validate_event({**ok, "kind": "vibes"})
    with pytest.raises(obs.SchemaError, match="missing"):
        obs.validate_event({"v": obs.SCHEMA_VERSION, "kind": "timing",
                            "step": 3})
    with pytest.raises(obs.SchemaError):
        obs.validate_event("not a dict")


def test_schema_v2_backward_compatible():
    """v1 files stay readable after the v2 bump; the v2-only kinds are
    refused when an event claims v1 (mislabeled writer, not an old
    file)."""
    assert obs.SCHEMA_VERSION == 2
    v1 = {"v": 1, "kind": "timing", "step": 0, "step_s": 1e-3,
          "interval_s": 1e-2}
    obs.validate_event(v1)                       # v1 read-compat
    prof = {"v": 2, "kind": "profile", "step0": 0, "n_steps": 4,
            "step_s": {"mean": 1e-3}}
    obs.validate_event(prof)
    calib = {"v": 2, "kind": "calibration", "bandwidth_Bps": 1e9,
             "latency_s": 1e-4}
    obs.validate_event(calib)
    for ev in (prof, calib):
        with pytest.raises(obs.SchemaError, match="requires schema v2"):
            obs.validate_event({**ev, "v": 1})
    with pytest.raises(obs.SchemaError, match="missing"):
        obs.validate_event({"v": 2, "kind": "profile", "step0": 0})


def test_jsonl_sink_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs.make_sink(path, strategy_hash="abc123") as sink:
        sink.emit("run_meta", steps=10)
        sink.emit("train_log", step=0, loss=jnp.float32(0.5),
                  hist=jnp.arange(3))
    evs = obs.read_events(path)           # validates every line
    assert [e["kind"] for e in evs] == ["run_meta", "train_log"]
    assert all(e["strategy"] == "abc123" for e in evs)
    # device values were jsonified at emit time
    assert evs[1]["loss"] == 0.5 and evs[1]["hist"] == [0, 1, 2]


def test_sink_rejects_malformed_at_emit(tmp_path):
    sink = obs.make_sink(str(tmp_path / "x.jsonl"))
    with pytest.raises(obs.SchemaError):
        sink.emit("timing", step=1)       # missing step_s/interval_s
    sink.close()


def test_make_sink_mapping(tmp_path):
    assert isinstance(obs.make_sink(""), obs.StdoutSink)
    assert not obs.make_sink("").verbose
    assert obs.make_sink("stdout").verbose
    assert isinstance(obs.make_sink("null"), obs.NullSink)
    tee = obs.make_sink(str(tmp_path / "a.jsonl"), tee_stdout=True)
    assert isinstance(tee, obs.TeeSink)
    tee.close()


def test_stdout_sink_default_rendering(capsys):
    """The quiet default prints train_log rows exactly as the pre-obs
    launcher did (bare JSON, no envelope) and nothing else."""
    sink = obs.StdoutSink(strategy_hash="deadbeef")
    sink.emit("run_meta", steps=5)
    rec = {"step": 3, "loss": 0.25}
    sink.emit("train_log", **rec)
    out = capsys.readouterr().out
    assert out == json.dumps(rec) + "\n"
    obs.StdoutSink(verbose=True).emit("run_meta", steps=5)
    assert "# obs[run_meta]" in capsys.readouterr().out


# --------------------------------------------------------------------------- #
# CommLedger per-bucket accounting (satellite b)
# --------------------------------------------------------------------------- #
def _budget_ledger(M=8):
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    params = jax.eval_shape(lambda k: mlp_gan_init(k, cfg),
                            jax.random.key(0))
    shapes = jax.tree.map(lambda x: tuple(x.shape), params)
    comp = Compression(plan="delta_budget", budget_mb=0.024,
                       bucket_mb=0.0625, adaptive=True)
    layout, family = comp.build_family(shapes, None, M)
    led = comm.CommLedger.from_plan(layout, family.full, "two_phase", M,
                                    comp.compressor, family=family)
    return led, family, M


def test_ledger_per_bucket_rows():
    led, family, M = _budget_ledger()
    rows = led.per_bucket()
    assert len(rows) == len(family.full.assignments)
    for r, b in zip(rows, family.full.assignments):
        assert r["compressor"] == b.compressor
        assert r["elems"] == b.elems
        assert r["payload_bytes"] > 0 and r["wire_bytes"] > 0
        assert 0 < r["delta"] <= 1.0
        assert 0 < r["budget_share"] <= 1.0
    # shares account for the whole payload against the effective budget
    assert sum(r["budget_share"] for r in rows) == pytest.approx(
        family.full.payload_bytes / led.effective_budget(), abs=0.01)


def test_ledger_per_bucket_repriced_under_participation():
    """When n of M report, rows are priced under the family member the
    round actually selected, and the effective budget scales to B·M/n."""
    led, family, M = _budget_ledger()
    n = M // 2
    assert led.effective_budget(n) == pytest.approx(
        led.budget_bytes * M / n)
    rows_n = led.per_bucket(participants=n)
    sel = family.plan_for(n)
    assert [r["compressor"] for r in rows_n] == \
        [b.compressor for b in sel.assignments]
    # the freed budget buys finer bits somewhere (family is adaptive)
    assert sum(r["payload_bytes"] for r in rows_n) >= \
        sum(r["payload_bytes"] for r in led.per_bucket())


def test_ledger_summary_includes_buckets_and_budget():
    led, family, M = _budget_ledger()
    led.tick(5)
    s = led.summary()
    assert len(s["per_bucket"]) == len(family.full.assignments)
    assert s["budget_bytes"] == round(led.budget_bytes)
    assert s["budget_utilization"] == pytest.approx(
        sum(r["payload_bytes"] for r in s["per_bucket"])
        / led.effective_budget(), abs=0.01)
    json.dumps(s)                        # must stay JSON-serializable


# --------------------------------------------------------------------------- #
# report CLI (tentpole part 3)
# --------------------------------------------------------------------------- #
def _demo_events(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs.make_sink(path, strategy_hash="cafe01") as sink:
        sink.emit("run_meta", steps=4, arch="dcgan32", n_workers=2,
                  obs_metrics="full")
        for step, (loss, ef) in enumerate([(0.5, 0.1), (0.4, 0.3)]):
            sink.emit("train_log", step=step, loss=loss)
            sink.emit("timing", step=step, step_s=0.01,
                      interval_s=0.02, steps_in_interval=2)
            sink.emit("obs_metrics", step=step, delta_hat=0.97,
                      bucket_delta=[0.97], ef_e1_norm=ef, ef_e2_norm=0.0,
                      staleness_hist=[2.0, 0.0], msg_mean=0.0,
                      msg_var=1e-3)
        sink.emit("comm_summary", wire_bytes_per_step=1000,
                  compression_ratio=4.0, sim_clock_s=1.0,
                  budget_bytes=4000, budget_utilization=0.25,
                  per_bucket=[{"bucket": 0, "compressor": "qsgd8_linf",
                               "bits": 8, "elems": 996,
                               "payload_bytes": 1000, "wire_bytes": 1000.0,
                               "delta": 0.9999, "budget_share": 0.25}])
    return path


def test_report_summarize_and_render(tmp_path):
    path = _demo_events(tmp_path)
    s = obs_report.summarize(obs.read_events(path))
    assert s["run"]["strategy"] == "cafe01"
    assert s["timing"]["step_s"]["n"] == 2
    [gap] = s["delta_gap"]
    assert gap["gap"] == pytest.approx(0.97 - 0.9999)
    assert s["obs"]["ef_e1"]["growth"] == pytest.approx(3.0)
    text = obs_report.render(s)
    for needle in ("cafe01", "assumed 0.9999", "measured 0.9700",
                   "25.0% utilization", "EF residual", "τ=0:2"):
        assert needle in text, (needle, text)


def test_report_cli_main(tmp_path, capsys):
    path = _demo_events(tmp_path)
    assert obs_report.main([path]) == 0
    assert "empirical δ̂ vs assumed δ" in capsys.readouterr().out
    assert obs_report.main([path, "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["run"]["arch"] == "dcgan32"


# --------------------------------------------------------------------------- #
# launcher end-to-end (single device; the 8-device acceptance run below)
# --------------------------------------------------------------------------- #
def test_train_launcher_writes_valid_sink(tmp_path):
    from repro.launch import train

    path = str(tmp_path / "run.jsonl")
    hist = train.main(["--arch", "dcgan32", "--smoke", "--steps", "4",
                       "--log-every", "2", "--comm-plan", "uniform",
                       "--obs-metrics", "full", "--obs-sink", path])
    assert hist
    evs = obs.read_events(path)          # schema-validates every event
    kinds = [e["kind"] for e in evs]
    assert kinds[0] == "run_meta"
    assert kinds.count("train_log") == kinds.count("timing") == \
        kinds.count("obs_metrics") == len(hist)
    assert kinds[-1] == "comm_summary"
    # every step's timing is a real synced measurement (satellite a):
    # the intervals partition the run
    timing = [e for e in evs if e["kind"] == "timing"]
    assert sum(e["steps_in_interval"] for e in timing) == 4
    assert all(0 < e["step_s"] <= e["interval_s"] for e in timing)
    om = [e for e in evs if e["kind"] == "obs_metrics"][-1]
    assert {"bucket_var", "bucket_delta", "delta_hat", "ef_e1_norm",
            "staleness_hist"} <= set(om)


# --------------------------------------------------------------------------- #
# 8-device invariance + acceptance (subprocess: forced host devices)
# --------------------------------------------------------------------------- #
INVARIANCE_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.strategy import (Compression, ExchangePlan, Observability,
                            Participation, Schedule, Strategy)

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)

def batch(i):
    return {"real": jax.random.normal(jax.random.fold_in(key, i), (64, 2))}

def run(spmd, metrics):
    strat = Strategy(
        compression=(Compression(plan="uniform", bucket_mb=0.03)
                     if spmd == "shard_map" else Compression()),
        exchange=ExchangePlan(
            kind="two_phase" if spmd == "shard_map" else "sim",
            spmd=spmd, worker_axes=("data",)),
        schedule=(Schedule.delayed(tau=2) if spmd == "shard_map"
                  else Schedule()),
        participation=Participation(fraction=0.5),
        observability=Observability(metrics=metrics))
    dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
    tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
               batch_spec=P(("data",)))
    with set_mesh(mesh):
        step = jax.jit(tr.step, static_argnums=(3,))
        st = tr.init(params)
        for i in range(6):
            out = step(st, batch(i), jax.random.key(7), True)
            st = out.state
        return jax.device_get(st), jax.device_get(out.metrics)

for spmd in ("shard_map", "vmap"):
    st_off, m_off = run(spmd, "off")
    st_full, m_full = run(spmd, "full")
    assert "obs" not in m_off and "obs" in m_full, spmd
    a, b = jax.tree.leaves(st_off), jax.tree.leaves(st_full)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    o = m_full["obs"]
    hist = np.asarray(o["staleness_hist"])
    assert hist.sum() == 8.0, (spmd, hist)   # every worker lands in a bin
    assert float(o["ef_e1_norm"]) > 0.0, spmd
    assert -2.0 < float(o["delta_hat"]) <= 1.0, (spmd, o["delta_hat"])
print("OK")
"""


@pytest.mark.multidevice
def test_off_vs_full_bit_exact_8dev(multidevice):
    """Both SPMD paths, 8 workers, partial participation (+ bounded
    staleness on shard_map): telemetry never perturbs the trajectory."""
    assert "OK" in multidevice(INVARIANCE_8DEV_SCRIPT)


ACCEPTANCE_8DEV_SCRIPT = r"""
import os, tempfile
from repro.launch import train
from repro.obs import read_events
from repro.obs.report import render, summarize

path = os.path.join(tempfile.mkdtemp(), "run.jsonl")
hist = train.main(["--arch", "dcgan32", "--smoke", "--steps", "6",
                   "--log-every", "3", "--preset", "adaptive_budget",
                   "--obs-metrics", "full", "--obs-sink", path])
assert hist
evs = read_events(path)                 # schema-validates
om = [e for e in evs if e["kind"] == "obs_metrics"]
assert om, [e["kind"] for e in evs]
for e in om:
    for k in ("bucket_var", "bucket_delta", "delta_hat", "ef_e1_norm",
              "ef_e2_norm", "staleness_hist"):
        assert k in e, (k, sorted(e))
cs = [e for e in evs if e["kind"] == "comm_summary"][-1]
assert cs["per_bucket"] and cs["budget_utilization"] > 0
text = render(summarize(evs))
for needle in ("timing (synced)", "empirical δ̂ vs assumed δ",
               "utilization", "EF residual", "staleness histogram"):
    assert needle in text, (needle, text)
print("OK")
"""


@pytest.mark.multidevice
def test_adaptive_budget_acceptance_8dev(multidevice):
    """The ISSUE's acceptance run: metrics="full" on the adaptive_budget
    preset over 8 forced host devices fills the sink with per-bucket
    variance, empirical δ, EF norms and staleness histograms, and the
    report CLI renders all of it."""
    assert "OK" in multidevice(ACCEPTANCE_8DEV_SCRIPT)
