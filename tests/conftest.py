import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 560):
    """Run a python snippet in a subprocess with a forced host device count.

    Needed because jax locks the device count at first init — the main test
    process stays single-device (per the dry-run isolation rule)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}"
            f"\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
