"""Per-architecture smoke + correctness tests on reduced configs:
(f) deliverable — one reduced-variant train step per assigned arch, plus the
decode-vs-teacher-forcing equivalence that exercises every cache type
(KV, rolling-window KV, SSD state, RG-LRU state, enc-dec cross-attn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.models import build
from repro.models import model as lm

ARCHS = list(cfgs.ASSIGNED) + ["gemma-2b-swa"]
KEY = jax.random.key(0)


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.encdec.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = cfgs.get(arch).reduced()
    bundle = build(cfg)
    params = bundle.init(KEY, max_seq=64)
    batch = _batch(cfg)
    grads, metrics = jax.jit(bundle.field_fn)(params, batch, KEY)
    assert jnp.isfinite(metrics["loss"])
    flat = jax.tree.leaves(grads)
    assert all(g.shape == p.shape for g, p in
               zip(flat, jax.tree.leaves(params)))
    assert not any(bool(jnp.any(jnp.isnan(g))) for g in flat)
    assert float(sum(jnp.sum(jnp.abs(g)) for g in flat)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    """prefill(tokens[:p]) then step-by-step decode must reproduce the
    teacher-forced forward logits at every position."""
    cfg = cfgs.get(arch).reduced()
    bundle = build(cfg)
    params = bundle.init(KEY, max_seq=64)
    B, S, p = 2, 24, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    enc = (0.1 * jax.random.normal(KEY, (B, cfg.encdec.enc_seq, cfg.d_model))
           if cfg.is_encdec else None)

    # teacher forcing
    positions = jnp.arange(S)
    enc_out = lm.encode(params, cfg, enc) if cfg.is_encdec else None
    hidden, _, _ = lm.forward(params, cfg, tokens, positions, enc_out=enc_out)
    full_logits = lm.logits_fn(params, cfg, hidden)  # (B, S, V)

    # prefill + decode
    logits_p, caches = bundle.prefill(params, tokens[:, :p], enc, max_len=S)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, p - 1]),
                               rtol=2e-3, atol=2e-3)
    decode = jax.jit(bundle.decode_step)
    for t in range(p, S):
        logits_t, caches = decode(params, tokens[:, t:t + 1], caches)
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode diverges at position {t}")


def test_sliding_window_masks_distant_tokens():
    """With window w, position t must be independent of tokens < t - w."""
    cfg = cfgs.get("gemma-2b-swa").reduced()  # window 32 -> reduced to 32
    assert cfg.attention_window > 0
    bundle = build(cfg)
    params = bundle.init(KEY, max_seq=256)
    w = cfg.attention_window
    S = w + 16
    t1 = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)  # perturb far past
    h1, _, _ = lm.forward(params, cfg, t1, jnp.arange(S))
    h2, _, _ = lm.forward(params, cfg, t2, jnp.arange(S))
    # positions >= w can no longer see position 0 through ANY layer only if
    # depth*window > S... with 2 layers receptive field is 2w >= S, so just
    # check the LAST position with a 1-layer-deep probe: compare against
    # dense equivalence instead — perturbation must affect early positions
    # but the attention itself at position t>w must mask index 0:
    from repro.models.layers import attention_dense
    q = jax.random.normal(KEY, (1, S, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, S, 2, 16))
    pos = jnp.arange(S)
    out = attention_dense(q, k, v, pos, pos, window=w)
    v2 = v.at[:, 0].add(100.0)  # huge change at position 0
    out2 = attention_dense(q, k, v2, pos, pos, window=w)
    np.testing.assert_allclose(np.asarray(out[:, w:]),
                               np.asarray(out2[:, w:]), atol=1e-5)
    assert float(jnp.max(jnp.abs(out[:, :w] - out2[:, :w]))) > 1.0


def test_chunked_attention_matches_dense():
    from repro.models.layers import attention_chunked, attention_dense
    B, S, H, D = 2, 4096, 2, 32
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D))
    pos = jnp.arange(S)
    for win in (0, 512):
        dense = attention_dense(q, k, v, pos, pos, window=win)
        chunked = attention_chunked(q, k, v, window=win, q_chunk=1024)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                                   rtol=2e-4, atol=2e-4)


def test_chunked_xent_matches_dense():
    cfg = cfgs.get("gemma-2b").reduced()
    import dataclasses
    cfg_chunked = dataclasses.replace(cfg, xent_chunk=8)
    bundle = build(cfg)
    params = bundle.init(KEY, max_seq=64)
    batch = _batch(cfg, B=2, S=32)
    l1 = lm.loss_fn(params, cfg, batch)[0]
    l2 = lm.loss_fn(params, cfg_chunked, batch)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_scan_vs_unrolled_equivalence():
    """scan-over-layers must compute the same function as the unrolled stack."""
    import dataclasses
    cfg = cfgs.get("mamba2-1.3b").reduced()
    cfg_unrolled = dataclasses.replace(cfg, scan_layers=False)
    bundle = build(cfg)
    params = bundle.init(KEY, max_seq=64)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    h1, _, _ = lm.forward(params, cfg, tokens, jnp.arange(16))
    # re-pack scan params into tail list for the unrolled config
    n = cfg.num_layers
    tail = [jax.tree.map(lambda x: x[i], params["scan"]["b0"]) for i in range(n)]
    params2 = {k: v for k, v in params.items() if k != "scan"}
    params2["tail"] = tail
    h2, _, _ = lm.forward(params2, cfg_unrolled, tokens, jnp.arange(16))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_param_count_matches_actual():
    """Analytic param_count (used for MODEL_FLOPS) within 5% of reality."""
    for arch in ("gemma-2b", "mamba2-1.3b", "qwen3-moe-30b-a3b"):
        cfg = cfgs.get(arch).reduced()
        bundle = build(cfg)
        params = bundle.init(KEY, max_seq=64)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.05, (arch, actual, analytic)
