"""repro.strategy: presets, exact JSON round-trip, construction-time
validation (StrategyError naming the offending field), the DQConfig
legacy shim (bit-exact vs the flat flag-bag spelling on 1 and 8
devices), lr_mults group validation, and the checkpoint resume guard."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.strategy import (
    PRESETS,
    Compression,
    ExchangePlan,
    Participation,
    Schedule,
    Strategy,
    StrategyError,
    get_preset,
)

KEY = jax.random.key(0)


# --------------------------------------------------------------------------- #
# presets + JSON round-trip
# --------------------------------------------------------------------------- #
def test_every_preset_constructs_and_roundtrips_exactly():
    assert len(PRESETS) >= 5
    for name, st in PRESETS.items():
        s = st.to_json()
        back = Strategy.from_json(s)
        assert back == st, name
        # canonical: serialize(deserialize(s)) is byte-identical
        assert back.to_json() == s, name
        assert back.short_hash() == st.short_hash(), name


def test_hash_is_structural():
    a = get_preset("paper_dqgan")
    b = a.evolve(staleness_tau=1)  # no-op evolve
    assert a.short_hash() == b.short_hash()
    c = a.evolve(schedule="delayed", staleness_tau=2)
    assert c.short_hash() != a.short_hash()
    assert "schedule.kind: 'every_step' != 'delayed'" in a.diff(c)


def test_unknown_preset_and_json_fields_raise():
    with pytest.raises(StrategyError, match="preset"):
        get_preset("nope")
    with pytest.raises(StrategyError, match="unknown component"):
        Strategy.from_json('{"compresion": {}}')
    with pytest.raises(StrategyError, match="unknown field"):
        Strategy.from_json('{"schedule": {"K": 4}}')
    with pytest.raises(StrategyError, match="invalid JSON"):
        Strategy.from_json("{not json")


# --------------------------------------------------------------------------- #
# every documented invalid combination is a StrategyError naming the field
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("make,field", [
    # schedule lattice
    (lambda: Schedule(kind="every_step", tau=2), "schedule.tau"),
    (lambda: Schedule(kind="delayed", k=3), "schedule.k"),
    (lambda: Schedule(kind="local_k", k=0), "schedule.k"),
    (lambda: Schedule.delayed(0), "schedule.tau"),
    (lambda: Schedule(kind="bogus"), "schedule.kind"),
    # compression
    (lambda: Compression(compressor="bogus"), "compression.compressor"),
    (lambda: Compression(plan="bogus"), "compression.plan"),
    (lambda: Compression(plan="delta_budget"), "compression.budget_mb"),
    (lambda: Compression(plan="uniform", budget_mb=1.0),
     "compression.budget_mb"),
    (lambda: Compression(bucket_mb=0.0), "compression.bucket_mb"),
    (lambda: Compression(ef_dtype="int8"), "compression.ef_dtype"),
    # exchange
    (lambda: ExchangePlan(kind="bogus"), "exchange.kind"),
    (lambda: ExchangePlan(spmd="bogus"), "exchange.spmd"),
    # participation
    (lambda: Participation(fraction=0.0), "participation.fraction"),
    (lambda: Participation(fraction=1.5), "participation.fraction"),
    (lambda: Participation(straggler_profile="bogus"),
     "participation.straggler_profile"),
    # cross-field
    (lambda: Strategy(participation=Participation(fraction=0.5),
                      exchange=ExchangePlan(kind="exact")),
     "participation.fraction"),
    (lambda: Strategy(compression=Compression(plan="uniform"),
                      exchange=ExchangePlan(spmd="vmap")),
     "compression.plan"),
    (lambda: Strategy(exchange=ExchangePlan(kind="two_phase", spmd="vmap")),
     "exchange.kind"),
])
def test_invalid_combinations_raise_with_field_name(make, field):
    with pytest.raises(StrategyError) as ei:
        make()
    assert field in str(ei.value), str(ei.value)


def test_strategy_error_is_a_value_error():
    assert issubclass(StrategyError, ValueError)


# --------------------------------------------------------------------------- #
# the legacy DQConfig shim
# --------------------------------------------------------------------------- #
def test_legacy_flag_bag_builds_equal_strategy():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dq = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                      exchange="sim", schedule="delayed", staleness_tau=2,
                      worker_axes=())
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    want = Strategy(exchange=ExchangePlan(kind="sim", worker_axes=()),
                    schedule=Schedule.delayed(2))
    assert dq.strategy == want
    # the blessed spelling mirrors back into the flat fields, no warning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dq2 = DQConfig.from_strategy(want, optimizer="omd")
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert dq2.schedule == "delayed" and dq2.staleness_tau == 2
    assert dq2.strategy == want and dq2 == dq


def test_legacy_bad_combos_raise_at_construction():
    with pytest.raises(StrategyError, match="schedule.tau"):
        DQConfig(staleness_tau=2)
    with pytest.raises(StrategyError, match="compression.budget_mb"):
        DQConfig(comm_plan="delta_budget")
    with pytest.raises(StrategyError, match="participation.fraction"):
        DQConfig(participation=0.5, exchange="exact")


def test_from_strategy_rejects_distribution_keywords():
    with pytest.raises(ValueError, match="strategy fields"):
        DQConfig.from_strategy(Strategy(), compressor="sign")


def test_replace_on_blessed_config_does_not_warn():
    """dataclasses.replace(dq, lr=...) is the documented optimizer-side
    patch path (gan_common dq_overrides) — it must not trip the legacy
    deprecation warning just because the carried strategy is non-default."""
    dq = DQConfig.from_strategy(
        Strategy(exchange=ExchangePlan(worker_axes=())), optimizer="omd")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dq2 = dataclasses.replace(dq, lr=1e-4)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert dq2.strategy == dq.strategy and dq2.lr == 1e-4


def test_dqgan_takes_a_strategy_directly():
    st = Strategy(exchange=ExchangePlan(worker_axes=()))
    tr = DQGAN(field_fn=lambda p, b, k: (p, {}), strategy=st)
    assert tr.strategy == st and tr.dq.strategy == st
    with pytest.raises(ValueError, match="disagree"):
        DQGAN(field_fn=lambda p, b, k: (p, {}),
              dq=DQConfig.from_strategy(st),
              strategy=st.evolve(compressor="sign"))


# --------------------------------------------------------------------------- #
# legacy spelling → Strategy → training is bit-exact (1 device; the
# 8-device variants run under the forced-host-device subprocess)
# --------------------------------------------------------------------------- #
A = jnp.asarray(np.random.RandomState(3).randn(6, 6), jnp.float32)


def _field(params, batch, rng):
    x, y = params["x"], params["y"]
    return {"x": A @ y, "y": -(A.T @ x)}, {"loss": x @ A @ y}


def _train(dq, steps=8):
    tr = DQGAN(field_fn=_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step, static_argnums=(3,))
    sched = tr.strategy.schedule.runtime()
    for i in range(steps):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    return jax.device_get(st.params)


@pytest.mark.parametrize("legacy", [
    dict(schedule="every_step"),
    dict(schedule="local_k", local_k=4),
    dict(schedule="delayed", staleness_tau=2),
])
def test_legacy_vs_strategy_training_bit_exact(legacy):
    dq_legacy = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                         exchange="sim", lr=0.05, worker_axes=(), **legacy)
    st = Strategy.from_legacy(exchange="sim", worker_axes=(), **legacy)
    dq_typed = DQConfig.from_strategy(st, optimizer="omd", lr=0.05)
    assert dq_legacy == dq_typed
    a, b = _train(dq_legacy), _train(dq_typed)
    for k in ("x", "y"):
        np.testing.assert_array_equal(a[k], b[k])


STRATEGY_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.strategy import Strategy

A = jnp.array(np.random.RandomState(0).randn(4, 4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0

def run(dq, steps=12):
    tr = DQGAN(field_fn=field, dq=dq, mesh=mesh,
               param_specs={"x": P(), "y": P()}, batch_spec=P(("data",)))
    sched = tr.strategy.schedule.runtime()
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            st = step(st, batch, jax.random.key(7),
                      sched.is_exchange_step(i)).state
    return jax.device_get(st.params)

for legacy in (dict(schedule="every_step"),
               dict(schedule="local_k", local_k=4),
               dict(schedule="delayed", staleness_tau=2)):
    dq_legacy = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                         exchange="sim", lr=0.05, worker_axes=("data",),
                         **legacy)
    st = Strategy.from_legacy(exchange="sim", worker_axes=("data",),
                              **legacy)
    dq_typed = DQConfig.from_strategy(st, optimizer="omd", lr=0.05)
    assert dq_legacy == dq_typed
    a, b = run(dq_legacy), run(dq_typed)
    for k in "xy":
        np.testing.assert_array_equal(a[k], b[k])
print("OK")
"""


@pytest.mark.multidevice
def test_legacy_vs_strategy_bit_exact_8dev(multidevice):
    out = multidevice(STRATEGY_8DEV_SCRIPT)
    assert "OK" in out


# --------------------------------------------------------------------------- #
# lr_mults group validation at DQGAN.init
# --------------------------------------------------------------------------- #
def test_lr_mults_unknown_group_raises():
    dq = DQConfig(optimizer="oadam", lr_mults=(("disc_", 5.0),))
    tr = DQGAN(field_fn=_field, dq=dq)
    with pytest.raises(ValueError, match=r"disc_.*not found"):
        tr.init({"gen": {"w": jnp.ones(3)}, "disc": {"w": jnp.ones(3)}})
    # valid group names pass
    ok = DQGAN(field_fn=_field, dq=DQConfig(optimizer="oadam",
                                            lr_mults=(("disc", 5.0),)))
    ok.init({"gen": {"w": jnp.ones(3)}, "disc": {"w": jnp.ones(3)}})


# --------------------------------------------------------------------------- #
# checkpoint: embedded strategy + fail-fast resume diff
# --------------------------------------------------------------------------- #
def test_checkpoint_strategy_guard(tmp_path):
    path = str(tmp_path / "ck.npz")
    st = Strategy(exchange=ExchangePlan(worker_axes=()),
                  schedule=Schedule.delayed(2))
    checkpoint.save(path, {"x": jnp.ones(3)}, step=7,
                    meta={"strategy": st.to_json()})
    assert checkpoint.read_meta(path)["strategy"] == st.to_json()
    assert checkpoint.latest_step(path) == 7
    checkpoint.verify_strategy(path, st)  # same strategy: fine
    other = st.evolve(schedule="every_step", staleness_tau=1)
    with pytest.raises(ValueError) as ei:
        checkpoint.verify_strategy(path, other)
    msg = str(ei.value)
    assert "schedule.kind: 'delayed' != 'every_step'" in msg
    assert "schedule.tau: 2 != 1" in msg
    # host-only fields (straggler profile) never block a resume — they
    # feed the wall-clock model, not the DQState layout
    checkpoint.verify_strategy(
        path, st.evolve(straggler_profile="heavy"))
    # pre-strategy checkpoints warn instead of failing
    checkpoint.save(path, {"x": jnp.ones(3)}, step=7)
    with pytest.warns(UserWarning, match="no embedded strategy"):
        checkpoint.verify_strategy(path, st)
    # the meta dict cannot clobber the reserved __meta__ record keys
    with pytest.raises(ValueError, match="reserved"):
        checkpoint.save(path, {"x": jnp.ones(3)}, step=7, meta={"step": 0})


# --------------------------------------------------------------------------- #
# CLI generation
# --------------------------------------------------------------------------- #
def test_cli_flags_resolve_to_strategy():
    import argparse

    from repro.strategy import add_strategy_args, strategy_from_args

    ap = argparse.ArgumentParser()
    add_strategy_args(ap)
    args = ap.parse_args(["--preset", "ssp_server", "--staleness-tau", "2",
                          "--no-error-feedback"])
    st = strategy_from_args(args, worker_axes=("data",))
    want = get_preset("ssp_server").evolve(
        staleness_tau=2, error_feedback=False, worker_axes=("data",))
    assert st == want

    args = ap.parse_args(["--strategy-json",
                          get_preset("low_bandwidth").to_json()])
    assert strategy_from_args(args) == get_preset("low_bandwidth")

    # boolean overrides work in BOTH directions over a preset base
    args = ap.parse_args(["--preset", "quantized_no_ef", "--error-feedback"])
    assert strategy_from_args(args).compression.error_feedback is True

    with pytest.raises(SystemExit):
        ap.parse_args(["--schedule", "bogus"])


def test_cli_kind_override_resets_companion_fields():
    """`--preset X --schedule Y` must not drag the preset's k/tau/budget
    onto a kind they are invalid for."""
    import argparse

    from repro.strategy import add_strategy_args, strategy_from_args

    ap = argparse.ArgumentParser()
    add_strategy_args(ap)
    # low_bandwidth is local_k(4): switching the kind drops K...
    st = strategy_from_args(
        ap.parse_args(["--preset", "low_bandwidth",
                       "--schedule", "every_step"]))
    assert st.schedule == Schedule.every_step()
    # ...but keeping the kind keeps the preset's K
    st = strategy_from_args(
        ap.parse_args(["--preset", "low_bandwidth",
                       "--schedule", "local_k"]))
    assert st.schedule.k == 4
    # ssp_server is delayed(4): kind switch drops tau; explicit tau wins
    st = strategy_from_args(
        ap.parse_args(["--preset", "ssp_server",
                       "--schedule", "local_k", "--local-k", "2"]))
    assert st.schedule == Schedule.local_k(2)
    # byte_budget carries budget_mb=1.0: switching plan drops the budget
    st = strategy_from_args(
        ap.parse_args(["--preset", "byte_budget",
                       "--comm-plan", "uniform"]))
    assert st.compression.plan == "uniform"
    assert st.compression.budget_mb == 0.0


def test_gate_refuses_fully_unmatched_baseline():
    """A sweep/schema change that shifts EVERY strategy hash must fail
    the gate, not silently gate nothing."""
    from benchmarks.run import check_sched_regression

    base = {"rows": [{"schedule": "delayed", "compressor": "8bit", "M": 8,
                      "strategy": "aaa111", "mean_step_s": 1.0,
                      "wire_mb": 10.0}]}
    shifted = {"rows": [{"schedule": "delayed", "compressor": "8bit",
                         "M": 8, "strategy": "ddd444",
                         "mean_step_s": 1.0, "wire_mb": 10.0}]}
    fails = check_sched_regression(shifted, base)
    assert len(fails) == 1 and "no current row matches" in fails[0]
