"""Substrate tests: data pipeline, checkpointing, optimizers, sharding
rules, dry-run utilities, GAN field."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.configs as cfgs
from repro import checkpoint, optim
from repro.configs.base import SHAPES, DQConfig
from repro.core.dqgan import DQGAN
from repro.data import (gaussian_mixture_sampler, lm_batch_iterator,
                        procedural_images, synthetic_lm_batch)
from repro.models import build
from repro.models.gan import GANConfig, clip_disc

KEY = jax.random.key(0)


# ------------------------------- data -------------------------------------- #
def test_lm_batch_shapes_and_determinism():
    b1 = synthetic_lm_batch(KEY, 4, 16, 100)
    b2 = synthetic_lm_batch(KEY, 4, 16, 100)
    assert b1["tokens"].shape == (4, 16) and b1["tokens"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert int(jnp.max(b1["targets"])) < 100
    # targets are the next-step stream of tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


def test_lm_iterator_advances():
    it = lm_batch_iterator(0, 2, 8, 50)
    a, b = next(it), next(it)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_gaussian_mixture_covers_modes():
    sample, centers = gaussian_mixture_sampler(n_modes=8)
    pts = sample(KEY, 4000)
    d = jnp.linalg.norm(pts[:, None] - centers[None], axis=-1)
    assign = jnp.argmin(d, axis=1)
    counts = np.bincount(np.asarray(assign), minlength=8)
    assert (counts > 100).all()


def test_procedural_images_range():
    imgs = procedural_images(KEY, 8, size=32)
    assert imgs.shape == (8, 32, 32, 3)
    assert float(jnp.min(imgs)) >= -1 and float(jnp.max(imgs)) <= 1
    # nontrivial variance across images (structured, not constant)
    assert float(jnp.std(imgs)) > 0.05


# ---------------------------- checkpoint ----------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)},
        "lst": [jnp.full((2,), 7.0)],
    }
    p = str(tmp_path / "ck.npz")
    checkpoint.save(p, tree, step=42)
    assert checkpoint.latest_step(p) == 42
    back = checkpoint.restore(p, jax.eval_shape(lambda: tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_dqgan_state_roundtrip(tmp_path):
    cfg = cfgs.get("gemma-2b").reduced()
    bundle = build(cfg)
    params = bundle.init(KEY, 32)
    tr = DQGAN(field_fn=bundle.field_fn,
               dq=DQConfig(optimizer="omd", compressor="qsgd8_linf",
                           exchange="sim", worker_axes=()))
    st = tr.init(params)
    p = str(tmp_path / "state.npz")
    checkpoint.save(p, st, step=0)
    back = checkpoint.restore(p, jax.eval_shape(lambda: st))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(st.params)[0]),
        np.asarray(jax.tree.leaves(back.params)[0]))


# ---------------------------- optimizers ----------------------------------- #
@pytest.mark.parametrize("name", ["sgd", "adam", "oadam"])
def test_single_machine_optimizers(name):
    opt = optim.REGISTRY[name](0.1)
    params = {"w": jnp.full((8,), 5.0)}
    state = opt.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.linalg.norm(params["w"])) < 1e-2


def test_cosine_schedule_shape():
    sch = optim.cosine_lr(1.0, warmup=10, total=100)
    assert float(sch(0)) == 0.0
    assert abs(float(sch(10)) - 1.0) < 1e-6
    assert float(sch(100)) < 0.01


# ---------------------------- sharding rules -------------------------------- #
def test_param_specs_consistency():
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as shd

    cfg = cfgs.get("gemma-2b")
    bundle = build(cfg)
    params = jax.eval_shape(lambda: bundle.init(KEY, 8))
    for mode in ("dp", "fsdp"):
        specs = shd.param_specs(params, cfg, mode)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= leaf.ndim, (spec, leaf.shape)


def test_sanitize_spec_drops_indivisible():
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import sanitize_spec

    class FakeMesh:
        shape = {"model": 16, "data": 16}

    s = sanitize_spec(P("model", None), (51865, 384), FakeMesh)
    assert s == P(None, None)
    s = sanitize_spec(P("model", None), (256000, 384), FakeMesh)
    assert s == P("model", None)
    s = sanitize_spec(P(("data", "model"),), (512,), FakeMesh)
    assert s == P(("data", "model"))
    s = sanitize_spec(P(("data", "model"),), (128,), FakeMesh)
    assert s == P(None)


# ---------------------------- dry-run utils -------------------------------- #
def test_parse_collective_bytes():
    from repro.launch.dryrun import parse_collective_bytes

    hlo = """
  %ag = s8[16,1024]{1,0} all-gather(s8[2,1024] %x), replica_groups={}
  %ar = (f32[512]{0}, f32[16]{0}) all-reduce(...), to_apply=%add
  %a2a.1 = s8[8,128]{1,0} all-to-all(s8[8,128] %y), dimensions={0}
  %ag2 = bf16[4,256]{1,0} all-gather-start(bf16[1,256] %z)
  %agd = bf16[4,256]{1,0} all-gather-done(bf16[4,256] %ag2)
"""
    c = parse_collective_bytes(hlo)
    assert c["all-gather"]["count"] == 2
    assert c["all-gather"]["bytes"] == 16 * 1024 + 4 * 256 * 2
    assert c["all-gather"]["int8_bytes"] == 16 * 1024
    assert c["all-reduce"]["bytes"] == (512 + 16) * 4
    assert c["all-to-all"]["int8_bytes"] == 8 * 128


def test_model_flops_and_applicability():
    from repro.launch.dryrun import applicable, model_flops

    cfg = cfgs.get("gemma-2b")
    tr = SHAPES["train_4k"]
    assert model_flops(cfg, tr) == 6.0 * cfg.param_count() * 256 * 4096
    moe = cfgs.get("qwen3-moe-30b-a3b")
    assert model_flops(moe, tr) < 6.0 * moe.param_count() * 256 * 4096
    assert applicable(cfgs.get("yi-34b"), SHAPES["long_500k"])[0] is False
    assert applicable(cfgs.get("mamba2-1.3b"), SHAPES["long_500k"])[0] is True
    assert applicable(cfgs.get("recurrentgemma-2b"), SHAPES["long_500k"])[0] is True


def test_exchange_modeled_wire_bytes():
    from repro.core import compressors as C
    from repro.core.exchange import modeled_wire_bytes

    shape = (1 << 20,)
    comp = C.get("qsgd8_linf")
    full = modeled_wire_bytes("exact", comp, shape, 32)
    two = modeled_wire_bytes("two_phase", comp, shape, 32)
    assert two < full / 3.5  # ~4x reduction at 8 bits


# ------------------------------- GAN ---------------------------------------- #
def test_gan_field_and_clip():
    from repro.models.gan import gan_field_fn, mlp_gan_init

    cfg = GANConfig(name="toy", image_size=0, latent_dim=8, hidden=32)
    params = mlp_gan_init(KEY, cfg)
    field = gan_field_fn(cfg)
    batch = {"real": jax.random.normal(KEY, (16, 2))}
    grads, metrics = jax.jit(field)(params, batch, KEY)
    assert set(grads) == {"gen", "disc"}
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads))
    clipped = clip_disc(params, cfg)
    for leaf in jax.tree.leaves(clipped["disc"]):
        assert float(jnp.max(jnp.abs(leaf))) <= cfg.weight_clip + 1e-7


def test_dcgan_shapes():
    from repro.models.gan import dcgan_discriminate, dcgan_generate, dcgan_init

    cfg = GANConfig(image_size=32, channels=3, latent_dim=16, base_width=8)
    p = dcgan_init(KEY, cfg)
    z = jax.random.normal(KEY, (4, 16))
    imgs = dcgan_generate(p["gen"], cfg, z)
    assert imgs.shape == (4, 32, 32, 3)
    score = dcgan_discriminate(p["disc"], cfg, imgs)
    assert score.shape == (4,)
