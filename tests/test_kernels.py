"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize import quantize_ef_blocked
from repro.kernels.ref import flash_attention_ref, quantize_ef_ref

KEY = jax.random.key(0)


@pytest.mark.parametrize("rows,cols", [(8, 128), (64, 256), (256, 512),
                                       (32, 1024)])
@pytest.mark.parametrize("e_dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_ef_matches_ref(rows, cols, e_dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    g = 0.3 * jax.random.normal(k1, (rows, cols), jnp.float32)
    e = (0.05 * jax.random.normal(k2, (rows, cols))).astype(e_dtype)
    r = jax.random.uniform(k3, (rows, cols), jnp.float32)
    br = min(rows, 64)
    while rows % br:
        br //= 2
    codes, scale, e_new = quantize_ef_blocked(g, e, r, block_rows=br)
    codes_r, scale_r, e_new_r = quantize_ef_ref(g, e, r)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(scale_r),
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(e_new, np.float32), np.asarray(e_new_r, np.float32),
        rtol=1e-2, atol=1e-3)


def test_quantize_ef_reconstruction_bound():
    """codes*scale/levels must reconstruct g+e within one quantization bin."""
    g = jax.random.normal(KEY, (128, 256))
    e = jnp.zeros_like(g)
    r = jax.random.uniform(jax.random.fold_in(KEY, 1), g.shape)
    codes, scale, e_new = quantize_ef_blocked(g, e, r)
    deq = codes.astype(jnp.float32) * scale / 127.0
    err = jnp.abs(deq - g)
    bin_size = scale / 127.0
    assert bool(jnp.all(err <= bin_size + 1e-6))
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(e_new),
                               atol=1e-6)


@pytest.mark.parametrize("S", [128, 256, 512])
@pytest.mark.parametrize("D", [64, 128])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(S, D, causal):
    q = jax.random.normal(KEY, (2, S, 2, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, 2, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, 2, D))
    ref = flash_attention_ref(q, k, v, causal=causal)
    qf = q.transpose(0, 2, 1, 3).reshape(4, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(4, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(4, S, D)
    out = flash_attention(qf, kf, vf, causal=causal, bq=128, bk=128)
    out = out.reshape(2, 2, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    S, D = 256, 128
    mk = lambda i: jax.random.normal(jax.random.fold_in(KEY, i),
                                     (2, S, D)).astype(jnp.bfloat16)
    q, k, v = mk(0), mk(1), mk(2)
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q[:, :, None].swapaxes(1, 2).swapaxes(1, 2).reshape(2, S, 1, D),
                              k.reshape(2, S, 1, D), v.reshape(2, S, 1, D))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.reshape(2, S, D), np.float32),
                               rtol=2e-2, atol=2e-2)
    assert out.dtype == jnp.bfloat16
