"""Hypothesis property tests for the round-adaptive PlanFamily
(DESIGN.md §10): for every participation count n the member payload fits
the effective budget B·M/n (or sits at the ladder floor), per-bucket
bit-widths are monotone in n, min_delta is non-increasing in n, and the
n = M member is exactly the static delta_budget plan."""
import pytest

from repro import comm
from repro.comm.planner import plan_comm, plan_family

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402


@st.composite
def _layout_and_budget(draw):
    n_leaves = draw(st.integers(1, 6))
    shapes = {f"l{i}": (draw(st.integers(1, 400)), draw(st.integers(1, 400)))
              for i in range(n_leaves)}
    M = draw(st.sampled_from([1, 2, 4, 8]))
    bucket_bytes = draw(st.sampled_from([1 << 14, 1 << 16, 1 << 18]))
    layout = comm.build_layout(shapes, None, n_workers=M,
                               bucket_bytes=bucket_bytes)
    full = plan_comm(layout, "qsgd8_linf", "uniform").payload_bytes
    frac = draw(st.floats(0.05, 1.5))
    return layout, M, max(1, int(full * frac))


@given(_layout_and_budget())
@settings(max_examples=40, deadline=None)
def test_family_invariants(case):
    """For every n: payload ≤ effective budget B·M/n (or the plan sits at
    the ladder floor), per-bucket bit-widths monotone non-decreasing as n
    drops, min_delta non-increasing in n."""
    layout, M, budget = case
    fam = plan_family(layout, "qsgd8_linf", budget, M)
    assert len(fam.plans) == M
    bits = fam.bits_table()
    floor_bits = 2  # qsgd2 floor of the linf quant ladder
    for n in range(1, M + 1):
        p = fam.plan_for(n)
        at_floor = all(b == floor_bits for b in bits[n - 1])
        assert p.payload_bytes <= fam.effective_budget(n) or at_floor, \
            (n, p.payload_bytes, fam.effective_budget(n))
    for bid in range(len(layout.buckets)):
        col = [bits[n][bid] for n in range(M)]  # n increasing
        assert all(a >= b for a, b in zip(col, col[1:])), (bid, col)
    deltas = [fam.plan_for(n).min_delta for n in range(1, M + 1)]
    assert all(a >= b - 1e-12 for a, b in zip(deltas, deltas[1:])), deltas


@given(_layout_and_budget())
@settings(max_examples=25, deadline=None)
def test_family_full_member_is_the_static_plan(case):
    """The n = M member IS plan_comm's static delta_budget plan — the
    bit-exactness anchor for full-participation adaptive training."""
    layout, M, budget = case
    fam = plan_family(layout, "qsgd8_linf", budget, M)
    static = plan_comm(layout, "qsgd8_linf", "delta_budget",
                       budget_bytes=budget)
    assert fam.full.assignments == static.assignments
