"""MoE routing/dispatch correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
from repro.models import moe as moe_lib

KEY = jax.random.key(0)


def _cfg(capacity_factor=8.0, top_k=2, dense=0):
    cfg = cfgs.get("qwen3-moe-30b-a3b").reduced()
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                top_k=top_k, dense_residual_d_ff=dense),
    )


def _dense_reference(p, cfg, x):
    """Compute the MoE output exactly: every token through its top-k experts
    (no capacity drops), via explicit per-expert full computation."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    act = jax.nn.silu
    # all experts for all tokens (reference only; exponentially wasteful)
    h = act(jnp.einsum("td,edf->tef", xt, p["gate_proj"])) * jnp.einsum(
        "td,edf->tef", xt, p["up_proj"])
    out_all = jnp.einsum("tef,efd->ted", h, p["down_proj"])
    onehot = jax.nn.one_hot(idx, m.num_experts)          # (T, k, E)
    w = jnp.einsum("tk,tke->te", gates, onehot)
    return jnp.einsum("te,ted->td", w, out_all).reshape(B, S, d)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = _cfg(capacity_factor=16.0)
    p = moe_lib.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = moe_lib.moe_apply(p, cfg, x)
    ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux["moe_load_balance"]) > 0
    assert float(aux["moe_router_z"]) >= 0


def test_moe_capacity_drops_tokens_gracefully():
    cfg = _cfg(capacity_factor=0.25)
    p = moe_lib.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y, _ = moe_lib.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens contribute zero, so norm must be below ample-capacity run
    y_full, _ = moe_lib.moe_apply(p, _cfg(capacity_factor=16.0), x)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-4


def test_arctic_dense_residual_contributes():
    cfg = _cfg(dense=64)
    p = moe_lib.moe_init(KEY, cfg, jnp.float32)
    assert "dense" in p
    x = jax.random.normal(KEY, (1, 8, cfg.d_model))
    y, _ = moe_lib.moe_apply(p, cfg, x)
    p_no = {k: v for k, v in p.items() if k != "dense"}
    y_no, _ = moe_lib.moe_apply(p_no, cfg, x)
    assert float(jnp.linalg.norm(y - y_no)) > 1e-3


def test_load_balance_loss_prefers_uniform_routing():
    cfg = _cfg()
    m = cfg.moe
    E = m.num_experts
    T = 1024
    # uniform routing stats
    me_u = jnp.full((E,), 1.0 / E)
    lb_uniform = E * float(jnp.sum(me_u * me_u)) * m.router_aux_coef
    # collapsed routing (everything to expert 0)
    me_c = jnp.zeros((E,)).at[0].set(1.0)
    lb_collapsed = E * float(jnp.sum(me_c * me_c)) * m.router_aux_coef
    assert lb_collapsed > lb_uniform


def test_per_row_dispatch_matches_global():
    """Hillclimb-1 variant (per-row capacity, no cross-device cumsum) is
    numerically identical when capacity is ample."""
    cfg_g = _cfg(capacity_factor=16.0)
    cfg_r = dataclasses.replace(
        cfg_g, moe=dataclasses.replace(cfg_g.moe, dispatch="per_row"))
    p = moe_lib.moe_init(KEY, cfg_g, jnp.float32)
    x = jax.random.normal(KEY, (3, 16, cfg_g.d_model))
    yg, _ = moe_lib.moe_apply(p, cfg_g, x)
    yr, _ = moe_lib.moe_apply(p, cfg_r, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
