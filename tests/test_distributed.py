"""Multi-device tests (8 forced host devices, run in subprocesses because
jax pins the device count at first init — see conftest.run_multidevice)."""
import pytest

pytestmark = pytest.mark.multidevice


DELTA1_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh, shard_map
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    return {"x": A @ y, "y": -(A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((2,2,2), ("pod","data","model"))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
pspecs = {"x": P(), "y": P()}
batch = jnp.zeros((8,1))

def run(exchange, compressor):
    dq = DQConfig(optimizer="omd", compressor=compressor, exchange=exchange,
                  lr=0.05, worker_axes=("pod","data"))
    tr = DQGAN(field_fn=field, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("pod","data")))
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step)
        for i in range(25):
            st = step(st, batch, jax.random.key(7)).state
        return jax.device_get(st.params)

exact = run("exact", "identity")
sim_id = run("sim", "identity")
np.testing.assert_array_equal(exact["x"], sim_id["x"])   # delta=1 bit-exact
np.testing.assert_array_equal(exact["y"], sim_id["y"])

# quantized strategies all converge toward the saddle and stay close to exact
for exch in ("sim", "allgather", "two_phase"):
    q = run(exch, "qsgd8_linf")
    d = float(np.linalg.norm(q["x"] - exact["x"]) + np.linalg.norm(q["y"] - exact["y"]))
    assert d < 0.5, (exch, d)
print("OK")
"""


def test_delta1_equivalence_and_strategies(multidevice):
    out = multidevice(DELTA1_SCRIPT)
    assert "OK" in out


EXCHANGE_SEMANTICS_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh, shard_map
from repro.core import compressors as C
from repro.core import exchange as X

mesh = make_mesh((8,), ("data",))
W = 8
comp = C.get("qsgd8_linf")
shape = (16, 32)
key = jax.random.key(0)
ps = jax.random.normal(key, (W,) + shape)  # per-worker messages

# reference: mean over workers of each worker's dequantized message with the
# SAME per-worker fold_in(key_leaf, widx) keys the exchange uses internally.
def ref_mean(strategy):
    outs = []
    for w in range(W):
        k = jax.random.fold_in(jax.random.fold_in(key, w), 0)
        outs.append(comp.roundtrip(ps[w], k))
    return jnp.mean(jnp.stack(outs), 0)

def worker(p, key):
    widx = jax.lax.axis_index(("data",))
    kw = jax.random.fold_in(jax.random.fold_in(key, widx), 0)
    plan = X.plan_leaf("allgather", shape, P(), W)
    q, _ = X.exchange_leaf(comp, plan, p[0], {"e1": jnp.zeros(shape)}, kw,
                           ("data",), W, True)
    return q[None]

f = shard_map(worker, mesh=mesh, in_specs=(P("data"), P()),
              out_specs=P("data"), axis_names=("data",))
with set_mesh(mesh):
    q = f(ps, key)
np.testing.assert_allclose(np.asarray(q[0]), np.asarray(ref_mean("allgather")),
                           rtol=1e-5, atol=1e-5)
for w in range(1, W):  # every worker received the same q-hat
    np.testing.assert_allclose(np.asarray(q[w]), np.asarray(q[0]), atol=1e-6)

# two_phase: phase-2 requantization error must be bounded by the quantizer's
# per-chunk resolution; and with the identity compressor it's exact psum-mean.
plan2 = X.plan_leaf("two_phase", shape, P(), W)
assert plan2["strategy"] == "two_phase" and plan2["chunk_axis"] == 1

def worker2(p, key):
    widx = jax.lax.axis_index(("data",))
    kw = jax.random.fold_in(key, widx)
    st = X.ef_state_zeros(plan2, shape, jnp.float32, W, True)
    q, _ = X.exchange_leaf(C.get("identity"), plan2, p[0], st, kw,
                           ("data",), W, True)
    return q[None]

f2 = shard_map(worker2, mesh=mesh, in_specs=(P("data"), P()),
               out_specs=P("data"), axis_names=("data",))
with set_mesh(mesh):
    q2 = f2(ps, key)
np.testing.assert_allclose(np.asarray(q2[0]), np.asarray(jnp.mean(ps, 0)),
                           rtol=1e-5, atol=1e-6)
print("OK")
"""


def test_exchange_semantics(multidevice):
    out = multidevice(EXCHANGE_SEMANTICS_SCRIPT)
    assert "OK" in out


SHARDED_TRAIN_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.compat import make_mesh, set_mesh
import repro.configs as cfgs
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models import build
from repro.parallel import sharding as shd
from repro.data import synthetic_lm_batch

# real (reduced) model trained data-parallel x tensor-parallel on 8 devices
mesh = make_mesh((2,2,2), ("pod","data","model"))
cfg = cfgs.get("gemma-2b").reduced()
bundle = build(cfg)
key = jax.random.key(0)
params = bundle.init(key, max_seq=64)
pspecs = shd.param_specs(params, cfg, "dp", mesh)
params = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                      params, pspecs, is_leaf=lambda x: hasattr(x, "shape"))
dq = DQConfig(optimizer="oadam", compressor="qsgd8_linf", exchange="two_phase",
              message="grad", lr=3e-3, worker_axes=("pod","data"))
tr = DQGAN(field_fn=bundle.field_fn, dq=dq, mesh=mesh, param_specs=pspecs,
           batch_spec=P(("pod","data")))
with set_mesh(mesh):
    st = tr.init(params)
    step = jax.jit(tr.step, donate_argnums=0)
    losses = []
    for i in range(20):
        batch = synthetic_lm_batch(jax.random.fold_in(key, i), 8, 32,
                                   cfg.vocab_size)
        out = step(st, batch, key)
        st = out.state
        losses.append(float(out.metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] - 0.3, losses  # actually learning
print("OK", losses[0], losses[-1])
"""


def test_sharded_model_training(multidevice):
    out = multidevice(SHARDED_TRAIN_SCRIPT)
    assert "OK" in out


FSDP_LOWER_SCRIPT = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh, shard_map
import repro.configs as cfgs
from repro.configs.base import DQConfig, InputShape
from repro.core.dqgan import DQGAN
from repro.launch import specs as S
from repro.models import build
from jax.sharding import NamedSharding

# mode B: FSDP over 'data' + TP over 'model', DQGAN workers = pods.
mesh = make_mesh((2,2,2), ("pod","data","model"))
cfg = cfgs.get("qwen3-moe-30b-a3b").reduced()
bundle = build(cfg)
with set_mesh(mesh):
    params_sds, pspecs = S.abstract_params(cfg, mesh, "fsdp", 8)
    # shard_map manual-over-pod + FSDP auto axes trips an XLA partitioner
    # CHECK (DESIGN.md §2) -> the vmap worker formulation is used instead.
    dq = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                  exchange="sim", spmd="vmap", worker_axes=("pod",))
    tr = DQGAN(field_fn=bundle.field_fn, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("pod",)))
    st = tr.init_abstract(params_sds)
    shape = InputShape("t", 32, 8, "train")
    batch = S.train_batch_specs(cfg, shape, mesh)
    compiled = jax.jit(tr.step).lower(st, batch, S.key_spec()).compile()
    txt = compiled.as_text()
    assert "all-reduce" in txt or "all-gather" in txt
    print("OK")
"""


def test_fsdp_moe_lowering(multidevice):
    out = multidevice(FSDP_LOWER_SCRIPT)
    assert "OK" in out
