"""repro.serve: paged KV cache, continuous-batching engine, weight quant.

The load-bearing equivalences:
  * paged flash attention == dense reference over ragged block tables;
  * the batching engine == N sequential generates, token for token
    (greedy, fixed seed), including requests joining and leaving
    mid-stream — with the decode step compiled exactly once;
  * quantized-weight decode within tolerance of f32 and bit-exact
    across engine restarts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.kernels.flash_attention import paged_flash_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import model as lm
from repro.serve import (
    BlockAllocator,
    Engine,
    Request,
    SequentialGenerator,
    ServeConfig,
    ServeError,
    floor_bucket,
    plan_request,
    required_tokens,
)
from repro.strategy.components import Compression

SCFG = ServeConfig(max_batch=4, block_size=8, num_blocks=64,
                   max_blocks_per_seq=8, prompt_buckets=(8, 16, 32))


def _params(arch="gemma-2b", seed=0):
    cfg = cfgs.get(arch).reduced()
    return cfg, lm.init(jax.random.key(seed), cfg, 0)


def _requests(cfg, n, rng, max_new=None):
    return [Request(rid=i,
                    prompt=list(rng.integers(1, cfg.vocab_size,
                                             int(rng.integers(3, 40)))),
                    max_new=int(max_new or rng.integers(1, 8)))
            for i in range(n)]


# --------------------------------------------------------------------------- #
# allocator + sizing
# --------------------------------------------------------------------------- #
def test_allocator_reuse_oom_double_free():
    a = BlockAllocator(8)                       # blocks 1..7 allocatable
    assert a.capacity == 7
    xs = a.alloc(7)
    assert sorted(xs) == list(range(1, 8)) and a.free_blocks == 0
    with pytest.raises(ServeError, match="out of KV blocks"):
        a.alloc(1)
    a.free(xs[:3])
    assert a.free_blocks == 3 and a.occupancy() == pytest.approx(4 / 7)
    ys = a.alloc(3)                             # recycled ids, no growth
    assert set(ys) <= set(xs[:3])
    a.free([xs[3]])
    with pytest.raises(ServeError, match="double free"):
        a.free([xs[3]])


def test_sizing_floor_bucket_and_validation():
    assert floor_bucket(5, SCFG) == 0           # shorter than every bucket
    assert floor_bucket(8, SCFG) == 8
    assert floor_bucket(31, SCFG) == 16
    assert floor_bucket(200, SCFG) == 32
    assert required_tokens(10, 1, SCFG) == 10   # token 0 is free
    assert required_tokens(10, 5, SCFG) == 14
    bucket, blocks = plan_request(20, 5, SCFG)
    assert (bucket, blocks) == (16, 3)          # 24 tokens / bs=8
    with pytest.raises(ServeError, match="max_context"):
        plan_request(32, 64, SCFG)              # 95 > 64 = 8*8
    with pytest.raises(ServeError, match="gen_steps"):
        required_tokens(10, 0, SCFG)
    with pytest.raises(ServeError, match="not a multiple"):
        ServeConfig(block_size=8, prompt_buckets=(12,))


# --------------------------------------------------------------------------- #
# paged attention kernel vs dense reference
# --------------------------------------------------------------------------- #
def test_paged_flash_matches_ref_on_ragged_tables():
    key = jax.random.key(0)
    B, Kh, G, D, NB, bs, MAXB = 3, 2, 2, 16, 12, 4, 5
    rng = np.random.default_rng(0)
    lengths = jnp.asarray([1, 7, 20], jnp.int32)   # ragged, incl. 1 block
    # random non-overlapping block assignment per row
    perm = rng.permutation(np.arange(1, NB))
    table = np.zeros((B, MAXB), np.int32)
    off = 0
    for b in range(B):
        nb = -(-int(lengths[b]) // bs)
        table[b, :nb] = perm[off:off + nb]
        off += nb
    q = jax.random.normal(key, (B, Kh, G, D))
    pool_k = jax.random.normal(jax.random.fold_in(key, 1), (NB, bs, Kh, D))
    pool_v = jax.random.normal(jax.random.fold_in(key, 2), (NB, bs, Kh, D))
    out = paged_flash_attention(q, pool_k, pool_v, jnp.asarray(table),
                                lengths)
    ref = paged_attention_ref(q, pool_k, pool_v, jnp.asarray(table), lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # a row with length 0 (empty decode-from-scratch slot) returns zeros
    out0 = paged_flash_attention(q, pool_k, pool_v, jnp.asarray(table),
                                 jnp.zeros((B,), jnp.int32))
    assert float(jnp.abs(out0).max()) == 0.0


# --------------------------------------------------------------------------- #
# engine == sequential, token for token
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-1.3b"])
def test_engine_matches_sequential_with_midstream_churn(arch):
    cfg, params = _params(arch)
    rng = np.random.default_rng(3)
    reqs = _requests(cfg, 7, rng)
    eng = Engine(cfg, SCFG, params)
    # staggered submits: a few up front, the rest joining mid-stream while
    # earlier requests are still decoding (and some have already left)
    for r in reqs[:3]:
        eng.submit(r)
    steps = 0
    for r in reqs[3:]:
        eng.step()
        steps += 1
        eng.submit(r)
    while not eng.idle:
        assert eng.step()
    out = eng.outputs

    seq = SequentialGenerator(cfg, SCFG, params)
    for r in reqs:
        assert seq.generate(list(r.prompt), r.max_new, rid=r.rid) \
            == out[r.rid], f"rid={r.rid} P={len(r.prompt)} G={r.max_new}"
    # the no-retrace contract: one decode compile across all churn
    assert len(eng.decode_traces) == 1
    assert len(seq.decode_traces) == 1
    # all blocks returned once everyone left
    assert eng.alloc.used_blocks == 0


def test_engine_slot_recycling_under_pressure():
    cfg, params = _params()
    scfg = ServeConfig(max_batch=2, block_size=8, num_blocks=10,
                       max_blocks_per_seq=4, prompt_buckets=(8, 16))
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(1, cfg.vocab_size,
                                             int(rng.integers(3, 20)))),
                    max_new=4)
            for i in range(8)]                   # 8 requests through 2 slots
    eng = Engine(cfg, scfg, params)
    out = eng.run(reqs)
    assert all(len(out[r.rid]) == 4 for r in reqs)
    assert len(eng.decode_traces) == 1
    assert eng.alloc.used_blocks == 0 and eng.peak_occupancy > 0
    seq = SequentialGenerator(cfg, scfg, params)
    for r in reqs[:3]:
        assert seq.generate(list(r.prompt), r.max_new, rid=r.rid) \
            == out[r.rid]


def test_engine_stop_token_and_sampled_equivalence():
    cfg, params = _params()
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(1, cfg.vocab_size, 12)),
                    max_new=6, temperature=0.8,
                    stop_token=int(rng.integers(1, cfg.vocab_size)))
            for i in range(4)]
    eng = Engine(cfg, SCFG, params, seed=11)
    out = eng.run(reqs)
    seq = SequentialGenerator(cfg, SCFG, params, seed=11)
    for r in reqs:
        ref = seq.generate(list(r.prompt), r.max_new, rid=r.rid,
                           temperature=r.temperature,
                           stop_token=r.stop_token)
        assert ref == out[r.rid]
        assert len(ref) <= r.max_new
        if len(ref) < r.max_new:
            assert ref[-1] == r.stop_token


def test_engine_request_validation():
    cfg, params = _params()
    eng = Engine(cfg, SCFG, params)
    with pytest.raises(ServeError, match="max_context"):
        eng.submit(Request(rid=0, prompt=list(range(1, 40)), max_new=60))
    with pytest.raises(ServeError, match="empty prompt"):
        eng.submit(Request(rid=1, prompt=[], max_new=4))
    eng.submit(Request(rid=2, prompt=[5, 6, 7], max_new=2))
    with pytest.raises(ServeError, match="duplicate"):
        eng.submit(Request(rid=2, prompt=[5], max_new=1))


# --------------------------------------------------------------------------- #
# quantized weights
# --------------------------------------------------------------------------- #
def test_quantized_weights_restart_bit_exact_and_close_to_f32():
    cfg, params = _params()
    comp = Compression(compressor="qsgd8_linf", bucket_mb=0.25)
    rng = np.random.default_rng(6)
    reqs = _requests(cfg, 3, rng, max_new=5)

    e1 = Engine(cfg, SCFG, params, compression=comp, seed=9)
    o1 = e1.run(reqs)
    e2 = Engine(cfg, SCFG, params, compression=comp, seed=9)
    o2 = e2.run(reqs)
    assert o1 == o2, "restart with same seed must decode bit-identically"
    # payloads themselves are bit-identical
    for a, b in zip(jax.tree.leaves(e1._weights), jax.tree.leaves(e2._weights)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "8b" in e1.stats()["weights"]
    assert e1.weight_meta.payload_bytes < e1.weight_meta.f32_bytes / 3

    # 8-bit logits stay close to f32 logits on a prefill
    from repro.serve import dequantize_weights
    deq = dequantize_weights(e1.weight_meta, e1._weights)
    toks = np.asarray([reqs[0].prompt[:8]], np.int32)
    lg_q, _ = lm.prefill(deq, cfg, jnp.asarray(toks))
    lg_f, _ = lm.prefill(params, cfg, jnp.asarray(toks))
    err = float(jnp.abs(lg_q - lg_f).max() / (jnp.abs(lg_f).max() + 1e-9))
    assert err < 0.15, f"8-bit weight logits drifted {err:.3f} from f32"


def test_quantized_weights_delta_budget_mixes_bitwidths():
    cfg, params = _params()
    from repro.serve import quantize_weights
    # budget between the all-2-bit floor (~0.5 MiB) and the all-8-bit
    # payload (~2.1 MiB) so the descent must stop partway: a real mix
    comp = Compression(compressor="qsgd8_linf", plan="delta_budget",
                       bucket_mb=0.0625, budget_mb=1.0)
    meta, _ = quantize_weights(params, comp)
    assert len(set(meta.bits)) >= 2, \
        f"budget plan should mix bit-widths, got {meta.bits}"
    with pytest.raises(ServeError, match="linf"):
        quantize_weights(params, Compression(compressor="qsgd8_l2"))


# --------------------------------------------------------------------------- #
# engine internals: pallas attention path + serve model determinism
# --------------------------------------------------------------------------- #
def test_engine_pallas_attn_path_matches_gather():
    cfg, params = _params()
    rng = np.random.default_rng(7)
    reqs = _requests(cfg, 3, rng, max_new=5)
    o_g = Engine(cfg, SCFG, params, attn_impl="gather").run(reqs)
    o_p = Engine(cfg, SCFG, params, attn_impl="pallas").run(reqs)
    assert o_g == o_p


def test_serve_model_rows_deterministic_and_gated():
    from benchmarks.run import check_sched_regression
    from benchmarks.serve_load import serve_model_rows

    a, b = serve_model_rows(), serve_model_rows()
    assert a == b, "model rows must be bit-identical across calls"
    assert all(r["latency_p99_s"] >= r["latency_p50_s"] for r in a)
    # higher offered load never lowers occupancy pressure in the model
    assert a[-1]["tokens_per_s"] > a[0]["tokens_per_s"]
    # the gate catches a modeled regression on the serve rows
    cur = {"serve": [dict(r) for r in a]}
    cur["serve"][0]["mean_step_s"] *= 1.5
    fails = check_sched_regression(cur, {"serve": a})
    assert fails and "serve" in fails[0]
    assert not check_sched_regression({"serve": a}, {"serve": b})
