"""Step profiler (repro.obs.profile, DESIGN.md §12.1): window semantics,
event schema, launcher integration, and the bit-exactness contract —
profiling on/off must not shift the compiled step by one op."""
import gzip
import json
import os
import time

import jax
import pytest

from repro import obs
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, gan_field_fn, mlp_gan_init
from repro.obs.profile import (
    DEFAULT_WINDOW,
    NullStepProfiler,
    StepProfiler,
    make_profiler,
)
from repro.strategy import Observability, Strategy, StrategyError

KEY = jax.random.key(0)
FIX = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")


# --------------------------------------------------------------------------- #
# window semantics
# --------------------------------------------------------------------------- #
def test_window_fills_and_closes():
    p = StepProfiler(window=3)
    assert p.active and not p.done
    for i in range(5):                    # 2 extra records are ignored
        p.record_step(10 + i, 1e-3, exchanged=(i % 2 == 0))
    assert p.done
    assert len(p.step_walls) == 3
    assert p.first_step == 10
    assert p.exchange_steps == 2          # steps 10, 12


def test_phase_accumulates_only_while_active():
    p = StepProfiler(window=1)
    with p.phase("data"):
        time.sleep(0.001)
    p.record_step(0, 1e-3)
    with p.phase("data"):                 # window closed: no-op context
        time.sleep(0.001)
    assert p.phase_s["data"][1] == 1
    assert p.phase_s["data"][0] > 0


def test_summary_payload():
    p = StepProfiler(window=4)
    for i, w in enumerate([3.0, 2e-3, 3e-3, 4e-3]):   # wall 0 = compile
        p.record_step(i, w)
    s = p.summary()
    assert s["step0"] == 0 and s["n_steps"] == 4
    assert s["step_s"]["min"] == 2e-3 and s["step_s"]["max"] == 3.0
    assert s["step_s"]["n"] == 4
    assert len(s["step_walls_s"]) == 4
    assert s["exchange_steps"] == 4
    assert "device_phases" not in s       # no HLO text given
    assert StepProfiler(window=2).summary() is None   # nothing recorded


def test_emit_is_idempotent_and_schema_valid(tmp_path):
    path = str(tmp_path / "prof.jsonl")
    sink = obs.JsonlFileSink(path, strategy_hash="abc")
    p = StepProfiler(window=2)
    p.record_step(0, 1e-3)
    p.record_step(1, 2e-3)
    ev = p.emit(sink)
    assert ev is not None and ev["kind"] == "profile" and ev["v"] == 2
    assert p.emit(sink) is None           # second emit: no-op
    sink.close()
    (read,) = obs.read_events(path)       # validates the schema
    assert read["n_steps"] == 2


def test_invalid_window():
    with pytest.raises(ValueError, match="window"):
        StepProfiler(window=0)


def test_make_profiler_factory():
    assert isinstance(make_profiler(False), NullStepProfiler)
    on = make_profiler(True)
    assert isinstance(on, StepProfiler) and on.window == DEFAULT_WINDOW
    assert make_profiler(True, window=7).window == 7


def test_null_profiler_surface(tmp_path):
    p = NullStepProfiler()
    with p.phase("step"):
        pass
    p.record_step(0, 1e-3)
    assert p.done and not p.active and p.step_walls == []
    assert p.summary() is None
    assert p.emit(obs.NullSink()) is None
    assert p.device_phase_costs("anything") == {}


def test_device_phase_costs_from_fixture():
    """The committed optimized-HLO fixture carries the repro.obs scope
    metadata — the profiler's device-phase attribution reads it."""
    with gzip.open(os.path.join(FIX, "mix_every_step_8dev.hlo.txt.gz"),
                   "rt") as fh:
        txt = fh.read()
    dev = StepProfiler(window=1).device_phase_costs(txt)
    assert "exchange" in dev and dev["exchange"]["ops"] > 0
    assert dev["exchange"]["bytes"] > 0
    from repro.obs.tracing import DEVICE_PHASES
    assert set(dev) <= set(DEVICE_PHASES)


# --------------------------------------------------------------------------- #
# strategy surface
# --------------------------------------------------------------------------- #
def test_observability_profile_field_validated():
    assert Observability(profile=True).profile is True
    with pytest.raises(StrategyError, match="profile"):
        Observability(profile="yes")


def test_profile_outside_structural_identity():
    base = Strategy()
    prof = Strategy(observability=Observability(profile=True))
    assert prof.short_hash() == base.short_hash()
    assert "obs_profile" in base.legacy_fields()


def test_obs_profile_cli_flag():
    import argparse

    from repro import strategy as strategy_api
    ap = argparse.ArgumentParser()
    strategy_api.add_strategy_args(ap)
    args = ap.parse_args(["--obs-profile"])
    strat = strategy_api.strategy_from_args(args)
    assert strat.observability.profile is True


# --------------------------------------------------------------------------- #
# bit-exactness: profiling cannot touch the compiled step
# --------------------------------------------------------------------------- #
def test_profile_on_hlo_identical():
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    texts = []
    for profile in (False, True):
        dq = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                      exchange="sim", error_feedback=True, lr=1e-2,
                      worker_axes=(), comm_plan="uniform", bucket_mb=0.03,
                      obs_profile=profile)
        tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq)
        st = tr.init(mlp_gan_init(KEY, cfg))
        batch = {"real": jax.random.normal(KEY, (64, 2))}
        texts.append(jax.jit(tr.step).lower(st, batch, KEY).as_text())
    assert texts[0] == texts[1]


PROFILE_HLO_8DEV_SCRIPT = r"""
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.strategy import (Compression, ExchangePlan, Observability,
                            Schedule, Strategy)

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)
batch = {"real": jax.random.normal(key, (64, 2))}

def lower(spmd, profile):
    strat = Strategy(
        compression=(Compression(plan="uniform", bucket_mb=0.03)
                     if spmd == "shard_map" else Compression()),
        exchange=ExchangePlan(
            kind="two_phase" if spmd == "shard_map" else "sim",
            spmd=spmd, worker_axes=("data",)),
        observability=Observability(profile=profile))
    dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
    tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
               batch_spec=P(("data",)))
    with set_mesh(mesh):
        st = tr.init(params)
        return jax.jit(tr.step, static_argnums=(3,)).lower(
            st, batch, key, True).as_text()

for spmd in ("shard_map", "vmap"):
    assert lower(spmd, False) == lower(spmd, True), spmd
print("OK")
"""


@pytest.mark.multidevice
def test_profile_on_hlo_identical_8dev(multidevice):
    """Profiling is host-side only: the lowered step is byte-identical
    with profile on/off — 8 workers, both SPMD paths."""
    assert "OK" in multidevice(PROFILE_HLO_8DEV_SCRIPT)


# --------------------------------------------------------------------------- #
# launcher integration
# --------------------------------------------------------------------------- #
def test_train_launcher_emits_profile_event(tmp_path):
    from repro.launch import train

    path = str(tmp_path / "run.jsonl")
    hist = train.main(["--arch", "dcgan32", "--smoke", "--steps", "6",
                       "--log-every", "3", "--obs-sink", path,
                       "--profile-steps", "4", "--obs-spans"])
    assert hist
    evs = obs.read_events(path)
    (prof,) = [e for e in evs if e["kind"] == "profile"]
    assert prof["step0"] == 0 and prof["n_steps"] == 4
    assert prof["exchange_steps"] == 4          # every_step schedule
    assert prof["step_s"]["min"] > 0
    assert {"data", "step"} <= set(prof["host_phases"])
    # single-device sim path still lowers named scopes -> device phases
    assert prof.get("device_phases"), prof.keys()
    # the calibrate CLI consumes this file end-to-end
    from repro.obs import calibrate
    assert calibrate.main([path]) == 0


def test_train_launcher_obs_profile_flag_defaults_window(tmp_path):
    from repro.launch import train

    path = str(tmp_path / "run.jsonl")
    train.main(["--arch", "dcgan32", "--smoke", "--steps", "4",
                "--log-every", "2", "--obs-sink", path, "--obs-profile"])
    (prof,) = [e for e in read_profile(path)]
    # 4 steps < DEFAULT_WINDOW: the window never fills; the launcher
    # still emits the partial window at the end of the run
    assert prof["n_steps"] == 4


def read_profile(path):
    return [e for e in obs.read_events(path) if e["kind"] == "profile"]
