"""Wall-clock calibration round-trip (repro.obs.calibrate, DESIGN.md §12.3).

The core property: events synthesized from a KNOWN LinkModel + compute
constant through the forward cost model must fit back to exactly those
constants, with modeled-vs-measured drift ≈ 0. Plus the degradation
ladder (single-run fallbacks), the CLI (incl. the drift gate exit code)
and the sched.clock load hook."""
import json

import pytest

from repro.obs import calibrate as cal
from repro.sched.clock import LinkModel, load_calibration
from repro.strategy import Schedule, Strategy

T_C = 2e-3
LINK = LinkModel(bandwidth_Bps=2e9, latency_s=5e-4)
W = 8
STEPS = 64
N_WALLS = 16


def _strategy(schedule) -> dict:
    return Strategy(schedule=schedule).to_dict()


def _run_events(schedule, wire_bytes, mean_step_s, walls=None):
    """One synthetic run: run_meta + a full profile window + the comm
    summary — the minimum calibrate consumes."""
    walls = walls if walls is not None else [mean_step_s] * N_WALLS
    ordered = sorted(walls)
    return [
        {"v": 2, "kind": "run_meta", "steps": STEPS, "n_workers": W,
         "arch": "syn", "strategy_json": _strategy(schedule)},
        {"v": 2, "kind": "profile", "step0": 0, "n_steps": len(walls),
         "exchange_steps": len(walls),
         "step_s": {"mean": sum(walls) / len(walls), "min": ordered[0],
                    "max": ordered[-1], "p50": ordered[len(walls) // 2],
                    "n": len(walls)},
         "step_walls_s": walls},
        {"v": 2, "kind": "comm_summary",
         "wire_bytes_per_step": wire_bytes},
    ]


def _forward(schedule, wire_bytes):
    """Measured mean step under the TRUE constants (the linear model the
    fit inverts)."""
    t_ex = LINK.exchange_time(wire_bytes)
    return T_C + t_ex / schedule.runtime().period


B1, B2 = 1e6, 4e6
RUNS = [
    (Schedule(), B1),
    (Schedule.local_k(4), B1),
    (Schedule(), B2),
]


def _events():
    evs = []
    for schedule, bytes_ in RUNS:
        evs += _run_events(schedule, bytes_, _forward(schedule, bytes_))
    return evs


# --------------------------------------------------------------------------- #
def test_extract_runs():
    runs = cal.extract_runs(_events())
    assert len(runs) == len(RUNS)
    assert [r.wire_bytes for r in runs] == [B1, B1, B2]
    assert all(r.source == "profile" and r.n_samples == N_WALLS
               for r in runs)
    assert runs[0].measured_step_s == pytest.approx(
        _forward(Schedule(), B1))


def test_extract_runs_timing_fallback():
    evs = [e for e in _run_events(Schedule(), B1, 3e-3)
           if e["kind"] != "profile"]
    evs.insert(1, {"v": 2, "kind": "timing", "step": 0, "step_s": 3e-3,
                   "interval_s": 3e-3})
    (run,) = cal.extract_runs(evs)
    assert run.source == "timing"
    assert run.measured_step_s == pytest.approx(3e-3)


def test_trimmed_mean_drops_compile_step():
    # one 3s compile wall in a 2ms window must not poison the fit
    walls = [3.0] + [2e-3] * 15
    assert cal._trimmed_mean(walls) == pytest.approx(2e-3)


# --------------------------------------------------------------------------- #
# the round trip: known constants -> events -> fit -> same constants
# --------------------------------------------------------------------------- #
def test_fit_recovers_known_constants():
    runs = cal.extract_runs(_events())
    constants = cal.fit(runs)
    assert constants["method"] == "lstsq3"
    assert constants["n_fit_runs"] == 3
    assert constants["t_compute_s"] == pytest.approx(T_C, rel=1e-6)
    assert constants["latency_s"] == pytest.approx(LINK.latency_s,
                                                   rel=1e-6)
    assert constants["bandwidth_Bps"] == pytest.approx(
        LINK.bandwidth_Bps, rel=1e-6)


def test_calibrate_drift_vanishes():
    out = cal.calibrate(cal.extract_runs(_events()))
    assert out["kind"] == "calibration" and out["v"] == 2
    assert out["max_abs_drift"] == pytest.approx(0.0, abs=1e-4)
    assert len(out["runs"]) == 3
    for row in out["runs"]:
        assert row["modeled_step_s"] == pytest.approx(
            row["measured_step_s"], rel=1e-3)


def test_delayed_run_joins_drift_not_fit():
    """delayed overlaps comm under compute (nonlinear) — excluded from
    the least squares, still evaluated for drift through the full
    simulate."""
    evs = _events()
    delayed = Schedule.delayed(tau=2)
    probe = cal.extract_runs(_run_events(delayed, B1, 1.0))[0]
    measured = cal.modeled_step_s(probe, T_C, LINK)  # forward model
    evs += _run_events(delayed, B1, measured)
    out = cal.calibrate(cal.extract_runs(evs))
    assert out["n_fit_runs"] == 3 and out["n_runs"] == 4
    assert out["max_abs_drift"] == pytest.approx(0.0, abs=1e-4)
    assert any(r["schedule"].startswith("delayed") for r in out["runs"])


def test_fit_needs_a_linear_run():
    evs = _run_events(Schedule.delayed(tau=2), B1, 3e-3)
    with pytest.raises(ValueError, match="linear"):
        cal.fit(cal.extract_runs(evs))


def test_single_run_residual_fallback():
    evs = _run_events(Schedule(), B1, _forward(Schedule(), B1))
    out = cal.calibrate(cal.extract_runs(evs))
    assert out["method"].startswith("residual")
    assert out["t_compute_s"] > 0
    assert out["bandwidth_Bps"] > 0


# --------------------------------------------------------------------------- #
# CLI + the sched.clock load hook
# --------------------------------------------------------------------------- #
def test_cli_roundtrip_and_clock_load(tmp_path, capsys):
    src = tmp_path / "runs.jsonl"
    src.write_text("".join(json.dumps(e) + "\n" for e in _events()))
    out_json = tmp_path / "calibration.json"
    rc = cal.main([str(src), "--out", str(out_json), "--max-drift", "0.05"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "calibrated constants" in text and "drift" in text
    link, payload = load_calibration(str(out_json))
    assert isinstance(link, LinkModel)
    assert link.bandwidth_Bps == pytest.approx(LINK.bandwidth_Bps,
                                               rel=1e-6)
    assert link.latency_s == pytest.approx(LINK.latency_s, rel=1e-6)
    assert payload["kind"] == "calibration"
    assert payload["t_compute_s"] == pytest.approx(T_C, rel=1e-6)


def test_cli_drift_gate_fails(tmp_path, capsys):
    # single W=1 run whose mean sits far above its floor: the residual
    # fallback models the floor, the gate sees the gap
    walls = [1e-3] + [3e-3] * 15
    evs = _run_events(Schedule(), 0.0, 0.0, walls=walls)
    evs[0]["n_workers"] = 1
    src = tmp_path / "run.jsonl"
    src.write_text("".join(json.dumps(e) + "\n" for e in evs))
    assert cal.main([str(src), "--max-drift", "0.5"]) == 3
    assert "DRIFT GATE FAILED" in capsys.readouterr().out
    # report-only mode keeps exit 0 on the same input
    assert cal.main([str(src)]) == 0


def test_cli_empty_input(tmp_path):
    src = tmp_path / "empty.jsonl"
    src.write_text("")
    assert cal.main([str(src)]) == 2


def test_linkmodel_from_dict():
    d = {"bandwidth_Bps": 3e9, "latency_s": 2e-4, "extra": "ignored"}
    lm = LinkModel.from_dict(d)
    assert lm == LinkModel(bandwidth_Bps=3e9, latency_s=2e-4)


# --------------------------------------------------------------------------- #
# report integration: measured-vs-modeled section
# --------------------------------------------------------------------------- #
def test_report_gains_calibration_section():
    from repro.obs.report import render, summarize
    s = summarize(_events())
    assert "calibration" in s
    assert s["profile"]["n_steps"] == N_WALLS
    text = render(s)
    assert "calibrated constants" in text
    assert "profile window" in text
