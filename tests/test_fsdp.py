"""Compressed-gradient FSDP (DESIGN.md §15): strategy-lattice validation,
shard-aware bucket layouts, the fsdp == replicated-DDP equivalence on 1
and 8 devices (GAN and transformer configs), single-trace compiled
steps, the reduce-scatter/all-gather HLO structure check, and the
skipped-leaf ledger accounting the train-log warning surfaces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import buckets as B
from repro.comm.ledger import CommLedger
from repro.comm.planner import plan_comm
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.strategy import (
    Compression,
    ExchangePlan,
    MomentCompression,
    Participation,
    Strategy,
    StrategyError,
    get_preset,
)

KEY = jax.random.key(0)


# --------------------------------------------------------------------------- #
# strategy lattice: presets + every invalid fsdp combination is a
# StrategyError naming the offending field
# --------------------------------------------------------------------------- #
def test_fsdp_presets():
    z2, z3 = get_preset("fsdp_zero2"), get_preset("fsdp_zero3")
    assert z2.exchange.fsdp and z2.exchange.zero_stage == 2
    assert z3.exchange.fsdp and z3.exchange.zero_stage == 3
    assert z3.moments.compressor == "qsgd8_linf"
    assert Strategy.from_json(z3.to_json()) == z3


@pytest.mark.parametrize("make,field", [
    # satellite: partial participation composes with replicated exchange
    # only — masked reduce-scatter would mis-average every shard
    (lambda: Strategy(
        compression=Compression(plan="uniform"),
        exchange=ExchangePlan(kind="two_phase", parallelism="fsdp"),
        participation=Participation(fraction=0.5)),
     "participation.fraction"),
    (lambda: ExchangePlan(kind="sim", parallelism="fsdp"), "exchange.kind"),
    (lambda: ExchangePlan(kind="sim", spmd="vmap", parallelism="fsdp"),
     "exchange.parallelism"),
    (lambda: ExchangePlan(kind="two_phase", parallelism="fsdp",
                          zero_stage=1), "exchange.zero_stage"),
    (lambda: ExchangePlan(kind="two_phase", parallelism="fsdp",
                          fsdp_axis="model", worker_axes=("data",)),
     "exchange.fsdp_axis"),
    # fsdp shards flat buckets; the bucketing pipeline is mandatory
    (lambda: Strategy(
        exchange=ExchangePlan(kind="two_phase", parallelism="fsdp")),
     "compression.plan"),
    # a moments component without fsdp would be silently ignored
    (lambda: Strategy(moments=MomentCompression(compressor="qsgd8_linf")),
     "moments.compressor"),
])
def test_invalid_fsdp_combinations_raise(make, field):
    with pytest.raises(StrategyError, match=field.replace(".", r"\.")):
        make()


# --------------------------------------------------------------------------- #
# shard-aware bucket layouts (comm.buckets, DESIGN.md §15.1)
# --------------------------------------------------------------------------- #
def test_layout_buckets_data_sharded_leaf_at_local_shape():
    shapes = {"w": (16, 4), "b": (4,)}
    specs = {"w": P("data"), "b": P()}
    lay = B.build_layout(shapes, specs, n_workers=4,
                         shard_axes=("data",), axis_sizes={"data": 4})
    assert not lay.skipped
    slots = {s.path: s for b in lay.buckets for s in b.slots}
    w = next(s for p, s in slots.items() if "w" in p)
    assert w.local and w.shape == (4, 4)        # 16/4 rows per owner
    b_ = next(s for p, s in slots.items() if "b" in p)
    assert not b_.local and b_.shape == (4,)


def test_layout_skips_leaf_sharded_outside_shard_axes():
    shapes = {"w": (16, 4)}
    lay = B.build_layout(shapes, {"w": P("model")}, n_workers=4,
                         shard_axes=("data",),
                         axis_sizes={"data": 4, "model": 2})
    assert len(lay.skipped) == 1 and not lay.buckets


def test_layout_treats_size1_axis_sharding_as_replication():
    # a degenerate model_n=1 mesh leaves P("model") specs on leaves;
    # "sharding" over a size-1 axis is replication and must not skip
    shapes = {"w": (16, 4)}
    lay = B.build_layout(shapes, {"w": P("model")}, n_workers=4,
                         axis_sizes={"data": 4, "model": 1})
    assert not lay.skipped and lay.buckets
    # without axis_sizes the spec is (conservatively) a real shard
    lay2 = B.build_layout(shapes, {"w": P("model")}, n_workers=4)
    assert len(lay2.skipped) == 1


# --------------------------------------------------------------------------- #
# skipped-leaf accounting (the train-log warning's data source)
# --------------------------------------------------------------------------- #
def test_ledger_skipped_leaf_summary():
    shapes = {"w": (16, 4), "t": (8, 8)}
    specs = {"w": P("model"), "t": P()}
    lay = B.build_layout(shapes, specs, n_workers=4)
    plan = plan_comm(lay, "qsgd8_linf", "uniform")
    led = CommLedger.from_plan(lay, plan, "two_phase", 4, "qsgd8_linf")
    n, byts = led.skipped_leaves()
    assert n == 1 and byts > 0
    s = led.summary()
    assert s["skipped_leaves"] == 1
    assert s["skipped_leaf_bytes_per_step"] == round(byts)
    # nothing skipped -> the keys stay absent (no noise in clean runs)
    lay2 = B.build_layout({"t": (8, 8)}, {"t": P()}, n_workers=4)
    led2 = CommLedger.from_plan(lay2, plan_comm(lay2, "qsgd8_linf", "uniform"),
                                "two_phase", 4, "qsgd8_linf")
    assert led2.skipped_leaves() == (0, 0)
    assert "skipped_leaves" not in led2.summary()


# --------------------------------------------------------------------------- #
# single-device (W=1) fsdp == replicated DDP, GAN + quadratic configs
# --------------------------------------------------------------------------- #
_A = jnp.array(np.random.RandomState(0).randn(8, 8), jnp.float32)


def _bilinear_field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return ({"x": s * (_A @ y), "y": -s * (_A.T @ x)},
            {"loss": x @ _A @ y})


def _replicated(kind="exact"):
    return Strategy(
        compression=Compression(compressor="identity", error_feedback=False,
                                plan="uniform"),
        exchange=ExchangePlan(kind=kind))


def _fsdp(zero_stage, kind="exact"):
    return Strategy(
        compression=Compression(compressor="identity", error_feedback=False,
                                plan="uniform"),
        exchange=ExchangePlan(kind=kind, parallelism="fsdp",
                              zero_stage=zero_stage),
        moments=MomentCompression(compressor="identity",
                                  error_feedback=False))


def _train(st, field, params, batch, opt, steps=5):
    dq = DQConfig.from_strategy(st, optimizer=opt, lr=0.05)
    tr = DQGAN(field_fn=field, dq=dq)
    sched = tr.strategy.schedule.runtime()
    state = tr.init(params)
    step = jax.jit(tr.step, static_argnums=(3,))
    for i in range(steps):
        state = step(state, batch, KEY, sched.is_exchange_step(i)).state
    return jax.device_get(state.params)


@pytest.mark.parametrize("zero_stage", [2, 3])
@pytest.mark.parametrize("opt", ["adam", "oadam", "sgd"])
def test_fsdp_matches_replicated_1dev(zero_stage, opt):
    params = {"x": jnp.ones(8), "y": jnp.ones(8)}
    batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0
    a = _train(_replicated(), _bilinear_field, params, batch, opt)
    b = _train(_fsdp(zero_stage), _bilinear_field, params, batch, opt)
    for k in "xy":
        np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-6)


def test_fsdp_matches_replicated_1dev_gan():
    from repro.models import gan
    cfg = gan.GANConfig(image_size=0, data_dim=2, hidden=16, latent_dim=8)
    params = gan.init(KEY, cfg)
    field = gan.gan_field_fn(cfg)
    batch = {"real": jax.random.normal(KEY, (16, 2))}
    a = _train(_replicated(), field, params, batch, "oadam", steps=4)
    b = _train(_fsdp(3), field, params, batch, "oadam", steps=4)
    for ka, kb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(ka, kb, rtol=1e-5, atol=1e-6)


def test_fsdp_state_slots():
    params = {"x": jnp.ones(8), "y": jnp.ones(8)}
    dq = DQConfig.from_strategy(_fsdp(3), optimizer="adam", lr=0.05)
    tr = DQGAN(field_fn=_bilinear_field, dq=dq)
    st = tr.init(params)
    assert st.m is None and st.v is None          # moments live sharded
    assert set(st.fsdp) == {"0"}                  # one flat bucket
    slot = st.fsdp["0"]
    assert set(slot) == {"m", "v", "w", "age"}    # zero3 carries params
    dq2 = DQConfig.from_strategy(_fsdp(2), optimizer="adam", lr=0.05)
    st2 = DQGAN(field_fn=_bilinear_field, dq=dq2).init(params)
    assert set(st2.fsdp["0"]) == {"m", "v", "age"}


# --------------------------------------------------------------------------- #
# 8-device: equivalence, trace count, HLO structure, skipped-leaf error
# --------------------------------------------------------------------------- #
FSDP_EQUIV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.core import exchange as X
from repro.obs.hlo import assert_fsdp_structure, check_fsdp_structure
from repro.strategy import (Strategy, Compression, ExchangePlan,
                            MomentCompression)

A = jnp.array(np.random.RandomState(0).randn(64, 64), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(64), "y": jnp.ones(64)}
batch = jnp.arange(16, dtype=jnp.float32).reshape(16, 1) / 16.0
traces = [0]

def counting_field(params, batch, rng):
    traces[0] += 1
    return field(params, batch, rng)

def run(st, steps=6, opt="adam", f=field, hlo=False):
    dq = DQConfig.from_strategy(st, optimizer=opt, lr=0.05)
    tr = DQGAN(field_fn=f, dq=dq, mesh=mesh,
               param_specs={"x": P(), "y": P()}, batch_spec=P(("data",)))
    sched = tr.strategy.schedule.runtime()
    with set_mesh(mesh):
        state = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        txt = (step.lower(state, batch, jax.random.key(7), True)
               .compile().as_text() if hlo else None)
        for i in range(steps):
            state = step(state, batch, jax.random.key(7),
                         sched.is_exchange_step(i)).state
    return jax.device_get(state.params), txt

repl = Strategy(
    compression=Compression(compressor="identity", error_feedback=False,
                            plan="uniform"),
    exchange=ExchangePlan(kind="exact", worker_axes=("data",)))
for zs in (2, 3):
    fsdp = Strategy(
        compression=Compression(compressor="identity", error_feedback=False,
                                plan="uniform"),
        exchange=ExchangePlan(kind="exact", parallelism="fsdp", zero_stage=zs,
                              worker_axes=("data",)),
        moments=MomentCompression(compressor="identity",
                                  error_feedback=False))
    for opt in ("adam", "sgd"):
        a, _ = run(repl, opt=opt)
        b, _ = run(fsdp, opt=opt)
        for k in "xy":
            np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-6)
print("EQUIV-OK")

# compressed zero3: one trace across all rounds + the ZeRO wire shape
fsdp_q = Strategy(
    compression=Compression(plan="uniform"),
    exchange=ExchangePlan(kind="two_phase", parallelism="fsdp", zero_stage=3,
                          worker_axes=("data",)),
    moments=MomentCompression(compressor="qsgd8_linf"))
traces[0] = 0
p, txt = run(fsdp_q, steps=6, f=counting_field, hlo=True)
assert all(np.isfinite(v).all() for v in p.values())
assert traces[0] == 1, f"compressed fsdp retraced: {traces[0]} traces"
print("TRACE-OK")
if X._HAS_MODERN_SHARD_MAP:
    assert_fsdp_structure(txt, compressed=True)
    print("HLO-MODERN-OK")
else:
    # legacy emulation lowers psum_scatter to all-reduce + slice; the
    # checker still parses the text (exercised, not asserted)
    check_fsdp_structure(txt, compressed=True)
    print("HLO-LEGACY-OK")

# a leaf sharded over a real (size>1) non-worker axis cannot enter a
# flat bucket -> init fails fast naming the leaf
mesh2 = make_mesh((4, 2), ("data", "model"))
dq = DQConfig.from_strategy(fsdp_q, optimizer="adam", lr=0.05)
tr = DQGAN(field_fn=field, dq=dq, mesh=mesh2,
           param_specs={"x": P("model"), "y": P()}, batch_spec=P(("data",)))
with set_mesh(mesh2):
    try:
        tr.init(params)
    except ValueError as e:
        assert "skipped leaf" in str(e), e
        print("SKIP-ERR-OK")
print("ALL-OK")
"""


@pytest.mark.multidevice
def test_fsdp_equivalence_8dev(multidevice):
    out = multidevice(FSDP_EQUIV_SCRIPT)
    for tag in ("EQUIV-OK", "TRACE-OK", "SKIP-ERR-OK", "ALL-OK"):
        assert tag in out, out


FSDP_GAN_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models import gan
from repro.strategy import (Strategy, Compression, ExchangePlan,
                            MomentCompression)

key = jax.random.key(0)
cfg = gan.GANConfig(image_size=0, data_dim=2, hidden=32, latent_dim=8)
params = gan.init(key, cfg)
field = gan.gan_field_fn(cfg)
mesh = make_mesh((8,), ("data",))
batch = {"real": jax.random.normal(key, (16, 2))}
pspecs = jax.tree.map(lambda x: P(), params)

def run(st, steps=4):
    dq = DQConfig.from_strategy(st, optimizer="oadam", lr=0.02)
    tr = DQGAN(field_fn=field, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("data",)))
    sched = tr.strategy.schedule.runtime()
    with set_mesh(mesh):
        state = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            state = step(state, batch, key, sched.is_exchange_step(i)).state
    return jax.device_get(state.params)

repl = Strategy(
    compression=Compression(compressor="identity", error_feedback=False,
                            plan="uniform"),
    exchange=ExchangePlan(kind="exact", worker_axes=("data",)))
a = run(repl)
for zs in (2, 3):
    fsdp = Strategy(
        compression=Compression(compressor="identity", error_feedback=False,
                                plan="uniform"),
        exchange=ExchangePlan(kind="exact", parallelism="fsdp", zero_stage=zs,
                              worker_axes=("data",)),
        moments=MomentCompression(compressor="identity",
                                  error_feedback=False))
    b = run(fsdp)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
print("OK")
"""


@pytest.mark.multidevice
def test_fsdp_gan_equivalence_8dev(multidevice):
    out = multidevice(FSDP_GAN_8DEV_SCRIPT)
    assert "OK" in out


FSDP_LM_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
import repro.configs as cfgs
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.data import synthetic_lm_batch
from repro.models import build
from repro.strategy import (Strategy, Compression, ExchangePlan,
                            MomentCompression)

key = jax.random.key(0)
cfg = cfgs.get("gemma-2b").reduced()
bundle = build(cfg)
params = bundle.init(key, max_seq=64)
pspecs = jax.tree.map(lambda x: P(), params)
mesh = make_mesh((8,), ("data",))
batch = synthetic_lm_batch(key, 8, 32, cfg.vocab_size)

def run(st, steps=3):
    dq = DQConfig.from_strategy(st, optimizer="adam", lr=1e-3)
    tr = DQGAN(field_fn=bundle.field_fn, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("data",)))
    sched = tr.strategy.schedule.runtime()
    with set_mesh(mesh):
        state = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            state = step(state, batch, key, sched.is_exchange_step(i)).state
    return jax.device_get(state.params)

repl = Strategy(
    compression=Compression(compressor="identity", error_feedback=False,
                            plan="uniform"),
    exchange=ExchangePlan(kind="exact", worker_axes=("data",)))
fsdp = Strategy(
    compression=Compression(compressor="identity", error_feedback=False,
                            plan="uniform"),
    exchange=ExchangePlan(kind="exact", parallelism="fsdp", zero_stage=3,
                          worker_axes=("data",)),
    moments=MomentCompression(compressor="identity", error_feedback=False))
a, b = run(repl), run(fsdp)
for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
print("OK")
"""


@pytest.mark.multidevice
def test_fsdp_transformer_equivalence_8dev(multidevice):
    out = multidevice(FSDP_LM_8DEV_SCRIPT)
    assert "OK" in out
