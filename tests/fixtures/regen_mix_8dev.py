"""Regenerate the committed mix-trainer HLO fixtures (test_obs_hlo.py).

Run after a deliberate change to the compiled step graph::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/fixtures/regen_mix_8dev.py

Writes the gzipped optimized (post-SPMD, per-device) HLO of the mix
trainer's jitted step — exchange variants for every_step / local_k(4) /
delayed(τ=4) plus the local_k mid-round variant and the split-phase
delayed(τ=4) ``exchange.overlap=True`` lowering — and the
mix_8dev_expected.json expectations the tests pin (collective
summaries, scope-phase op counts, ring-parameter count, ledger bytes,
and the overlap variant's schedule-structure verdict).
"""
import gzip
import json
import os

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.launch.hlo_analysis import scope_costs
from repro.models.gan import GANConfig, gan_field_fn, mlp_gan_init
from repro.obs import hlo as ohlo
from repro.parallel.compat import make_mesh, set_mesh
from repro.strategy import (
    Compression,
    ExchangePlan,
    Observability,
    Schedule,
    Strategy,
)

FIX = os.path.dirname(os.path.abspath(__file__))


def build(schedule, mesh, cfg, overlap=False):
    strat = Strategy(
        compression=Compression(plan="uniform", bucket_mb=0.03),
        exchange=ExchangePlan(kind="two_phase", spmd="shard_map",
                              worker_axes=("data",), overlap=overlap),
        schedule=schedule,
        observability=Observability(spans=True))
    dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
                 batch_spec=P(("data",)))


def main():
    assert jax.device_count() >= 8, \
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    mesh = make_mesh((8,), ("data",))
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    key = jax.random.key(0)
    params = mlp_gan_init(key, cfg)
    batch = {"real": jax.random.normal(key, (64, 2))}
    expected = {}

    def dump(fname, txt):
        with gzip.open(os.path.join(FIX, fname), "wt",
                       compresslevel=9) as fh:
            fh.write(txt)
        expected[fname] = {
            "collectives": ohlo.collective_summary(txt),
            "scope_phases": {k: v["ops"]
                             for k, v in scope_costs(txt).items()},
        }

    for name, schedule, overlap in [
            ("every_step", Schedule(), False),
            ("local_k4", Schedule.local_k(4), False),
            ("delayed_tau4", Schedule.delayed(tau=4), False),
            ("delayed_tau4_overlap", Schedule.delayed(tau=4), True)]:
        tr = build(schedule, mesh, cfg, overlap=overlap)
        with set_mesh(mesh):
            st = tr.init(params)
            step = jax.jit(tr.step, static_argnums=(3,))
            ex = ohlo.compiled_text(step, st, batch, jax.random.key(7),
                                    True)
            dump(f"mix_{name}_8dev.hlo.txt.gz", ex)
            if name == "local_k4":
                mid = ohlo.compiled_text(step, st, batch,
                                         jax.random.key(7), False)
                dump("mix_local_k4_mid_8dev.hlo.txt.gz", mid)
        if name.startswith("delayed_tau4"):
            expected[f"mix_{name}_8dev.hlo.txt.gz"]["ring_params"] = \
                len(ohlo.ring_parameters(ex, 4))
        if overlap:
            # the split-phase lowering's structural invariant, pinned:
            # every exchange-scoped collective is dataflow-independent
            # of the field phase (async -start/-done pairs only appear
            # on GPU/TPU backends, so they are reported, not required)
            indep = ohlo.exchange_field_independence(ex)
            expected[f"mix_{name}_8dev.hlo.txt.gz"]["independence"] = {
                "exchange_collectives": indep["exchange_collectives"],
                "tainted": indep["tainted"], "ok": indep["ok"],
            }

    expected["n_param_leaves"] = len(jax.tree.leaves(params))
    led = build(Schedule(), mesh, cfg).comm_ledger(params)
    expected["ledger"] = {
        "wire_bytes_per_step": led.wire_bytes_per_step,
        "carried_bytes_per_step": led.carried_bytes_per_step,
        "n_workers": led.n_workers,
    }
    with open(os.path.join(FIX, "mix_8dev_expected.json"), "w") as fh:
        json.dump(expected, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(json.dumps(expected, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
