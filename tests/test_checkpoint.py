"""repro.checkpoint round-trips of the FULL DQState — including the
bucketed comm-plan EF layout (``ef["bucket"]`` entries) and the
repro.sched buffers — plus resume equivalence: train 2N steps must equal
train N, save, restore, train N, bit-for-bit."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

KEY = jax.random.key(0)

A = jnp.array(np.linalg.qr(np.random.RandomState(5).randn(8, 8))[0],
              jnp.float32)


def field(params, batch, rng):
    x, y = params["x"], params["y"]
    return ({"x": A @ y, "y": -(A.T @ x), "b": params["b"]},
            {"loss": x @ A @ y})


def _params():
    return {"x": jnp.ones(8), "y": jnp.ones(8), "b": jnp.ones((4, 8))}


BUCKETED = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                    exchange="two_phase", error_feedback=True, lr=0.05,
                    worker_axes=(), comm_plan="uniform", bucket_mb=0.001)


def _assert_state_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bucketed_dqstate_roundtrip(tmp_path):
    """A comm-plan state (per-leaf e1 + per-bucket e2 under ef["bucket"])
    survives save/restore bit-exactly, structure included."""
    tr = DQGAN(field_fn=field, dq=BUCKETED)
    st = tr.init(_params())
    assert isinstance(st.ef, dict) and "bucket" in st.ef
    assert st.ef["bucket"], "two_phase comm plan must carry bucket e2 state"
    step = jax.jit(tr.step, static_argnums=(3,))
    for _ in range(3):
        st = step(st, None, KEY, True).state
    # residuals are live, not zeros — the round-trip moves real data
    assert any(float(jnp.sum(jnp.abs(l))) > 0
               for l in jax.tree.leaves(st.ef))

    path = str(tmp_path / "state.npz")
    checkpoint.save(path, st, step=int(jax.device_get(st.step)))
    assert checkpoint.latest_step(path) == 3
    restored = checkpoint.restore(path, tr.init(_params()))
    assert jax.tree.structure(restored) == jax.tree.structure(st)
    _assert_state_equal(restored, st)
    for bid, ent in st.ef["bucket"].items():
        np.testing.assert_array_equal(np.asarray(restored.ef["bucket"][bid]["e2"]),
                                      np.asarray(ent["e2"]))


@pytest.mark.parametrize("variant", ["bucketed", "delayed", "delayed_tau",
                                     "local_k", "oadam"])
def test_resume_equivalence(tmp_path, variant):
    """train 2N ≡ train N, save, restore, train N — bit-exact even with a
    stochastic compressor (RNG keys derive from the carried step count).
    `delayed_tau` covers the τ>1 pending ring buffer + version vector
    (DESIGN.md §8): a mid-pipeline save must restore all τ in-flight
    messages and the per-worker staleness bookkeeping."""
    from repro import sched as S

    N = 4
    dq = {
        "bucketed": BUCKETED,
        "delayed": dataclasses.replace(BUCKETED, comm_plan="none",
                                       exchange="sim", schedule="delayed"),
        "delayed_tau": dataclasses.replace(BUCKETED, comm_plan="none",
                                           exchange="sim",
                                           schedule="delayed",
                                           staleness_tau=3),
        "local_k": dataclasses.replace(BUCKETED, comm_plan="none",
                                       exchange="sim", schedule="local_k",
                                       local_k=2),
        "oadam": dataclasses.replace(BUCKETED, comm_plan="none",
                                     exchange="sim", optimizer="oadam",
                                     message="grad"),
    }[variant]
    sched = S.get(dq.schedule, dq.local_k, dq.staleness_tau)
    tr = DQGAN(field_fn=field, dq=dq)
    step = jax.jit(tr.step, static_argnums=(3,))

    st = tr.init(_params())
    for i in range(2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    full = jax.device_get(st)

    st = tr.init(_params())
    for i in range(N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    path = str(tmp_path / "mid.npz")
    checkpoint.save(path, st, step=N)
    st = checkpoint.restore(path, tr.init(_params()))
    start = int(jax.device_get(st.step))
    assert start == N
    for i in range(start, 2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    resumed = jax.device_get(st)

    _assert_state_equal(full, resumed)
