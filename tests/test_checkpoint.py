"""repro.checkpoint round-trips of the FULL DQState — including the
bucketed comm-plan EF layout (``ef["bucket"]`` entries) and the
repro.sched buffers — plus resume equivalence: train 2N steps must equal
train N, save, restore, train N, bit-for-bit."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

KEY = jax.random.key(0)

A = jnp.array(np.linalg.qr(np.random.RandomState(5).randn(8, 8))[0],
              jnp.float32)


def field(params, batch, rng):
    x, y = params["x"], params["y"]
    return ({"x": A @ y, "y": -(A.T @ x), "b": params["b"]},
            {"loss": x @ A @ y})


def _params():
    return {"x": jnp.ones(8), "y": jnp.ones(8), "b": jnp.ones((4, 8))}


BUCKETED = DQConfig(optimizer="omd", compressor="qsgd8_linf",
                    exchange="two_phase", error_feedback=True, lr=0.05,
                    worker_axes=(), comm_plan="uniform", bucket_mb=0.001)


def _assert_state_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bucketed_dqstate_roundtrip(tmp_path):
    """A comm-plan state (per-leaf e1 + per-bucket e2 under ef["bucket"])
    survives save/restore bit-exactly, structure included."""
    tr = DQGAN(field_fn=field, dq=BUCKETED)
    st = tr.init(_params())
    assert isinstance(st.ef, dict) and "bucket" in st.ef
    assert st.ef["bucket"], "two_phase comm plan must carry bucket e2 state"
    step = jax.jit(tr.step, static_argnums=(3,))
    for _ in range(3):
        st = step(st, None, KEY, True).state
    # residuals are live, not zeros — the round-trip moves real data
    assert any(float(jnp.sum(jnp.abs(l))) > 0
               for l in jax.tree.leaves(st.ef))

    path = str(tmp_path / "state.npz")
    checkpoint.save(path, st, step=int(jax.device_get(st.step)))
    assert checkpoint.latest_step(path) == 3
    restored = checkpoint.restore(path, tr.init(_params()))
    assert jax.tree.structure(restored) == jax.tree.structure(st)
    _assert_state_equal(restored, st)
    for bid, ent in st.ef["bucket"].items():
        np.testing.assert_array_equal(np.asarray(restored.ef["bucket"][bid]["e2"]),
                                      np.asarray(ent["e2"]))


@pytest.mark.parametrize("variant", ["bucketed", "delayed", "delayed_tau",
                                     "local_k", "oadam"])
def test_resume_equivalence(tmp_path, variant):
    """train 2N ≡ train N, save, restore, train N — bit-exact even with a
    stochastic compressor (RNG keys derive from the carried step count).
    `delayed_tau` covers the τ>1 pending ring buffer + version vector
    (DESIGN.md §8): a mid-pipeline save must restore all τ in-flight
    messages and the per-worker staleness bookkeeping."""
    from repro import sched as S

    N = 4
    dq = {
        "bucketed": BUCKETED,
        "delayed": dataclasses.replace(BUCKETED, comm_plan="none",
                                       exchange="sim", schedule="delayed"),
        "delayed_tau": dataclasses.replace(BUCKETED, comm_plan="none",
                                           exchange="sim",
                                           schedule="delayed",
                                           staleness_tau=3),
        "local_k": dataclasses.replace(BUCKETED, comm_plan="none",
                                       exchange="sim", schedule="local_k",
                                       local_k=2),
        "oadam": dataclasses.replace(BUCKETED, comm_plan="none",
                                     exchange="sim", optimizer="oadam",
                                     message="grad"),
    }[variant]
    sched = S.get(dq.schedule, dq.local_k, dq.staleness_tau)
    tr = DQGAN(field_fn=field, dq=dq)
    step = jax.jit(tr.step, static_argnums=(3,))

    st = tr.init(_params())
    for i in range(2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    full = jax.device_get(st)

    st = tr.init(_params())
    for i in range(N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    path = str(tmp_path / "mid.npz")
    checkpoint.save(path, st, step=N)
    st = checkpoint.restore(path, tr.init(_params()))
    start = int(jax.device_get(st.step))
    assert start == N
    for i in range(start, 2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    resumed = jax.device_get(st)

    _assert_state_equal(full, resumed)


# --------------------------------------------------------------------------- #
# sharded (per-host) checkpoints (DESIGN.md §15.5)
# --------------------------------------------------------------------------- #
def _synthetic_tree():
    """Leaves exercising every manifest case: dim0-splittable, whole
    (round-robined), bf16 (uint16 view), 0-d, and None."""
    r = np.random.RandomState(3)
    return {
        "emb": jnp.asarray(r.randn(16, 8), jnp.float32),     # splits on dim0
        "w": jnp.asarray(r.randn(3, 5), jnp.float32),        # whole leaf
        "h": jnp.asarray(r.randn(8, 4), jnp.bfloat16),       # bf16 view
        "scale": jnp.float32(0.5),                           # 0-d
        "none": None,
    }


@pytest.mark.parametrize("save_h", [1, 8])
@pytest.mark.parametrize("restore_h", [1, 4, 8])
def test_sharded_resharding_matrix(tmp_path, save_h, restore_h):
    """save with H shards, restore under a different host count — the
    gathered pytree is bit-exact regardless of either count. The
    restore side never reads n_shards from the environment (chunks are
    assembled from the manifest), so `restore_h` here means: the
    manifest written at `save_h` must restore anywhere."""
    del restore_h  # restore is layout-agnostic by construction; the
    #                matrix documents that no restore-side knob exists
    tree = _synthetic_tree()
    path = str(tmp_path / f"ck-{save_h}")
    checkpoint.save_sharded(path, tree, step=7, n_shards=save_h)
    assert checkpoint.is_sharded(path)
    assert checkpoint.latest_step(path) == 7
    mf = checkpoint.read_manifest(path)
    assert mf["n_shards"] == save_h
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = checkpoint.restore_sharded(path, like)
    for k in ("emb", "w", "h"):
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))
    assert float(out["scale"]) == 0.5
    assert out["none"] is None
    assert out["h"].dtype == jnp.bfloat16


def test_sharded_resume_equivalence(tmp_path):
    """train 2N ≡ train N, sharded-save, restore, train N — the sharded
    format is a drop-in for the .npz resume contract at the same worker
    count (here W=1: shard files ≠ worker shards)."""
    from repro import sched as S

    N = 4
    sched = S.get(BUCKETED.schedule, BUCKETED.local_k,
                  BUCKETED.staleness_tau)
    tr = DQGAN(field_fn=field, dq=BUCKETED)
    step = jax.jit(tr.step, static_argnums=(3,))

    st = tr.init(_params())
    for i in range(2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    full = jax.device_get(st)

    st = tr.init(_params())
    for i in range(N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    path = str(tmp_path / "mid-sharded")
    checkpoint.save_sharded(path, st, step=N, n_shards=4,
                            meta={"strategy": tr.strategy.to_json()})
    st = checkpoint.restore_sharded(path, tr.init(_params()))
    assert int(jax.device_get(st.step)) == N
    for i in range(N, 2 * N):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    _assert_state_equal(full, jax.device_get(st))


def test_sharded_manifest_strategy_mismatch_fails_fast(tmp_path):
    """verify_strategy reads the manifest-embedded strategy JSON and
    refuses a resume under a different strategy with a field-level
    diff — same contract as the .npz format."""
    tr = DQGAN(field_fn=field, dq=BUCKETED)
    st = tr.init(_params())
    path = str(tmp_path / "ck")
    checkpoint.save_sharded(path, st, step=1,
                            meta={"strategy": tr.strategy.to_json()})
    checkpoint.verify_strategy(path, tr.strategy)  # same strategy: ok
    other = dataclasses.replace(BUCKETED, schedule="local_k", local_k=4)
    with pytest.raises(ValueError, match="schedule.kind"):
        checkpoint.verify_strategy(path, DQGAN(field_fn=field,
                                               dq=other).strategy)


def test_sharded_restore_shape_mismatch_fails_fast(tmp_path):
    """Per-worker state (EF residuals, fsdp shard slots) is laid out by
    worker count; restoring under a different count must refuse with
    the shape diff, not crash mid-step."""
    path = str(tmp_path / "ck")
    checkpoint.save_sharded(path, {"ef": jnp.ones((8, 4))}, step=1)
    with pytest.raises(ValueError, match="resharding|worker count"):
        checkpoint.restore_sharded(path, {"ef": jnp.zeros((4, 8))})


def test_sharded_missing_leaf_fails_subtree_restore_allowed(tmp_path):
    path = str(tmp_path / "ck")
    checkpoint.save_sharded(path, {"a": jnp.ones(4), "c": jnp.ones(2)},
                            step=1)
    # a leaf the checkpoint never saved is an error...
    with pytest.raises(ValueError, match="missing"):
        checkpoint.restore_sharded(path, {"a": jnp.zeros(4),
                                          "b": jnp.zeros(2)})
    # ...but restoring a subtree (e.g. params only, cross-worker-count
    # resume) is the documented escape hatch and must work
    out = checkpoint.restore_sharded(path, {"a": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(4))
