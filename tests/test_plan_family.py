"""Round-adaptive compression (DESIGN.md §10): PlanFamily construction,
the participation-aware ledger, heterogeneous per-worker τ_m, and the
single-device adaptive training path (full-participation bit-exactness +
no retracing). The Hypothesis property tests live in
test_plan_family_props.py; the 8-device variants in the multidevice
subprocess tests of test_comm.py/test_checkpoint.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.comm.planner import plan_comm, plan_family, quant_ladder
from repro.configs.base import DQConfig
from repro.core import compressors as C
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, gan_field_fn, mlp_gan_init
from repro.sched import seeded_tau_vector
from repro.strategy import (
    Compression,
    ExchangePlan,
    Participation,
    Schedule,
    Strategy,
    StrategyError,
    get_preset,
)

KEY = jax.random.key(0)


# --------------------------------------------------------------------------- #
# quant_ladder
# --------------------------------------------------------------------------- #
def test_quant_ladder_structures():
    assert quant_ladder("qsgd8_linf") == ["qsgd8_linf", "qsgd4_linf",
                                          "qsgd2_linf"]
    assert quant_ladder("qsgd8_block1024") == [
        "qsgd8_block1024", "qsgd4_block1024", "qsgd2_block1024"]
    assert quant_ladder("qsgd4_linf") == ["qsgd4_linf", "qsgd2_linf"]
    for bad in ("sign", "identity", "topk1", "qsgd8_l2", "qsgd8_block256"):
        with pytest.raises(ValueError):
            quant_ladder(bad)


def test_adaptive_compression_validation():
    with pytest.raises(StrategyError, match="compression.adaptive"):
        Compression(adaptive=True)
    with pytest.raises(StrategyError, match="compression.adaptive"):
        Compression(plan="uniform", adaptive=True)
    with pytest.raises(StrategyError, match="compression.compressor"):
        Compression(plan="delta_budget", budget_mb=1.0, adaptive=True,
                    compressor="sign")
    # valid spelling constructs (and the preset registry carries one)
    Compression(plan="delta_budget", budget_mb=1.0, adaptive=True)
    assert get_preset("adaptive_budget").compression.adaptive


# --------------------------------------------------------------------------- #
# PlanFamily construction (fixed cases; randomized Hypothesis variants in
# test_plan_family_props.py)
# --------------------------------------------------------------------------- #
def test_family_invariants_fixed_case():
    shapes = {"a": (300, 300), "b": (64,), "c": (200, 500), "d": (90000,)}
    M = 8
    layout = comm.build_layout(shapes, None, n_workers=M,
                               bucket_bytes=1 << 19)
    full = plan_comm(layout, "qsgd8_linf", "uniform").payload_bytes
    budget = full // 2
    fam = plan_family(layout, "qsgd8_linf", budget, M)
    assert len(fam.plans) == M
    bits = fam.bits_table()
    for n in range(1, M + 1):
        p = fam.plan_for(n)
        at_floor = all(b == 2 for b in bits[n - 1])
        assert p.payload_bytes <= fam.effective_budget(n) or at_floor
    for bid in range(len(layout.buckets)):
        col = [bits[n][bid] for n in range(M)]  # n increasing
        assert all(a >= b for a, b in zip(col, col[1:])), (bid, col)
    deltas = [fam.plan_for(n).min_delta for n in range(1, M + 1)]
    assert all(a >= b - 1e-12 for a, b in zip(deltas, deltas[1:])), deltas
    # the n = M member IS the static delta_budget plan (the bit-exactness
    # anchor for full-participation adaptive training)
    static = plan_comm(layout, "qsgd8_linf", "delta_budget",
                       budget_bytes=budget)
    assert fam.full.assignments == static.assignments


def test_family_diff_names_participation_count():
    shapes = {"w": (256, 256), "v": (64, 2048)}
    layout = comm.build_layout(shapes, None, n_workers=4,
                               bucket_bytes=1 << 16)
    full = plan_comm(layout, "qsgd8_linf", "uniform").payload_bytes
    a = plan_family(layout, "qsgd8_linf", full // 2, 4)
    b = plan_family(layout, "qsgd8_linf", full // 3, 4)
    assert a.diff(a) == []
    d = a.diff(b)
    assert d and any(s.startswith("plan_family[n=") for s in d)
    n_named = {int(s.split("[n=")[1].split("]")[0])
               for s in d if "[n=" in s}
    # the named counts are exactly the members whose sub-plans differ
    want = {n for n in range(1, 5)
            if a.plan_for(n).assignments != b.plan_for(n).assignments}
    assert n_named == want
    assert any("budget_bytes" in s for s in a.diff(b))


def test_traced_quant_matches_static_quant():
    """TracedQuant with a concrete levels scalar reproduces StochasticQuant
    bit-for-bit (same draws, same codes) — the dispatch path is the same
    arithmetic, selected by data."""
    for name in ("qsgd8_linf", "qsgd4_linf", "qsgd2_linf"):
        sq = C.get(name)
        tq = C.TracedQuant(jnp.float32(sq.levels), per_block=sq.per_block)
        v = 0.3 * jax.random.normal(KEY, (2048,))
        a = jax.jit(lambda v: sq.roundtrip(v, KEY))(v)
        b = jax.jit(lambda v: tq.roundtrip(v, KEY))(v)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_kernel_dynamic_levels_matches_static():
    from repro.kernels.quantize import quantize_ef_flat

    n = 4 * 1024
    g = 0.3 * jax.random.normal(KEY, (n,))
    e = 0.05 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    r = jax.random.uniform(jax.random.fold_in(KEY, 2), (n,))
    for lv in (127, 7, 1):
        cs, ss, es = quantize_ef_flat(g, e, r, levels=lv)
        cd, sd, ed = jax.jit(
            lambda g, e, r, l: quantize_ef_flat(g, e, r, levels=l)
        )(g, e, r, jnp.float32(lv))
        np.testing.assert_array_equal(np.asarray(cs), np.asarray(cd))
        np.testing.assert_allclose(np.asarray(ss), np.asarray(sd), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(es), np.asarray(ed), atol=1e-6)


# --------------------------------------------------------------------------- #
# participation-aware ledger
# --------------------------------------------------------------------------- #
def _mix_layout_family(M=8, frac=0.5):
    params = jax.eval_shape(
        lambda k: mlp_gan_init(k, GANConfig(name="mix", image_size=0,
                                            data_dim=2, latent_dim=16,
                                            hidden=128)), KEY)
    shapes = jax.tree.map(lambda x: tuple(x.shape), params)
    layout = comm.build_layout(shapes, None, n_workers=M,
                               bucket_bytes=1 << 16)
    full = plan_comm(layout, "qsgd8_linf", "uniform").payload_bytes
    fam = plan_family(layout, "qsgd8_linf", int(full * frac), M)
    return layout, fam


def test_ledger_bills_selected_plan_for_reporting_workers():
    M = 8
    layout, fam = _mix_layout_family(M)
    led = comm.CommLedger.from_plan(layout, fam.full, "two_phase", M,
                                    "qsgd8_linf", family=fam)
    full_w, _ = led.round_bytes()            # all M ship the full-M plan
    half_w, _ = led.round_bytes(4)           # 4 ship the n=4 plan
    # fleet-average: half the workers report, but each ships the finer
    # n=4 plan — strictly more than half the full-M bytes (the absent
    # workers' budget is re-spent), yet still within the fleet-average
    # byte budget B times the two_phase collective multiplier
    assert half_w > 0.5 * full_w
    bound = fam.budget_bytes * 2 * (M - 1) / M
    assert half_w <= bound * (1 + 1e-9)
    assert full_w <= bound * (1 + 1e-9)
    # the old conservative accounting (full-M plan for everyone) is gone:
    led_static = comm.CommLedger.from_plan(layout, fam.full, "two_phase",
                                           M, "qsgd8_linf")
    stat_half_w, _ = led_static.round_bytes(4)
    assert stat_half_w == pytest.approx(0.5 * full_w)
    # cumulative accounting follows the billed rounds
    led.tick(10, participants=4)
    assert led.cumulative_wire_bytes == pytest.approx(10 * half_w)
    assert led.summary()["participants"] == 4
    # full-participation ticks keep the legacy identity
    led2 = comm.CommLedger.from_plan(layout, fam.full, "two_phase", M,
                                     "qsgd8_linf", family=fam)
    led2.tick(10)
    assert led2.cumulative_wire_bytes == pytest.approx(
        10 * led2.wire_bytes_per_step)


# --------------------------------------------------------------------------- #
# heterogeneous per-worker τ_m
# --------------------------------------------------------------------------- #
def test_tau_vector_validation_and_seeding():
    with pytest.raises(StrategyError, match="tau_vector"):
        Schedule(kind="every_step", tau_vector=(1,))
    with pytest.raises(StrategyError, match="max"):
        Schedule.delayed(2, tau_vector=(1, 3))
    with pytest.raises(StrategyError, match="ints"):
        Schedule.delayed(2, tau_vector=(0, 2))
    s = Schedule.delayed_hetero((1, 3, 2))
    assert s.tau == 3 and s.tau_vector == (1, 3, 2)
    tv = seeded_tau_vector(4, 8, seed=3)
    assert tv == seeded_tau_vector(4, 8, seed=3)  # deterministic
    assert len(tv) == 8 and max(tv) == 4 and min(tv) >= 1
    # JSON round-trip carries the vector
    st2 = Strategy(schedule=Schedule.delayed_hetero(tv),
                   exchange=ExchangePlan(worker_axes=()))
    assert Strategy.from_json(st2.to_json()) == st2
    # mismatched length refuses at trainer init
    dq = DQConfig.from_strategy(st2, optimizer="omd")
    tr = DQGAN(field_fn=lambda p, b, k: (p, {}), dq=dq)
    with pytest.raises(ValueError, match="tau_vector"):
        tr.init({"x": jnp.ones(4)})


def test_tau_vector_pull_positions():
    """Worker m's wire head is ring slot τ−τ_m (the message it produced
    τ_m steps ago) and its staleness correction sums exactly its τ_m
    in-flight slots."""
    s = Schedule.delayed_hetero((3, 1, 2))
    ring = {"p": jnp.arange(3 * 4, dtype=jnp.float32).reshape(3, 4)}
    state = {"pending": ring, "versions": jnp.zeros((3,), jnp.int32)}
    for m, tau_m in enumerate((3, 1, 2)):
        buf, head = s.wire_head(state, jnp.int32(m))
        np.testing.assert_array_equal(np.asarray(head["p"]),
                                      np.asarray(ring["p"][3 - tau_m]))
        stale = s.staleness_correction(buf, "update", 1.0, jnp.int32(m))
        np.testing.assert_allclose(
            np.asarray(stale["p"]),
            np.asarray(ring["p"][3 - tau_m:].sum(axis=0)), rtol=1e-6)
        v = s.advance_version(jnp.int32(-1), jnp.int32(10), None,
                              jnp.int32(m))
        assert int(v) == 10 - tau_m


# --------------------------------------------------------------------------- #
# single-device adaptive training: bit-exact + no retracing
# --------------------------------------------------------------------------- #
def _mk_mix_trainer(adaptive, participation=1.0, budget_mb=0.033):
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    strat = Strategy(
        compression=Compression(plan="delta_budget", budget_mb=budget_mb,
                                adaptive=adaptive, bucket_mb=0.03),
        exchange=ExchangePlan(kind="sim", worker_axes=()),
        participation=Participation(fraction=participation))
    dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-3)
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq), cfg


def test_adaptive_single_worker_bit_exact_and_single_trace():
    tr_a, cfg = _mk_mix_trainer(True)
    tr_s, _ = _mk_mix_trainer(False)
    params = mlp_gan_init(KEY, cfg)
    fam = tr_a._family(params)
    assert fam is not None and fam.full.assignments == \
        tr_s._comm(params)[1].assignments
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    traces = []

    def run(tr):
        def counting(st, b, k):
            traces.append(1)
            return tr.step(st, b, k)
        st = tr.init(params)
        step = jax.jit(counting)
        for i in range(4):
            st = step(st, batch, jax.random.fold_in(KEY, i)).state
        return st

    sa = run(tr_a)
    n_traces_a = len(traces)
    ss = run(tr_s)
    assert n_traces_a == 1, "adaptive step retraced across rounds"
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(ss.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adaptive_strategy_json_and_hash_roundtrip():
    st2 = get_preset("adaptive_budget")
    back = Strategy.from_json(st2.to_json())
    assert back == st2 and back.short_hash() == st2.short_hash()
    assert "compression.adaptive: True != False" in st2.diff(
        st2.evolve(comm_adaptive=False))


def test_list_presets_cli():
    import contextlib
    import io

    from repro.strategy.__main__ import main

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert main(["--list-presets"]) == 0
    out = buf.getvalue()
    assert "adaptive_budget" in out and "re-spent on finer bits" in out
    # every preset prints name + hash + one-line doc
    from repro.strategy import PRESETS
    assert all(name in out for name in PRESETS)


# --------------------------------------------------------------------------- #
# 8 devices: adaptive dispatch under real participation (subprocess)
# --------------------------------------------------------------------------- #
ADAPTIVE_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.strategy import (Compression, ExchangePlan, Participation,
                            Strategy)

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)

def mk(adaptive, participation, exchange="sim"):
    st = Strategy(
        compression=Compression(plan="delta_budget", budget_mb=0.033,
                                adaptive=adaptive, bucket_mb=0.03),
        exchange=ExchangePlan(kind=exchange, worker_axes=("data",)),
        participation=Participation(fraction=participation))
    dq = DQConfig.from_strategy(st, optimizer="omd", lr=1e-2)
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
                 batch_spec=P(("data",)))

def run(tr, steps=5):
    traces = []
    def counting(st, batch, k, do_ex):
        traces.append(1)
        return tr.step(st, batch, k, do_ex)
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(counting, static_argnums=(3,))
        for i in range(steps):
            batch = {"real": jax.random.normal(jax.random.fold_in(key, i),
                                               (64, 2))}
            st = step(st, batch, jax.random.key(7), True).state
    return jax.device_get(st), len(traces)

# full participation: adaptive == static bit-exactly (single-member
# selection -> the identical static compressor path)
tr_a, tr_s = mk(True, 1.0), mk(False, 1.0)
assert tr_a._family(params).full.assignments == \
    tr_s._comm(params)[1].assignments
sa, na = run(tr_a); ss, ns = run(tr_s)
for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(ss.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert na == ns == 1

# partial participation: the traced-gather dispatch runs, compiles ONCE
# across rounds, and produces finite params that DIFFER from static
# (finer bits for the reporting workers)
for exchange in ("sim", "two_phase"):
    tr_p = mk(True, 0.5, exchange)
    fam = tr_p._family(params)
    assert fam.n_distinct > 1, fam.describe()
    sp, nt = run(tr_p, steps=6)
    assert nt == 1, f"adaptive step retraced ({nt} traces)"
    leaves = jax.tree.leaves(sp.params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    sq, _ = run(mk(False, 0.5, exchange), steps=6)
    diff = sum(float(np.abs(np.asarray(a) - np.asarray(b)).sum())
               for a, b in zip(leaves, jax.tree.leaves(sq.params)))
    assert diff > 0, "adaptive plan selection had no effect"
print("OK")
"""


@pytest.mark.multidevice
def test_adaptive_dispatch_8dev(multidevice):
    out = multidevice(ADAPTIVE_8DEV_SCRIPT)
    assert "OK" in out


# checkpoint: a mid-run adaptive state (EF residuals shaped by rounds of
# different selected plans) must resume bit-exactly through the existing
# strategy.to_json guard
ADAPTIVE_RESUME_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro import checkpoint
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.strategy import (Compression, ExchangePlan, Participation,
                            Strategy)

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)
strat = Strategy(
    compression=Compression(plan="delta_budget", budget_mb=0.033,
                            adaptive=True, bucket_mb=0.03),
    exchange=ExchangePlan(kind="two_phase", worker_axes=("data",)),
    participation=Participation(fraction=0.5))
dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
           batch_spec=P(("data",)))
N = 3

def batch(i):
    return {"real": jax.random.normal(jax.random.fold_in(key, i), (64, 2))}

with set_mesh(mesh):
    step = jax.jit(tr.step, static_argnums=(3,))
    st = tr.init(params)
    for i in range(2 * N):
        st = step(st, batch(i), jax.random.key(7), True).state
    full = jax.device_get(st)

    st = tr.init(params)
    for i in range(N):
        st = step(st, batch(i), jax.random.key(7), True).state
    path = os.path.join(tempfile.mkdtemp(), "adaptive.npz")
    checkpoint.save(path, st, step=N, meta={"strategy": strat.to_json()})

    # guard: the same strategy resumes; a different family refuses with
    # the field-level diff
    checkpoint.verify_strategy(path, strat)
    try:
        checkpoint.verify_strategy(path, strat.evolve(comm_adaptive=False))
        raise SystemExit("guard let a mismatched family resume")
    except ValueError as e:
        assert "compression.adaptive" in str(e), e

    st = checkpoint.restore(path, tr.init(params))
    assert int(jax.device_get(st.step)) == N
    for i in range(N, 2 * N):
        st = step(st, batch(i), jax.random.key(7), True).state
    resumed = jax.device_get(st)

fl, rl = jax.tree.leaves(full), jax.tree.leaves(resumed)
assert len(fl) == len(rl)
for a, b in zip(fl, rl):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
"""


@pytest.mark.multidevice
def test_adaptive_checkpoint_resume_8dev(multidevice):
    out = multidevice(ADAPTIVE_RESUME_SCRIPT)
    assert "OK" in out


# heterogeneous τ_m on 8 workers: per-worker staleness metrics + resume
TAU_VECTOR_8DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.sched import seeded_tau_vector
from repro.strategy import ExchangePlan, Schedule, Strategy

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)
tv = seeded_tau_vector(3, 8, seed=1)

def run(schedule, steps=6):
    st = Strategy(exchange=ExchangePlan(kind="sim", worker_axes=("data",)),
                  schedule=schedule)
    tr = DQGAN(field_fn=gan_field_fn(cfg),
               dq=DQConfig.from_strategy(st, optimizer="omd", lr=1e-2),
               mesh=mesh, batch_spec=P(("data",)))
    with set_mesh(mesh):
        s = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            batch = {"real": jax.random.normal(jax.random.fold_in(key, i),
                                               (64, 2))}
            out = step(s, batch, jax.random.key(7), True)
            s = out.state
    return jax.device_get(s), jax.device_get(out.metrics)

# per-worker staleness metrics reflect the τ_m bound once warm
_, m = run(Schedule.delayed_hetero(tv))
assert m["staleness_max"] == max(tv), (m, tv)
assert abs(m["staleness_mean"] - np.mean(tv)) < 1e-6, (m, tv)

# a homogeneous tau_vector is bit-exact with the plain delayed schedule
a, _ = run(Schedule.delayed(2, tau_vector=(2,) * 8))
b, _ = run(Schedule.delayed(2))
for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("OK")
"""


@pytest.mark.multidevice
def test_tau_vector_8dev(multidevice):
    out = multidevice(TAU_VECTOR_8DEV_SCRIPT)
    assert "OK" in out
