"""Structural HLO verification (repro.obs.hlo, DESIGN.md §12.2).

The committed fixtures under tests/fixtures/ are real optimized
(post-SPMD) HLO of the mix trainer's jitted step, lowered on 8 forced
host devices with spans on (regenerate with the snippet in
mix_8dev_expected.json's sibling docstring below) — they keep the
extraction + structure logic covered on single-device CI; the
@multidevice test re-derives everything live.

Regenerating the fixtures (after a deliberate step-graph change)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/fixtures/regen_mix_8dev.py
"""
import gzip
import json
import os

import pytest

from repro.obs import hlo as ohlo
from repro.strategy import Schedule

FIX = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")


def _fixture(name: str) -> str:
    with gzip.open(os.path.join(FIX, name), "rt") as fh:
        return fh.read()


def _expected() -> dict:
    with open(os.path.join(FIX, "mix_8dev_expected.json")) as fh:
        return json.load(fh)


class _StubLedger:
    """Just enough CommLedger surface for byte_gap."""

    def __init__(self, wire, carried, n_workers):
        self.wire, self.carried, self.n_workers = wire, carried, n_workers

    def round_bytes(self, participants=None):
        return self.wire, self.carried

    def per_bucket(self, participants=None):
        return []


# --------------------------------------------------------------------------- #
# extraction against the committed fixtures
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", [
    "mix_every_step_8dev.hlo.txt.gz",
    "mix_local_k4_8dev.hlo.txt.gz",
    "mix_local_k4_mid_8dev.hlo.txt.gz",
    "mix_delayed_tau4_8dev.hlo.txt.gz",
    "mix_delayed_tau4_overlap_8dev.hlo.txt.gz",
])
def test_collective_summary_matches_recorded(name):
    txt = _fixture(name)
    assert ohlo.collective_summary(txt) == _expected()[name]["collectives"]


def test_scope_costs_survive_to_optimized_hlo():
    """The repro.obs named-scope metadata is present in the compiled
    step and scope_costs attributes real ops + bytes to each phase."""
    from repro.launch.hlo_analysis import scope_costs
    exp = _expected()
    for name, rec in exp.items():
        if not isinstance(rec, dict) or "scope_phases" not in rec:
            continue
        got = scope_costs(_fixture(name))
        assert {k: v["ops"] for k, v in got.items()} == rec["scope_phases"]
        # the exchange phase moves real bytes on exchange-step variants
        if "exchange" in rec["scope_phases"]:
            assert got["exchange"]["bytes"] > 0


def test_ring_parameters_delayed_fixture():
    txt = _fixture("mix_delayed_tau4_8dev.hlo.txt.gz")
    exp = _expected()
    rings = ohlo.ring_parameters(txt, 4)
    assert len(rings) == exp["mix_delayed_tau4_8dev.hlo.txt.gz"][
        "ring_params"]
    assert len(rings) >= exp["n_param_leaves"]
    assert all(4 in shp[:2] for shp in rings)


def test_entry_parameter_shapes_nonempty():
    shapes = ohlo.entry_parameter_shapes(
        _fixture("mix_every_step_8dev.hlo.txt.gz"))
    assert shapes, "no ENTRY parameters parsed"
    assert all(isinstance(s, tuple) for s in shapes)


# --------------------------------------------------------------------------- #
# the measured-vs-modeled byte gap
# --------------------------------------------------------------------------- #
def test_byte_gap_report():
    exp = _expected()
    led = _StubLedger(exp["ledger"]["wire_bytes_per_step"],
                      exp["ledger"]["carried_bytes_per_step"],
                      exp["ledger"]["n_workers"])
    gap = ohlo.byte_gap(_fixture("mix_every_step_8dev.hlo.txt.gz"), led)
    coll = exp["mix_every_step_8dev.hlo.txt.gz"]["collectives"]
    assert gap["hlo_bytes"] == sum(v["bytes"] for v in coll.values())
    assert gap["hlo_int8_bytes"] == sum(v["int8_bytes"]
                                        for v in coll.values())
    # transport factor 2(W-1)/W divided back out of the carried model
    W = exp["ledger"]["n_workers"]
    assert gap["modeled_result_bytes"] == pytest.approx(
        exp["ledger"]["carried_bytes_per_step"] / (2 * (W - 1) / W))
    assert gap["gap_ratio"] == pytest.approx(
        gap["hlo_bytes"] / gap["modeled_result_bytes"] - 1.0)
    # the recorded program all-reduces every worker's int8 codes: the
    # compiled wire format is wider than the per-worker carried model —
    # the gap is the point of the report, assert it is surfaced
    assert gap["gap_ratio"] > 1.0


# --------------------------------------------------------------------------- #
# schedule-shaped structure
# --------------------------------------------------------------------------- #
def test_structure_every_step_fixture():
    rep = ohlo.assert_schedule_structure(
        Schedule(), _fixture("mix_every_step_8dev.hlo.txt.gz"))
    assert rep["exchange_class_totals"]["ops"] >= 1


def test_structure_local_k_fixture():
    rep = ohlo.assert_schedule_structure(
        Schedule.local_k(4),
        _fixture("mix_local_k4_8dev.hlo.txt.gz"),
        _fixture("mix_local_k4_mid_8dev.hlo.txt.gz"))
    # mid-round: scalar metric psums only — no payload-class bytes
    assert rep["midround_class_totals"]["int8_bytes"] == 0
    assert rep["midround_class_totals"]["bytes"] < \
        0.01 * rep["exchange_class_totals"]["bytes"]


def test_structure_delayed_fixture():
    exp = _expected()
    rep = ohlo.assert_schedule_structure(
        Schedule.delayed(tau=4),
        _fixture("mix_delayed_tau4_8dev.hlo.txt.gz"),
        n_param_leaves=exp["n_param_leaves"])
    assert len(rep["ring_parameters"]) >= exp["n_param_leaves"]


_NO_COLLECTIVE_HLO = """\
HloModule step

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  ROOT %add = f32[8,128]{1,0} add(%p0, %p0)
}
"""


def test_structure_violations_raise():
    # an exchange step with no collective at all is flagged ...
    with pytest.raises(AssertionError, match="no all-reduce-class"):
        ohlo.assert_schedule_structure(Schedule(), _NO_COLLECTIVE_HLO)
    # ... and a mid-round step moving the full exchange payload is the
    # accumulator leaking onto the wire
    ex = _fixture("mix_local_k4_8dev.hlo.txt.gz")
    rep = ohlo.check_schedule_structure(Schedule.local_k(4), ex,
                                        midround_txt=ex)
    assert not rep["ok"]
    assert any("quantized payload" in v or "leaking" in v
               for v in rep["violations"])
    # local_k without the mid-round variant cannot be verified
    rep = ohlo.check_schedule_structure(Schedule.local_k(4), ex)
    assert not rep["ok"]
    # delayed(τ) whose ring is absent from loop state is flagged
    with pytest.raises(AssertionError, match="ring"):
        ohlo.assert_schedule_structure(
            Schedule.delayed(tau=7),
            _fixture("mix_delayed_tau4_8dev.hlo.txt.gz"),
            n_param_leaves=12)


# --------------------------------------------------------------------------- #
# live: re-derive everything on 8 forced host devices (CI 8-dev tier)
# --------------------------------------------------------------------------- #
LIVE_8DEV_SCRIPT = r"""
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn
from repro.strategy import (Compression, ExchangePlan, Observability,
                            Schedule, Strategy)
from repro.obs import hlo as ohlo

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                hidden=128)
params = mlp_gan_init(jax.random.key(0), cfg)
batch = {"real": jax.random.normal(jax.random.key(0), (64, 2))}

for schedule in (Schedule(), Schedule.local_k(4), Schedule.delayed(tau=4)):
    strat = Strategy(
        compression=Compression(plan="uniform", bucket_mb=0.03),
        exchange=ExchangePlan(kind="two_phase", spmd="shard_map",
                              worker_axes=("data",)),
        schedule=schedule,
        observability=Observability(spans=True))
    dq = DQConfig.from_strategy(strat, optimizer="omd", lr=1e-2)
    tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
               batch_spec=P(("data",)))
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        ex = ohlo.compiled_text(step, st, batch, jax.random.key(7), True)
        mid = (ohlo.compiled_text(step, st, batch, jax.random.key(7),
                                  False)
               if schedule.kind == "local_k" else None)
    rep = ohlo.assert_schedule_structure(
        schedule, ex, mid, n_param_leaves=len(jax.tree.leaves(params)))
    gap = ohlo.byte_gap(ex, tr.comm_ledger(params))
    assert gap["hlo_bytes"] > 0 and gap["modeled_result_bytes"] > 0
    print(rep["schedule"], "ok")
print("OK")
"""


@pytest.mark.multidevice
def test_schedule_structure_live_8dev(multidevice):
    """The three schedule presets verified against freshly compiled HLO
    (not the fixtures) — the check the 8-device CI tier runs."""
    out = multidevice(LIVE_8DEV_SCRIPT)
    assert "OK" in out
    for frag in ("every_step", "local_k", "delayed"):
        assert frag in out
