"""repro.comm: bucket layout, layer-wise planner, wire ledger, and the
bucketed exchange path (DESIGN.md §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.configs.base import DQConfig
from repro.core import compressors as C
from repro.core import exchange as X
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, dcgan_init, gan_field_fn, mlp_gan_init

KEY = jax.random.key(0)


# --------------------------------------------------------------------------- #
# bucket layout
# --------------------------------------------------------------------------- #
def test_layout_alignment_and_roundtrip():
    params = dcgan_init(KEY, GANConfig())
    W = 8
    layout = comm.layout_for_params(params, n_workers=W, bucket_bytes=1 << 20)
    assert len(layout.buckets) > 1 and not layout.skipped
    align = W * comm.buckets.LANE * comm.buckets.SUBLANE
    for b in layout.buckets:
        assert b.size % align == 0          # worker-divisible AND lane-aligned
        assert b.size % W == 0
        # slots tile the bucket contiguously from offset 0
        off = 0
        for s in b.slots:
            assert s.offset == off
            off += s.size
        assert off == b.used <= b.size
    # every leaf appears exactly once
    seen = sorted(s.index for b in layout.buckets for s in b.slots)
    assert seen == list(range(layout.n_leaves))

    leaves, _ = jax.tree.flatten(params)
    flats = comm.pack(layout, leaves)
    assert all(f.shape == (b.size,) for f, b in zip(flats, layout.buckets))
    back = comm.unpack_into(layout, flats, leaves)
    for a, b in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_skips_sharded_leaves():
    from jax.sharding import PartitionSpec as P

    shapes = {"w": (64, 32), "b": (64,)}
    specs = {"w": P("model", None), "b": P()}
    layout = comm.build_layout(shapes, specs, n_workers=4)
    assert len(layout.skipped) == 1 and layout.skipped[0].shape == (64, 32)
    assert sum(len(b.slots) for b in layout.buckets) == 1


# --------------------------------------------------------------------------- #
# plan_for_tree fallbacks (satellite) vs bucketing
# --------------------------------------------------------------------------- #
def test_plan_for_tree_fallbacks_and_bucketing_removes_them():
    from jax.sharding import PartitionSpec as P

    W = 8
    shapes = {"odd_vec": (33,), "good_mat": (16, 32), "prime": (7, 3),
              "sharded": (64, 32)}
    specs = {"odd_vec": P(), "good_mat": P(), "prime": P(),
             "sharded": P("model", None)}
    plans = X.plan_for_tree("two_phase", shapes, specs, W)
    # no worker-divisible unsharded axis -> sim fallback
    assert plans["odd_vec"]["fallback"] and plans["odd_vec"]["strategy"] == "sim"
    assert plans["prime"]["fallback"]
    # (16, 32): axis 1 divisible by 8 and unsharded -> real two_phase
    assert not plans["good_mat"]["fallback"]
    assert plans["good_mat"]["chunk_axis"] == 1
    # sharded spec blocks axis 0; axis 1 (32) still works
    assert not plans["sharded"]["fallback"]

    # bucketing: every unsharded leaf lands in a bucket whose padded size is
    # divisible by W -> zero fallbacks regardless of leaf shapes
    layout = comm.build_layout(shapes, specs, n_workers=W)
    bucketed_idx = {s.index for b in layout.buckets for s in b.slots}
    assert len(bucketed_idx) == 3           # all but "sharded"
    for b in layout.buckets:
        pb = X.plan_bucket("two_phase", b.size, W)
        assert pb["strategy"] == "two_phase" and not pb["fallback"]

    # and the ledger agrees: seed planner has fallbacks, bucketed has none
    led_seed = comm.CommLedger.from_tree("two_phase", "qsgd8_linf",
                                         shapes, specs, W)
    cplan = comm.plan_comm(layout, "qsgd8_linf", "uniform")
    led_buck = comm.CommLedger.from_plan(layout, cplan, "two_phase", W,
                                         "qsgd8_linf",
                                         leaf_plans=[plans["sharded"]])
    assert led_seed.n_fallbacks() == 2
    assert led_buck.n_fallbacks() == 0
    # without leaf plans the skipped (sharded) leaf is accounted
    # conservatively as a sim fallback
    led_cons = comm.CommLedger.from_plan(layout, cplan, "two_phase", W,
                                         "qsgd8_linf")
    assert led_cons.n_fallbacks() == 1


# --------------------------------------------------------------------------- #
# planner policies
# --------------------------------------------------------------------------- #
def _dcgan_layout(W=8):
    params = dcgan_init(KEY, GANConfig())
    return comm.layout_for_params(params, n_workers=W, bucket_bytes=1 << 20)


def test_planner_uniform():
    layout = _dcgan_layout()
    plan = comm.plan_comm(layout, "qsgd8_linf", "uniform")
    assert all(a.compressor == "qsgd8_linf" for a in plan.assignments)
    assert plan.payload_bytes > 0


def test_planner_size_tiered_protects_small_buckets():
    # bias/norm-sized tensors only -> the whole bucket stays full precision
    shapes = {"b1": (64,), "b2": (128,), "w": (1 << 18,)}
    layout = comm.build_layout(shapes, None, n_workers=2, bucket_bytes=1 << 12)
    plan = comm.plan_comm(layout, "qsgd8_linf", "size_tiered")
    small = [a for b, a in zip(layout.buckets, plan.assignments)
             if all(s.size < comm.planner.SMALL_ELEMS for s in b.slots)]
    big = [a for b, a in zip(layout.buckets, plan.assignments)
           if any(s.size >= comm.planner.SMALL_ELEMS for s in b.slots)]
    assert small and all(a.compressor == "identity" for a in small)
    assert big and all(a.compressor == "qsgd8_linf" for a in big)


def test_planner_delta_budget_meets_budget():
    layout = _dcgan_layout()
    base = comm.plan_comm(layout, "qsgd8_linf", "uniform")
    # generous budget: stays at the base compressor
    rich = comm.plan_comm(layout, "qsgd8_linf", "delta_budget",
                          budget_bytes=2 * base.payload_bytes)
    assert all(a.compressor == "qsgd8_linf" for a in rich.assignments)
    # tight budget: downgrades until under budget, δ degrades monotonically
    tight = comm.plan_comm(layout, "qsgd8_linf", "delta_budget",
                           budget_bytes=base.payload_bytes // 2)
    assert tight.payload_bytes <= base.payload_bytes // 2
    assert tight.min_delta <= rich.min_delta


def test_planner_rejects_unknown_policy():
    with pytest.raises(ValueError):
        comm.plan_comm(_dcgan_layout(), "qsgd8_linf", "bogus")


# --------------------------------------------------------------------------- #
# ledger
# --------------------------------------------------------------------------- #
def test_ledger_allgather_matches_analytic_wire_model():
    """Acceptance: CommLedger byte counts == Compressor.wire_bytes analytic
    model for the allgather strategy (send own + receive W-1 others)."""
    W = 8
    comp = C.get("qsgd8_linf")
    shape = (4096,)
    led = comm.CommLedger()
    led.register("t", "allgather", comp, shape, W)
    expected = comp.wire_bytes(shape, W) * W
    assert led.wire_bytes_per_step == expected
    assert led.wire_bytes_per_step == X.modeled_wire_bytes(
        "allgather", comp, shape, W)
    # int8 codes + f32 scale: carried == analytic for the 8-bit quantizer
    assert led.carried_bytes_per_step == expected


def test_ledger_carried_vs_wire_for_subbyte_codes():
    # sign codes ride in int8 (1B) but model 1 bit on the wire -> carried ≈ 8x
    led = comm.CommLedger()
    led.register("t", "allgather", C.get("sign"), (8192,), 4)
    assert led.carried_bytes_per_step > 6 * led.wire_bytes_per_step


def test_ledger_accumulation_and_ratio():
    led = comm.CommLedger()
    led.register("t", "two_phase", C.get("qsgd8_linf"), (1 << 16,), 8)
    led.tick(10)
    s = led.summary()
    assert s["steps"] == 10
    assert s["cumulative_wire_bytes"] == 10 * s["wire_bytes_per_step"]
    # 8-bit codes vs f32: achieved ratio ≈ 4x under the same collective
    assert 3.5 < s["compression_ratio"] < 4.5


def test_payload_nbytes_matches_manual_count():
    comp = C.get("qsgd8_block256")
    shape = (1000,)
    n_scales = -(-1000 // 256)
    assert comm.payload_nbytes(comp, shape) == 1024 * 1 + 4 * n_scales


# --------------------------------------------------------------------------- #
# bucketed exchange numerics (single worker; multi-worker below)
# --------------------------------------------------------------------------- #
def _mk_trainer(comm_plan, exchange, compressor, ef=True, **kw):
    cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16,
                    hidden=128)
    dq = DQConfig(optimizer="omd", compressor=compressor, exchange=exchange,
                  error_feedback=ef, lr=1e-3, worker_axes=(),
                  comm_plan=comm_plan, bucket_mb=0.25, **kw)
    return DQGAN(field_fn=gan_field_fn(cfg), dq=dq), cfg


def test_bucketed_identity_equals_per_tensor_single_worker():
    tr_b, cfg = _mk_trainer("uniform", "sim", "identity")
    tr_n, _ = _mk_trainer("none", "sim", "identity")
    params = mlp_gan_init(KEY, cfg)
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    st_b, st_n = tr_b.init(params), tr_n.init(params)
    for i in range(3):
        k = jax.random.fold_in(KEY, i)
        st_b = jax.jit(tr_b.step)(st_b, batch, k).state
        st_n = jax.jit(tr_n.step)(st_n, batch, k).state
    for a, b in zip(jax.tree.leaves(st_b.params), jax.tree.leaves(st_n.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_bucketed_qsgd_within_delta_bound():
    """The bucketed compress of a message tree stays a δ-contraction
    (δ ≥ 0.9 for linf 8-bit, as in test_compressors) — padding and tensor
    fusion must not break Definition 1."""
    tr, _ = _mk_trainer("uniform", "sim", "qsgd8_linf", ef=False)
    message = {"a": 0.1 * jax.random.normal(KEY, (128, 64)),
               "b": jax.random.normal(jax.random.fold_in(KEY, 1), (33,))}
    plans = tr._plans(message)
    errs, l2 = [], float(sum(jnp.sum(v**2) for v in jax.tree.leaves(message)))
    for i in range(8):
        qhat, _ = tr._exchange_tree(message, None, plans,
                                    jax.random.fold_in(KEY, 10 + i), ())
        err = sum(float(jnp.sum((q - m) ** 2))
                  for q, m in zip(jax.tree.leaves(qhat),
                                  jax.tree.leaves(message)))
        errs.append(err)
    assert np.mean(errs) <= (1 - 0.9) * l2 + 1e-6


def test_bucketed_two_phase_ef_state_structure():
    tr, cfg = _mk_trainer("uniform", "two_phase", "qsgd8_linf")
    params = mlp_gan_init(KEY, cfg)
    st = tr.init(params)
    assert set(st.ef.keys()) == {"leaf", "bucket"}
    layout, _ = tr._comm(params)
    assert set(st.ef["bucket"].keys()) == {str(b.bid) for b in layout.buckets}
    # training remains finite and EF residuals are bounded
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    for i in range(5):
        out = jax.jit(tr.step)(st, batch, jax.random.fold_in(KEY, i))
        st = out.state
    m = jax.device_get(out.metrics)
    assert np.isfinite(m["loss"]) and np.isfinite(m["error_norm"])
    assert m["error_norm"] > 0  # EF is live


def test_comm_ledger_from_trainer_counts_fallbacks():
    cfg = GANConfig()  # dcgan32: conv biases are not 8-divisible
    dq_seed = DQConfig(exchange="two_phase", compressor="qsgd8_linf",
                       worker_axes=("data",))
    dq_buck = DQConfig(exchange="two_phase", compressor="qsgd8_linf",
                       worker_axes=("data",), comm_plan="uniform")

    class FakeMesh:
        shape = {"data": 8}
    params = dcgan_init(KEY, cfg)
    tr_seed = DQGAN(field_fn=gan_field_fn(cfg), dq=dq_seed, mesh=FakeMesh())
    tr_buck = DQGAN(field_fn=gan_field_fn(cfg), dq=dq_buck, mesh=FakeMesh())
    n_seed = tr_seed.comm_ledger(params).n_fallbacks()
    n_buck = tr_buck.comm_ledger(params).n_fallbacks()
    assert n_seed > 0 and n_buck == 0


# --------------------------------------------------------------------------- #
# fused kernel over bucket tiles
# --------------------------------------------------------------------------- #
def test_quantize_ef_flat_matches_blocked_ref():
    from repro.kernels.quantize import quantize_ef_flat
    from repro.kernels.ref import quantize_ef_ref

    n = 4 * 1024
    g = 0.3 * jax.random.normal(KEY, (n,))
    e = 0.05 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    r = jax.random.uniform(jax.random.fold_in(KEY, 2), (n,))
    codes, scales, e_new = quantize_ef_flat(g, e, r)
    assert codes.shape == (n,) and scales.shape == (n // 1024,)
    cr, sr, er = quantize_ef_ref(g.reshape(-1, 1024), e.reshape(-1, 1024),
                                 r.reshape(-1, 1024))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(cr).reshape(n))
    np.testing.assert_allclose(np.asarray(scales),
                               np.asarray(sr).reshape(-1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_new),
                               np.asarray(er).reshape(n), atol=1e-6)


def test_fused_quantize_ef_contract():
    from repro.core.error_feedback import fused_quantize_ef

    n = 2 * 1024
    m = jax.random.normal(KEY, (n,))
    e = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    payload, m_hat, e_new = fused_quantize_ef(m, e, jax.random.fold_in(KEY, 2))
    np.testing.assert_allclose(np.asarray(m + e - m_hat), np.asarray(e_new),
                               atol=1e-5)
    assert payload["codes"].dtype == jnp.int8
    # payload is wire-compatible with the blocked StochasticQuant: the
    # compressor's own decompress reconstructs the kernel's m_hat
    comp = C.get("qsgd8_block1024")
    deq = comp.decompress(payload, (n,), jnp.float32)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(m_hat), atol=1e-6)


def test_compress_with_ef_dispatches_to_fused_kernel():
    """qsgd8_block1024 over a flat lane-aligned bucket routes through the
    Pallas kernel and honors the EF contract; a non-aligned operand takes
    the plain path with the same (payload, m_hat, e_new) interface."""
    from repro.core.error_feedback import compress_with_ef, fused_compatible

    comp = C.get("qsgd8_block1024")
    flat = jax.random.normal(KEY, (4 * 1024,))
    e = jnp.zeros_like(flat)
    assert fused_compatible(comp, flat)
    payload, m_hat, e_new = compress_with_ef(comp, flat, e, KEY)
    np.testing.assert_allclose(np.asarray(flat - m_hat), np.asarray(e_new),
                               atol=1e-6)
    assert payload["codes"].shape == (4, 1024)

    ragged = jax.random.normal(KEY, (1000,))
    assert not fused_compatible(comp, ragged)
    _, m_hat2, _ = compress_with_ef(comp, ragged, jnp.zeros_like(ragged), KEY)
    assert m_hat2.shape == ragged.shape


def test_bucketed_training_with_fused_compressor():
    tr, cfg = _mk_trainer("uniform", "two_phase", "qsgd8_block1024")
    params = mlp_gan_init(KEY, cfg)
    st = tr.init(params)
    batch = {"real": jax.random.normal(KEY, (64, 2))}
    for i in range(3):
        out = jax.jit(tr.step)(st, batch, jax.random.fold_in(KEY, i))
        st = out.state
    m = jax.device_get(out.metrics)
    assert np.isfinite(m["loss"]) and np.isfinite(m["error_norm"])


# --------------------------------------------------------------------------- #
# multi-worker equivalence (8 forced host devices, subprocess)
# --------------------------------------------------------------------------- #
BUCKETED_EQUIV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro.models.gan import GANConfig, mlp_gan_init, gan_field_fn

mesh = make_mesh((8,), ("data",))
cfg = GANConfig(name="mix", image_size=0, data_dim=2, latent_dim=16, hidden=128)
key = jax.random.key(0)
params = mlp_gan_init(key, cfg)

def run(comm_plan, exch, comp, steps=4):
    dq = DQConfig(optimizer="omd", compressor=comp, exchange=exch,
                  error_feedback=True, lr=1e-2, worker_axes=("data",),
                  comm_plan=comm_plan, bucket_mb=0.25)
    tr = DQGAN(field_fn=gan_field_fn(cfg), dq=dq, mesh=mesh,
               batch_spec=P(("data",)))
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step)
        for i in range(steps):
            batch = {"real": jax.random.normal(jax.random.fold_in(key, i), (64, 2))}
            st = step(st, batch, jax.random.key(7)).state
    return jax.device_get(st.params)

# identity: bucketed == per-tensor for every strategy (exact semantics)
for exch in ("sim", "allgather", "two_phase", "exact"):
    p_none = run("none", exch, "identity")
    p_buck = run("uniform", exch, "identity")
    for a, b in zip(jax.tree.leaves(p_none), jax.tree.leaves(p_buck)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   err_msg=exch)

# quantized: bucketed runs stay near the exact trajectory (δ-bounded drift)
p_exact = run("none", "exact", "identity")
for exch in ("sim", "allgather", "two_phase"):
    p_q = run("uniform", exch, "qsgd8_linf")
    d = sum(float(np.linalg.norm(np.asarray(a) - np.asarray(b)))
            for a, b in zip(jax.tree.leaves(p_exact), jax.tree.leaves(p_q)))
    assert np.isfinite(d) and d < 1.0, (exch, d)
print("OK")
"""


@pytest.mark.multidevice
def test_bucketed_exchange_multiworker_equivalence(multidevice):
    out = multidevice(BUCKETED_EQUIV_SCRIPT)
    assert "OK" in out
