"""repro.sched: exchange schedules, straggler/participation simulation and
the wall-clock model (DESIGN.md §5), plus their core.dqgan integration —
local_k=1 must be bit-exact every_step, delayed must match the reference
staleness recursion, on 1 device here and on 8 forced-host devices via
the `multidevice` subprocess fixture."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched as S
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

KEY = jax.random.key(0)

A = jnp.array(np.linalg.qr(np.random.RandomState(3).randn(6, 6))[0],
              jnp.float32)


def bilinear_field(params, batch, rng):
    del batch, rng
    x, y = params["x"], params["y"]
    return ({"x": A @ y, "y": -(A.T @ x)}, {"loss": x @ A @ y})


BASE = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                error_feedback=True, lr=0.05, worker_axes=())


def _run(dq, steps, field=bilinear_field, ret_state=False):
    tr = DQGAN(field_fn=field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step, static_argnums=(3,))
    sched = S.get(dq.schedule, dq.local_k)
    for i in range(steps):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    return jax.device_get(st if ret_state else st.params)


# --------------------------------------------------------------------------- #
# schedule arithmetic
# --------------------------------------------------------------------------- #
def test_schedule_helpers():
    es = S.get("every_step")
    assert es.period == 1 and es.staleness == 0
    assert all(es.is_exchange_step(i) for i in range(5))
    assert es.exchanges_in(7) == 7

    lk = S.get("local_k", 3)
    assert [lk.is_exchange_step(i) for i in range(7)] == [
        False, False, True, False, False, True, False]
    assert lk.exchanges_in(7) == 2
    assert [lk.round_index(i) for i in range(7)] == [0, 0, 0, 1, 1, 1, 2]

    dl = S.get("delayed")
    assert dl.staleness == 1 and dl.period == 1

    with pytest.raises(ValueError):
        S.get("bogus")
    with pytest.raises(ValueError):
        S.get("local_k", 0)
    with pytest.raises(ValueError):
        S.ExchangeSchedule("delayed", local_k=4)


# --------------------------------------------------------------------------- #
# local_k
# --------------------------------------------------------------------------- #
def test_local_k1_is_bitexact_every_step():
    """K=1 rounds ARE every_step — bit-for-bit, through jit, with a
    stochastic compressor and EF in the loop."""
    p0 = _run(BASE, steps=25)
    p1 = _run(dataclasses.replace(BASE, schedule="local_k", local_k=1),
              steps=25)
    np.testing.assert_array_equal(p0["x"], p1["x"])
    np.testing.assert_array_equal(p0["y"], p1["y"])


def test_local_k_matches_accumulation_reference():
    """K=3 with the identity compressor + exact exchange must follow the
    hand-rolled recursion: messages accumulate locally, params move only
    at round ends by the accumulated update."""
    K, steps, eta = 3, 10, 0.05
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="local_k", local_k=K, lr=eta)
    got = _run(dq, steps=steps)

    w = {"x": np.ones(6, np.float32), "y": np.ones(6, np.float32)}
    gp = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    acc = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    An = np.asarray(A)
    for t in range(steps):
        wh = {k: w[k] - eta * gp[k] for k in w}
        g = {"x": An @ wh["y"], "y": -(An.T @ wh["x"])}
        for k in w:
            acc[k] += eta * g[k]
        if (t + 1) % K == 0:
            for k in w:
                w[k] -= acc[k]
                acc[k] = 0.0
        gp = g
    np.testing.assert_allclose(got["x"], w["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["y"], w["y"], rtol=1e-5, atol=1e-6)


def test_local_k_moves_params_only_at_round_ends():
    dq = dataclasses.replace(BASE, schedule="local_k", local_k=4)
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step, static_argnums=(3,))
    sched = S.get("local_k", 4)
    for i in range(4):
        prev = jax.device_get(st.params)
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
        moved = not np.array_equal(jax.device_get(st.params)["x"], prev["x"])
        assert moved == sched.is_exchange_step(i), i
    # accumulator drained at the round end
    acc = jax.device_get(st.sched["accum"])
    assert all(np.all(a == 0) for a in jax.tree.leaves(acc))


def test_local_k_requires_static_do_exchange():
    dq = dataclasses.replace(BASE, schedule="local_k", local_k=2)
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    with pytest.raises(TypeError):
        jax.jit(tr.step)(st, None, KEY, jnp.array(True))


# --------------------------------------------------------------------------- #
# delayed
# --------------------------------------------------------------------------- #
def test_delayed_matches_reference_staleness_recursion():
    """Identity compressor + exact exchange: the delayed schedule must
    follow    w_half_t = w_{t-1} − P_t − η g_{t-1}
              w_t      = w_{t-1} − P_t          (apply the stale message)
              P_{t+1}  = η g_t                  (this step's message waits)
    where P is the pending buffer and the −P_t term in the lookahead is
    the staleness correction folded into the OMD extrapolation."""
    steps, eta = 12, 0.05
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="delayed", lr=eta)
    got = _run(dq, steps=steps)

    w = {"x": np.ones(6, np.float32), "y": np.ones(6, np.float32)}
    gp = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    P = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    An = np.asarray(A)
    for t in range(steps):
        wh = {k: w[k] - (eta * gp[k] + P[k]) for k in w}
        g = {"x": An @ wh["y"], "y": -(An.T @ wh["x"])}
        for k in w:
            w[k] -= P[k]
            P[k] = eta * g[k]
        gp = g
    np.testing.assert_allclose(got["x"], w["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["y"], w["y"], rtol=1e-5, atol=1e-6)


def test_delayed_first_step_applies_nothing():
    dq = dataclasses.replace(BASE, schedule="delayed")
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    out = jax.jit(tr.step, static_argnums=(3,))(st, None, KEY, True)
    np.testing.assert_array_equal(
        jax.device_get(out.state.params)["x"], np.ones(6, np.float32))
    pend = jax.device_get(out.state.sched["pending"])
    assert any(np.any(p != 0) for p in jax.tree.leaves(pend))


def test_delayed_still_converges_on_bilinear():
    """One step of staleness must not break the OMD contraction (the
    corrected lookahead keeps the extragradient structure)."""
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="delayed", lr=0.1,
                             error_feedback=False)
    p = _run(dq, steps=3000)
    dist = float(np.linalg.norm(p["x"]) + np.linalg.norm(p["y"]))
    assert dist < 0.05, dist


# --------------------------------------------------------------------------- #
# participation (host-side pieces; in-step semantics tested multidevice)
# --------------------------------------------------------------------------- #
def test_participation_counts_and_mask():
    assert S.n_participants(1.0, 8) == 8
    assert S.n_participants(0.5, 8) == 4
    assert S.n_participants(0.01, 8) == 1
    with pytest.raises(ValueError):
        S.n_participants(0.0, 8)
    with pytest.raises(ValueError):
        S.n_participants(1.5, 8)

    m0 = np.asarray(S.round_mask(KEY, 0, 8, 3))
    assert m0.sum() == 3 and set(np.unique(m0)) <= {0.0, 1.0}
    # deterministic per round, varies across rounds
    np.testing.assert_array_equal(m0, np.asarray(S.round_mask(KEY, 0, 8, 3)))
    masks = [tuple(np.asarray(S.round_mask(KEY, r, 8, 3))) for r in range(6)]
    assert len(set(masks)) > 1


# --------------------------------------------------------------------------- #
# stragglers + wall clock
# --------------------------------------------------------------------------- #
def test_straggler_profiles_deterministic():
    none = S.step_times(S.get_profile("none"), 8, 16, seed=0)
    np.testing.assert_array_equal(none, np.ones((16, 8)))
    a = S.step_times(S.get_profile("heavy"), 8, 16, seed=0)
    b = S.step_times(S.get_profile("heavy"), 8, 16, seed=0)
    np.testing.assert_array_equal(a, b)
    c = S.step_times(S.get_profile("heavy"), 8, 16, seed=1)
    assert not np.array_equal(a, c)
    assert (a > 0).all()
    with pytest.raises(ValueError):
        S.get_profile("nope")


def test_clock_schedule_ordering_under_stragglers():
    """The acceptance-criterion inequality: local_k and delayed beat
    every_step per step once comm costs anything, and stragglers widen
    the local_k gap (max-of-sums < sum-of-maxes)."""
    prof = S.get_profile("mild")
    for M in (4, 8, 16):
        times = S.step_times(prof, M, 64, seed=0, base=1e-3)
        t_ex = 2e-3
        every = S.simulate(S.get("every_step"), times, t_ex)
        local = S.simulate(S.get("local_k", 4), times, t_ex)
        delay = S.simulate(S.get("delayed"), times, t_ex)
        assert local["mean_step_s"] < every["mean_step_s"], M
        assert delay["mean_step_s"] < every["mean_step_s"], M
        assert every["n_exchanges"] == 64 and local["n_exchanges"] == 16


def test_clock_delayed_hides_comm_under_compute():
    times = np.ones((32, 8)) * 1e-3
    # comm far cheaper than compute: delayed pays (almost) compute only
    out = S.simulate(S.get("delayed"), times, 1e-5)
    assert out["mean_step_s"] == pytest.approx(1e-3, rel=0.05)
    # comm dominating: delayed pays (almost) comm only, every_step both
    slow = S.simulate(S.get("delayed"), times, 1e-1)
    every = S.simulate(S.get("every_step"), times, 1e-1)
    assert slow["mean_step_s"] == pytest.approx(1e-1, rel=0.05)
    assert every["mean_step_s"] == pytest.approx(1e-1 + 1e-3, rel=0.01)


def test_clock_participation_gates_barrier_on_fewer_workers():
    prof = S.get_profile("heavy")
    times = S.step_times(prof, 8, 64, seed=3, base=1e-3)
    full = S.simulate(S.get("every_step"), times, 1e-3, participation=1.0)
    half = S.simulate(S.get("every_step"), times, 1e-3, participation=0.5)
    assert half["mean_step_s"] < full["mean_step_s"]


def test_speedup_vs_M_monotone_compute_term():
    prof = S.get_profile("none")
    rows = S.speedup_vs_M(S.get("delayed"), prof, (1, 2, 4, 8), steps=32,
                          t_compute_single=1e-2,
                          bytes_fn=lambda M: 1e5)
    sp = [r["speedup"] for r in rows]
    assert sp[0] == pytest.approx(1.0)
    assert sp[-1] > sp[0]


# --------------------------------------------------------------------------- #
# ledger schedule columns
# --------------------------------------------------------------------------- #
def test_ledger_counts_rounds_not_steps():
    from repro.comm import CommLedger
    from repro.core import compressors as C

    led = CommLedger()
    led.register("t", "sim", C.get("qsgd8_linf"), (64, 64), 8)
    per = led.wire_bytes_per_step
    sched = S.get("local_k", 4)
    for i in range(8):
        led.tick(exchanged=sched.is_exchange_step(i), wall_s=0.5)
    assert led.steps == 8 and led.rounds == 2
    assert led.cumulative_wire_bytes == pytest.approx(2 * per)
    assert led.sim_clock_s == pytest.approx(4.0)
    s = led.summary()
    assert s["rounds"] == 2 and s["sim_clock_s"] == pytest.approx(4.0)


# --------------------------------------------------------------------------- #
# multidevice: 8 forced-host workers, shard_map + vmap SPMD paths
# --------------------------------------------------------------------------- #
SCHED_EQUIV_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro import sched as S

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)           # worker-dependent data
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
pspecs = {"x": P(), "y": P()}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0

def run(dq, steps=16):
    tr = DQGAN(field_fn=field, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("data",)))
    sched = S.get(dq.schedule, dq.local_k)
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            st = step(st, batch, jax.random.key(7),
                      sched.is_exchange_step(i)).state
        return jax.device_get(st.params)

base = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                lr=0.05, worker_axes=("data",))
for spmd in ("shard_map", "vmap"):
    b = dataclasses.replace(base, spmd=spmd)
    p0 = run(b)
    p1 = run(dataclasses.replace(b, schedule="local_k", local_k=1))
    np.testing.assert_array_equal(p0["x"], p1["x"])
    np.testing.assert_array_equal(p0["y"], p1["y"])

# delayed, exact+identity, against the M-worker reference recursion
dq = dataclasses.replace(base, compressor="identity", exchange="exact",
                         schedule="delayed", error_feedback=False)
got = run(dq, steps=10)

An = np.asarray(A); eta = 0.05; M = 8
scales = 1.0 + np.arange(M) / 8.0   # mean of each worker's batch slice
w = {k: np.ones(4, np.float32) for k in "xy"}
gp = [{k: np.zeros(4, np.float32) for k in "xy"} for _ in range(M)]
Pd = [{k: np.zeros(4, np.float32) for k in "xy"} for _ in range(M)]
for t in range(10):
    gs = []
    for m in range(M):
        wh = {k: w[k] - (eta * gp[m][k] + Pd[m][k]) for k in w}
        gs.append({"x": scales[m] * (An @ wh["y"]),
                   "y": -scales[m] * (An.T @ wh["x"])})
    qh = {k: np.mean([Pd[m][k] for m in range(M)], axis=0) for k in w}
    for k in w:
        w[k] = w[k] - qh[k]
    for m in range(M):
        Pd[m] = {k: eta * gs[m][k] for k in w}
        gp[m] = gs[m]
np.testing.assert_allclose(got["x"], w["x"], rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(got["y"], w["y"], rtol=1e-4, atol=1e-5)
print("OK")
"""


@pytest.mark.multidevice
def test_sched_equivalences_8dev(multidevice):
    out = multidevice(SCHED_EQUIV_SCRIPT)
    assert "OK" in out


PARTICIPATION_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro import sched as S

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0
key = jax.random.key(7)
M, eta = 8, 0.05

dq = DQConfig(optimizer="omd", compressor="identity", exchange="exact",
              error_feedback=True, lr=eta, worker_axes=("data",),
              participation=0.5)
tr = DQGAN(field_fn=field, dq=dq, mesh=mesh,
           param_specs={"x": P(), "y": P()}, batch_spec=P(("data",)))
with set_mesh(mesh):
    st = tr.init(params)
    out = jax.jit(tr.step, static_argnums=(3,))(st, batch, key, True)
st1 = jax.device_get(out.state)

# reference: q_hat = mean over the round's participants only; the workers
# sitting out keep their message in the EF residual.
mask = np.asarray(S.round_mask(key, 0, M, S.n_participants(0.5, M)))
assert mask.sum() == 4
An = np.asarray(A)
scales = 1.0 + np.arange(M) / 8.0
gs = [{"x": scales[m] * (An @ np.ones(4, np.float32)),
       "y": -scales[m] * (An.T @ np.ones(4, np.float32))} for m in range(M)]
part = [m for m in range(M) if mask[m] == 1.0]
qh = {k: np.mean([eta * gs[m][k] for m in part], axis=0) for k in "xy"}
np.testing.assert_allclose(st1.params["x"], 1.0 - qh["x"], rtol=1e-5,
                           atol=1e-6)
np.testing.assert_allclose(st1.params["y"], 1.0 - qh["y"], rtol=1e-5,
                           atol=1e-6)

# EF: participants untouched (identity => zero residual), absentees carry
# their unsent message eta*g
for m in range(M):
    for k in "xy":
        e1 = np.asarray(st1.ef[k]["e1"])[m]
        want = np.zeros(4) if mask[m] == 1.0 else eta * gs[m][k]
        np.testing.assert_allclose(e1, want, rtol=1e-5, atol=1e-6)
print("OK")
"""


@pytest.mark.multidevice
def test_participation_semantics_8dev(multidevice):
    out = multidevice(PARTICIPATION_SCRIPT)
    assert "OK" in out
