"""repro.sched: exchange schedules, straggler/participation simulation and
the wall-clock model (DESIGN.md §5), plus their core.dqgan integration —
local_k=1 must be bit-exact every_step, delayed must match the reference
staleness recursion, on 1 device here and on 8 forced-host devices via
the `multidevice` subprocess fixture."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched as S
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN

KEY = jax.random.key(0)

A = jnp.array(np.linalg.qr(np.random.RandomState(3).randn(6, 6))[0],
              jnp.float32)


def bilinear_field(params, batch, rng):
    del batch, rng
    x, y = params["x"], params["y"]
    return ({"x": A @ y, "y": -(A.T @ x)}, {"loss": x @ A @ y})


BASE = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                error_feedback=True, lr=0.05, worker_axes=())


def _run(dq, steps, field=bilinear_field, ret_state=False):
    tr = DQGAN(field_fn=field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step, static_argnums=(3,))
    sched = S.get(dq.schedule, dq.local_k)
    for i in range(steps):
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
    return jax.device_get(st if ret_state else st.params)


# --------------------------------------------------------------------------- #
# schedule arithmetic
# --------------------------------------------------------------------------- #
def test_schedule_helpers():
    es = S.get("every_step")
    assert es.period == 1 and es.staleness == 0
    assert all(es.is_exchange_step(i) for i in range(5))
    assert es.exchanges_in(7) == 7

    lk = S.get("local_k", 3)
    assert [lk.is_exchange_step(i) for i in range(7)] == [
        False, False, True, False, False, True, False]
    assert lk.exchanges_in(7) == 2
    assert [lk.round_index(i) for i in range(7)] == [0, 0, 0, 1, 1, 1, 2]

    dl = S.get("delayed")
    assert dl.staleness == 1 and dl.period == 1

    dl4 = S.get("delayed", tau=4)
    assert dl4.staleness == 4 and dl4.period == 1
    assert dl4.describe() == "delayed(tau=4)"
    assert S.get("delayed").describe() == "delayed"

    with pytest.raises(ValueError):
        S.get("bogus")
    with pytest.raises(ValueError):
        S.get("local_k", 0)
    with pytest.raises(ValueError):
        S.ExchangeSchedule("delayed", local_k=4)
    with pytest.raises(ValueError):
        S.get("delayed", tau=0)
    with pytest.raises(ValueError):
        S.ExchangeSchedule("local_k", 2, tau=3)
    with pytest.raises(ValueError):
        S.ExchangeSchedule("every_step", tau=2)


# --------------------------------------------------------------------------- #
# local_k
# --------------------------------------------------------------------------- #
def test_local_k1_is_bitexact_every_step():
    """K=1 rounds ARE every_step — bit-for-bit, through jit, with a
    stochastic compressor and EF in the loop."""
    p0 = _run(BASE, steps=25)
    p1 = _run(dataclasses.replace(BASE, schedule="local_k", local_k=1),
              steps=25)
    np.testing.assert_array_equal(p0["x"], p1["x"])
    np.testing.assert_array_equal(p0["y"], p1["y"])


def test_local_k_matches_accumulation_reference():
    """K=3 with the identity compressor + exact exchange must follow the
    hand-rolled recursion: messages accumulate locally, params move only
    at round ends by the accumulated update."""
    K, steps, eta = 3, 10, 0.05
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="local_k", local_k=K, lr=eta)
    got = _run(dq, steps=steps)

    w = {"x": np.ones(6, np.float32), "y": np.ones(6, np.float32)}
    gp = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    acc = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    An = np.asarray(A)
    for t in range(steps):
        wh = {k: w[k] - eta * gp[k] for k in w}
        g = {"x": An @ wh["y"], "y": -(An.T @ wh["x"])}
        for k in w:
            acc[k] += eta * g[k]
        if (t + 1) % K == 0:
            for k in w:
                w[k] -= acc[k]
                acc[k] = 0.0
        gp = g
    np.testing.assert_allclose(got["x"], w["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["y"], w["y"], rtol=1e-5, atol=1e-6)


def test_local_k_moves_params_only_at_round_ends():
    dq = dataclasses.replace(BASE, schedule="local_k", local_k=4)
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    step = jax.jit(tr.step, static_argnums=(3,))
    sched = S.get("local_k", 4)
    for i in range(4):
        prev = jax.device_get(st.params)
        st = step(st, None, KEY, sched.is_exchange_step(i)).state
        moved = not np.array_equal(jax.device_get(st.params)["x"], prev["x"])
        assert moved == sched.is_exchange_step(i), i
    # accumulator drained at the round end
    acc = jax.device_get(st.sched["accum"])
    assert all(np.all(a == 0) for a in jax.tree.leaves(acc))


def test_local_k_requires_static_do_exchange():
    dq = dataclasses.replace(BASE, schedule="local_k", local_k=2)
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    with pytest.raises(TypeError):
        jax.jit(tr.step)(st, None, KEY, jnp.array(True))


# --------------------------------------------------------------------------- #
# delayed
# --------------------------------------------------------------------------- #
def test_delayed_matches_reference_staleness_recursion():
    """Identity compressor + exact exchange: the delayed schedule must
    follow    w_half_t = w_{t-1} − P_t − η g_{t-1}
              w_t      = w_{t-1} − P_t          (apply the stale message)
              P_{t+1}  = η g_t                  (this step's message waits)
    where P is the pending buffer and the −P_t term in the lookahead is
    the staleness correction folded into the OMD extrapolation."""
    steps, eta = 12, 0.05
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="delayed", lr=eta)
    got = _run(dq, steps=steps)

    w = {"x": np.ones(6, np.float32), "y": np.ones(6, np.float32)}
    gp = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    P = {"x": np.zeros(6, np.float32), "y": np.zeros(6, np.float32)}
    An = np.asarray(A)
    for t in range(steps):
        wh = {k: w[k] - (eta * gp[k] + P[k]) for k in w}
        g = {"x": An @ wh["y"], "y": -(An.T @ wh["x"])}
        for k in w:
            w[k] -= P[k]
            P[k] = eta * g[k]
        gp = g
    np.testing.assert_allclose(got["x"], w["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["y"], w["y"], rtol=1e-5, atol=1e-6)


def test_delayed_first_step_applies_nothing():
    dq = dataclasses.replace(BASE, schedule="delayed")
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    out = jax.jit(tr.step, static_argnums=(3,))(st, None, KEY, True)
    np.testing.assert_array_equal(
        jax.device_get(out.state.params)["x"], np.ones(6, np.float32))
    pend = jax.device_get(out.state.sched["pending"])
    assert any(np.any(p != 0) for p in jax.tree.leaves(pend))


def test_delayed_still_converges_on_bilinear():
    """One step of staleness must not break the OMD contraction (the
    corrected lookahead keeps the extragradient structure)."""
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="delayed", lr=0.1,
                             error_feedback=False)
    p = _run(dq, steps=3000)
    dist = float(np.linalg.norm(p["x"]) + np.linalg.norm(p["y"]))
    assert dist < 0.05, dist


# --------------------------------------------------------------------------- #
# delayed(tau): the bounded-staleness parameter-server pipeline (DESIGN.md §8)
# --------------------------------------------------------------------------- #
def test_delayed_tau1_is_bitexact_delayed():
    """delayed(tau=1) IS PR 2's delayed — same single-slot layout, same
    compiled graph — bit-for-bit through jit with a stochastic compressor
    and EF in the loop."""
    p0 = _run(dataclasses.replace(BASE, schedule="delayed"), steps=25)
    p1 = _run(dataclasses.replace(BASE, schedule="delayed", staleness_tau=1),
              steps=25)
    np.testing.assert_array_equal(p0["x"], p1["x"])
    np.testing.assert_array_equal(p0["y"], p1["y"])


@pytest.mark.parametrize("tau", [1, 2, 3, 4])
def test_delayed_tau_matches_reference_recursion(tau):
    """Identity compressor + exact exchange: delayed(τ) must follow the
    τ-step recursion (the τ=1 case is PR 2's frozen delayed reference):
        w_half_t = w_{t-1} − Σ_j R_t[j] − η g_{t-1}
        w_t      = w_{t-1} − R_t[0]          (apply the τ-stale message)
        R_{t+1}  = [R_t[1:], η g_t]          (ring shift)
    """
    steps, eta = 14, 0.05
    dq = dataclasses.replace(BASE, compressor="identity", exchange="exact",
                             schedule="delayed", staleness_tau=tau, lr=eta,
                             error_feedback=False)
    got = _run(dq, steps=steps)

    An = np.asarray(A)
    w = {k: np.ones(6, np.float32) for k in "xy"}
    gp = {k: np.zeros(6, np.float32) for k in "xy"}
    R = {k: np.zeros((tau, 6), np.float32) for k in "xy"}
    for t in range(steps):
        wh = {k: w[k] - (eta * gp[k] + R[k].sum(0)) for k in w}
        g = {"x": An @ wh["y"], "y": -(An.T @ wh["x"])}
        for k in w:
            w[k] -= R[k][0]
            R[k] = np.concatenate([R[k][1:], (eta * g[k])[None]], 0)
        gp = g
    np.testing.assert_allclose(got["x"], w["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["y"], w["y"], rtol=1e-5, atol=1e-6)


def test_delayed_tau_warmup_and_staleness_metrics():
    """The first τ steps apply zero messages (pipeline fill) and the
    version vector makes the staleness metric read exactly τ."""
    tau = 3
    dq = dataclasses.replace(BASE, schedule="delayed", staleness_tau=tau)
    tr = DQGAN(field_fn=bilinear_field, dq=dq)
    st = tr.init({"x": jnp.ones(6), "y": jnp.ones(6)})
    assert st.sched["pending"]["x"].shape == (1, tau, 6)
    assert int(st.sched["versions"][0]) == -tau
    step = jax.jit(tr.step, static_argnums=(3,))
    for i in range(tau + 2):
        out = step(st, None, KEY, True)
        st = out.state
        m = jax.device_get(out.metrics)
        assert m["staleness_max"] == tau and m["staleness_mean"] == tau
        if i < tau:  # pipeline fill: nothing applied yet
            np.testing.assert_array_equal(
                jax.device_get(st.params)["x"], np.ones(6, np.float32))
    assert not np.array_equal(jax.device_get(st.params)["x"],
                              np.ones(6, np.float32))
    # every ring slot is a live in-flight message after warmup
    pend = jax.device_get(st.sched["pending"])
    assert all(np.all(np.any(p[0] != 0, axis=tuple(range(1, p[0].ndim))))
               for p in jax.tree.leaves(pend))


def test_staleness_tau_config_validation():
    with pytest.raises(ValueError):
        DQGAN(field_fn=bilinear_field,
              dq=dataclasses.replace(BASE, staleness_tau=2)).init(
                  {"x": jnp.ones(6), "y": jnp.ones(6)})
    with pytest.raises(ValueError):
        DQGAN(field_fn=bilinear_field,
              dq=dataclasses.replace(BASE, schedule="delayed",
                                     staleness_tau=0)).init(
                  {"x": jnp.ones(6), "y": jnp.ones(6)})


# --------------------------------------------------------------------------- #
# participation (host-side pieces; in-step semantics tested multidevice)
# --------------------------------------------------------------------------- #
def test_participation_counts_and_mask():
    assert S.n_participants(1.0, 8) == 8
    assert S.n_participants(0.5, 8) == 4
    assert S.n_participants(0.01, 8) == 1
    with pytest.raises(ValueError):
        S.n_participants(0.0, 8)
    with pytest.raises(ValueError):
        S.n_participants(1.5, 8)

    m0 = np.asarray(S.round_mask(KEY, 0, 8, 3))
    assert m0.sum() == 3 and set(np.unique(m0)) <= {0.0, 1.0}
    # deterministic per round, varies across rounds
    np.testing.assert_array_equal(m0, np.asarray(S.round_mask(KEY, 0, 8, 3)))
    masks = [tuple(np.asarray(S.round_mask(KEY, r, 8, 3))) for r in range(6)]
    assert len(set(masks)) > 1


# --------------------------------------------------------------------------- #
# stragglers + wall clock
# --------------------------------------------------------------------------- #
def test_straggler_profiles_deterministic():
    none = S.step_times(S.get_profile("none"), 8, 16, seed=0)
    np.testing.assert_array_equal(none, np.ones((16, 8)))
    a = S.step_times(S.get_profile("heavy"), 8, 16, seed=0)
    b = S.step_times(S.get_profile("heavy"), 8, 16, seed=0)
    np.testing.assert_array_equal(a, b)
    c = S.step_times(S.get_profile("heavy"), 8, 16, seed=1)
    assert not np.array_equal(a, c)
    assert (a > 0).all()
    with pytest.raises(ValueError):
        S.get_profile("nope")


def test_clock_schedule_ordering_under_stragglers():
    """The acceptance-criterion inequality: local_k and delayed beat
    every_step per step once comm costs anything, and stragglers widen
    the local_k gap (max-of-sums < sum-of-maxes)."""
    prof = S.get_profile("mild")
    for M in (4, 8, 16):
        times = S.step_times(prof, M, 64, seed=0, base=1e-3)
        t_ex = 2e-3
        every = S.simulate(S.get("every_step"), times, t_ex)
        local = S.simulate(S.get("local_k", 4), times, t_ex)
        delay = S.simulate(S.get("delayed"), times, t_ex)
        assert local["mean_step_s"] < every["mean_step_s"], M
        assert delay["mean_step_s"] < every["mean_step_s"], M
        assert every["n_exchanges"] == 64 and local["n_exchanges"] == 16


def test_clock_delayed_hides_comm_under_compute():
    times = np.ones((32, 8)) * 1e-3
    # comm far cheaper than compute: delayed pays (almost) compute only
    out = S.simulate(S.get("delayed"), times, 1e-5)
    assert out["mean_step_s"] == pytest.approx(1e-3, rel=0.05)
    # comm dominating: delayed pays (almost) comm only, every_step both
    slow = S.simulate(S.get("delayed"), times, 1e-1)
    every = S.simulate(S.get("every_step"), times, 1e-1)
    assert slow["mean_step_s"] == pytest.approx(1e-1, rel=0.05)
    assert every["mean_step_s"] == pytest.approx(1e-1 + 1e-3, rel=0.01)


def test_clock_participation_gates_barrier_on_fewer_workers():
    prof = S.get_profile("heavy")
    times = S.step_times(prof, 8, 64, seed=3, base=1e-3)
    full = S.simulate(S.get("every_step"), times, 1e-3, participation=1.0)
    half = S.simulate(S.get("every_step"), times, 1e-3, participation=0.5)
    assert half["mean_step_s"] < full["mean_step_s"]


def test_speedup_vs_M_monotone_compute_term():
    prof = S.get_profile("none")
    rows = S.speedup_vs_M(S.get("delayed"), prof, (1, 2, 4, 8), steps=32,
                          t_compute_single=1e-2,
                          bytes_fn=lambda M: 1e5)
    sp = [r["speedup"] for r in rows]
    assert sp[0] == pytest.approx(1.0)
    assert sp[-1] > sp[0]


def test_baseline_mean_step_shared_across_schedules():
    """The hoisted M=1 baseline (benchmarks.run bugfix): with one worker
    and no comm every schedule walks the same compute times, so the
    shared baseline must equal each schedule's own M=1 simulation."""
    prof = S.get_profile("mild")
    base = S.baseline_mean_step(prof, 48, 2e-3, seed=3)
    for sch in (S.get("every_step"), S.get("local_k", 4), S.get("delayed"),
                S.get("delayed", tau=4)):
        own = S.time_per_step(sch, prof, 1, 48, 2e-3, 0.0,
                              seed=3)["mean_step_s"]
        assert own == pytest.approx(base, rel=1e-12), sch.describe()


# --------------------------------------------------------------------------- #
# versioned parameter server (sched.server, DESIGN.md §8)
# --------------------------------------------------------------------------- #
def test_versioned_server_semantics():
    srv = S.VersionedServer(n_workers=4, tau=2)
    assert [srv.pull(m) for m in range(4)] == [0, 0, 0, 0]
    for m in range(4):
        assert srv.push(m) == 0
    assert srv.version == 1          # one round = n_workers pushes
    # worker 0 never re-pulls: staleness grows one version per round; its
    # round-3 push lands exactly AT the bound (staleness 2), then trips it
    for _ in range(2):
        for m in range(4):
            if m:
                srv.pull(m)
            srv.push(m)
    assert srv.staleness(0) == 3 and not srv.can_push(0)
    with pytest.raises(S.StalenessBoundExceeded):
        srv.push(0)                  # 3 versions behind: bound trips
    srv.pull(0)
    assert srv.push(0) == 0          # re-pull resets the staleness
    with pytest.raises(ValueError):
        S.VersionedServer(n_workers=4, tau=0)


def test_server_partial_rounds():
    srv = S.VersionedServer(n_workers=4, tau=1, n_round=2)
    srv.pull(0), srv.pull(1)
    srv.push(0)
    assert srv.version == 0
    srv.push(0)                      # duplicate: same round, not a close
    assert srv.version == 0
    srv.push(1)
    assert srv.version == 1          # 2 DISTINCT participants close a round


ADVERSARIAL = S.StragglerProfile("adversarial", slowdown_sigma=1.0,
                                 jitter_sigma=0.3, spike_prob=0.3,
                                 spike_factor=20.0)


@pytest.mark.parametrize("profile",
                         [S.get_profile("heavy"), ADVERSARIAL])
def test_push_pull_staleness_bounded(profile):
    """The SSP gate: whatever the stragglers do, no applied contribution
    is ever more than τ versions stale — and the extra slack makes the
    modeled clock monotone non-increasing in τ."""
    times = S.step_times(profile, 8, 96, seed=11, base=1e-3)
    prev_total = None
    for tau in (1, 2, 4, 8):
        out = S.simulate_push_pull(times, 2e-3, tau)
        assert out["staleness_max"] <= tau, (tau, out["staleness_max"])
        assert out["staleness_mean"] <= out["staleness_max"]
        assert out["n_exchanges"] == 96
        if prev_total is not None:
            assert out["total_s"] <= prev_total * (1 + 1e-9), tau
        prev_total = out["total_s"]
    # determinism
    a = S.simulate_push_pull(times, 2e-3, 4)
    b = S.simulate_push_pull(times, 2e-3, 4)
    np.testing.assert_array_equal(a["per_step_s"], b["per_step_s"])


def test_push_pull_participation_staleness_consistent():
    """Under partial participation a round's aggregate can be *ready*
    before a straggler-gated earlier round — the server still applies
    versions in order, so the staleness bookkeeping stays valid and the
    participant bound ≤ τ holds."""
    times = S.step_times(ADVERSARIAL, 8, 96, seed=5, base=1e-3)
    for tau in (1, 2, 4):
        out = S.simulate_push_pull(times, 2e-3, tau, participation=0.5)
        assert out["staleness_max"] <= tau, (tau, out["staleness_max"])
        assert 0.0 <= out["staleness_mean"] <= out["staleness_max"]
        full = S.simulate_push_pull(times, 2e-3, tau)
        assert out["total_s"] <= full["total_s"] * (1 + 1e-9)


def test_clock_routes_delayed_tau_to_server_dataflow():
    times = S.step_times(S.get_profile("mild"), 8, 32, seed=0, base=1e-3)
    auto = S.simulate(S.get("delayed", tau=4), times, 2e-3)
    forced = S.simulate_push_pull(times, 2e-3, 4)
    assert auto["tau"] == 4
    np.testing.assert_array_equal(auto["per_step_s"], forced["per_step_s"])
    # delayed(1) default stays on PR 2's synchronous pipelined model ...
    sync = S.simulate(S.get("delayed"), times, 2e-3)
    assert "tau" not in sync
    # ... unless the server dataflow is forced (the τ-frontier sweep)
    srv1 = S.simulate(S.get("delayed"), times, 2e-3, dataflow="server")
    assert srv1["tau"] == 1 and srv1["staleness_max"] <= 1
    with pytest.raises(ValueError):
        S.simulate(S.get("delayed"), times, 2e-3, dataflow="bogus")
    # only delayed has a push/pull loop to model
    with pytest.raises(ValueError):
        S.simulate(S.get("local_k", 4), times, 2e-3, dataflow="server")
    with pytest.raises(ValueError):
        S.simulate(S.get("every_step"), times, 2e-3, dataflow="server")


def test_benchmark_regression_gate():
    """Rows match on the structural strategy hash (PR 4), not the
    schedule/compressor label strings."""
    from benchmarks.run import check_sched_regression

    base = {"rows": [{"schedule": "delayed", "compressor": "8bit", "M": 8,
                      "strategy": "aaa111", "mean_step_s": 1.0,
                      "wire_mb": 10.0}],
            "tau_frontier": [{"tau": 4, "strategy": "bbb222",
                              "mean_step_s": 0.5, "wire_mb": 5.0}]}
    ok = {"rows": [{"schedule": "delayed", "compressor": "8bit", "M": 8,
                    "strategy": "aaa111", "mean_step_s": 1.05,
                    "wire_mb": 10.0}],
          "tau_frontier": [{"tau": 4, "strategy": "bbb222",
                            "mean_step_s": 0.4, "wire_mb": 5.0}]}
    assert check_sched_regression(ok, base) == []
    bad = {"rows": [{"schedule": "delayed", "compressor": "8bit", "M": 8,
                     "strategy": "aaa111", "mean_step_s": 1.2,
                     "wire_mb": 10.0}],
           "tau_frontier": [{"tau": 4, "strategy": "bbb222",
                             "mean_step_s": 0.5, "wire_mb": 5.6}]}
    fails = check_sched_regression(bad, base)
    assert len(fails) == 2
    assert any("mean_step_s" in f for f in fails)
    assert any("tau_frontier" in f and "wire_mb" in f for f in fails)
    # new rows (no baseline counterpart) never gate — including a row
    # whose LABELS match the baseline but whose strategy differs
    # structurally (this was a bogus comparison under name matching);
    # at least one row must still match or the gate refuses outright
    extra = {"rows": [{"schedule": "delayed", "compressor": "8bit", "M": 8,
                       "strategy": "aaa111", "mean_step_s": 1.0,
                       "wire_mb": 10.0},
                      {"schedule": "delayed", "compressor": "8bit", "M": 8,
                       "strategy": "ccc333", "mean_step_s": 9.9,
                       "wire_mb": 99.0}]}
    assert check_sched_regression(extra, base) == []
    # a baseline predating the strategy hashes is refused outright
    legacy = {"rows": [{"schedule": "delayed", "compressor": "8bit",
                        "M": 8, "mean_step_s": 1.0, "wire_mb": 10.0}]}
    fails = check_sched_regression(ok, legacy)
    assert len(fails) == 1 and "pre-strategy" in fails[0]


def test_mixture_gan_schedule_overrides_smoke():
    """The tau-frontier convergence path: train_mixture_gan must accept
    dq_overrides and drive the schedule-aware step (static do_exchange)
    for delayed(tau) — a 3-step smoke so CI catches plumbing breaks
    without paying the full frontier sweep."""
    from benchmarks.gan_common import train_mixture_gan

    final, curve, st = train_mixture_gan(
        "DQGAN", steps=3, batch=32,
        dq_overrides={"schedule": "delayed", "staleness_tau": 2})
    assert {"modes", "hq_frac", "fid"} <= set(final)
    # every pending leaf carries the (worker, τ) ring axes
    assert all(l.shape[:2] == (1, 2)
               for l in jax.tree.leaves(st.sched["pending"]))
    assert int(jax.device_get(st.step)) == 3


def test_benchmark_gate_rejects_tier_mismatch(tmp_path):
    """Running the gate at a different tier than the baseline (wire_mb
    scales with steps) must exit with a config error, not spurious
    regressions."""
    import json
    import os
    import subprocess
    import sys as _sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(repo, "experiments/baselines/"
                                 "sched_quick.json")) as f:
        doctored = json.load(f)
    doctored["steps"] = 256          # pretend the baseline was full-tier
    bad = tmp_path / "sched_full_baseline.json"
    bad.write_text(json.dumps(doctored))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [_sys.executable, "-m", "benchmarks.run", "--quick", "--only",
         "sched", "--check-against", str(bad)],
        capture_output=True, text=True, timeout=300, cwd=repo, env=env)
    assert proc.returncode == 2, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "tier mismatch" in proc.stdout


def test_bench_roofline_rows_ride_the_sink(tmp_path):
    """The roofline section is a first-class benchmarks.run citizen: one
    row per dry-run record through row() -> bench_row events, explicit
    reporting when the records are absent (never a silent skip)."""
    import json as _json

    import benchmarks.run as BR
    from repro import obs

    rec = {"arch": "gemma-2b", "shape": "train_4k", "mesh": "16x16",
           "layout": "dp", "status": "ok", "params": 2e9, "chips": 256,
           "mf": 1e15, "analytic_flops": 1.5e15, "flops": 1e12,
           "bottleneck": "collective",
           "roofline": {"compute_s": 1e-3, "memory_s": 2e-3,
                        "collective_s": 5e-3}}
    d = tmp_path / "dryrun"
    d.mkdir()
    (d / "a.json").write_text(_json.dumps(rec))
    path = str(tmp_path / "bench.jsonl")
    BR._SINK = obs.make_sink(path)
    try:
        recs = BR.bench_roofline(True, dirpath=str(d))
        # a missing records dir is itself a reported row
        none = BR.bench_roofline(True, dirpath=str(tmp_path / "absent"))
    finally:
        BR._SINK.close()
        BR._SINK = None
    assert len(recs) == 1 and none == []
    evs = [e for e in obs.read_events(path) if e["kind"] == "bench_row"]
    assert [e["name"] for e in evs] == [
        "roofline/gemma-2b/train_4k/16x16", "roofline/none"]
    assert "bottleneck=collective" in evs[0]["derived"]
    assert "no dry-run records" in evs[1]["derived"]
    assert (tmp_path / "roofline.md").exists()


# --------------------------------------------------------------------------- #
# ledger schedule columns
# --------------------------------------------------------------------------- #
def test_ledger_counts_rounds_not_steps():
    from repro.comm import CommLedger
    from repro.core import compressors as C

    led = CommLedger()
    led.register("t", "sim", C.get("qsgd8_linf"), (64, 64), 8)
    per = led.wire_bytes_per_step
    sched = S.get("local_k", 4)
    for i in range(8):
        led.tick(exchanged=sched.is_exchange_step(i), wall_s=0.5)
    assert led.steps == 8 and led.rounds == 2
    assert led.cumulative_wire_bytes == pytest.approx(2 * per)
    assert led.sim_clock_s == pytest.approx(4.0)
    s = led.summary()
    assert s["rounds"] == 2 and s["sim_clock_s"] == pytest.approx(4.0)


# --------------------------------------------------------------------------- #
# multidevice: 8 forced-host workers, shard_map + vmap SPMD paths
# --------------------------------------------------------------------------- #
SCHED_EQUIV_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro import sched as S

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)           # worker-dependent data
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
pspecs = {"x": P(), "y": P()}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0

def run(dq, steps=16):
    tr = DQGAN(field_fn=field, dq=dq, mesh=mesh, param_specs=pspecs,
               batch_spec=P(("data",)))
    sched = S.get(dq.schedule, dq.local_k)
    with set_mesh(mesh):
        st = tr.init(params)
        step = jax.jit(tr.step, static_argnums=(3,))
        for i in range(steps):
            st = step(st, batch, jax.random.key(7),
                      sched.is_exchange_step(i)).state
        return jax.device_get(st.params)

base = DQConfig(optimizer="omd", compressor="qsgd8_linf", exchange="sim",
                lr=0.05, worker_axes=("data",))
for spmd in ("shard_map", "vmap"):
    b = dataclasses.replace(base, spmd=spmd)
    p0 = run(b)
    p1 = run(dataclasses.replace(b, schedule="local_k", local_k=1))
    np.testing.assert_array_equal(p0["x"], p1["x"])
    np.testing.assert_array_equal(p0["y"], p1["y"])
    # delayed(tau=1) is bit-exact PR 2 delayed (stochastic compressor + EF)
    d0 = run(dataclasses.replace(b, schedule="delayed"))
    d1 = run(dataclasses.replace(b, schedule="delayed", staleness_tau=1))
    np.testing.assert_array_equal(d0["x"], d1["x"])
    np.testing.assert_array_equal(d0["y"], d1["y"])

# delayed(tau), uncompressed, against the M-worker reference recursion
# (tau=1 is PR 2's frozen delayed reference; tau=2 exercises the ring).
# identity+'sim' IS the exact mean, and unlike 'exact' it composes with
# spmd='vmap' (non-sim exchange kinds there are refused since PR 4).
An = np.asarray(A); eta = 0.05; M = 8
scales = 1.0 + np.arange(M) / 8.0   # mean of each worker's batch slice
for spmd in ("shard_map", "vmap"):
    for tau in (1, 2):
        dq = dataclasses.replace(base, spmd=spmd, compressor="identity",
                                 exchange="sim", schedule="delayed",
                                 staleness_tau=tau, error_feedback=False)
        got = run(dq, steps=10)

        w = {k: np.ones(4, np.float32) for k in "xy"}
        gp = [{k: np.zeros(4, np.float32) for k in "xy"} for _ in range(M)]
        Rd = [{k: np.zeros((tau, 4), np.float32) for k in "xy"}
              for _ in range(M)]
        for t in range(10):
            gs = []
            for m in range(M):
                wh = {k: w[k] - (eta * gp[m][k] + Rd[m][k].sum(0))
                      for k in w}
                gs.append({"x": scales[m] * (An @ wh["y"]),
                           "y": -scales[m] * (An.T @ wh["x"])})
            qh = {k: np.mean([Rd[m][k][0] for m in range(M)], axis=0)
                  for k in w}
            for k in w:
                w[k] = w[k] - qh[k]
            for m in range(M):
                Rd[m] = {k: np.concatenate([Rd[m][k][1:],
                                            (eta * gs[m][k])[None]], 0)
                         for k in w}
                gp[m] = gs[m]
        np.testing.assert_allclose(got["x"], w["x"], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(got["y"], w["y"], rtol=1e-4, atol=1e-5)
print("OK")
"""


@pytest.mark.multidevice
def test_sched_equivalences_8dev(multidevice):
    out = multidevice(SCHED_EQUIV_SCRIPT)
    assert "OK" in out


PARTICIPATION_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro import sched as S

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0
key = jax.random.key(7)
M, eta = 8, 0.05

# (identity + 'sim' is numerically the exact mean; partial participation
# with exchange='exact' is refused at Strategy construction since PR 4)
dq = DQConfig(optimizer="omd", compressor="identity", exchange="sim",
              error_feedback=True, lr=eta, worker_axes=("data",),
              participation=0.5)
tr = DQGAN(field_fn=field, dq=dq, mesh=mesh,
           param_specs={"x": P(), "y": P()}, batch_spec=P(("data",)))
with set_mesh(mesh):
    st = tr.init(params)
    out = jax.jit(tr.step, static_argnums=(3,))(st, batch, key, True)
st1 = jax.device_get(out.state)

# reference: q_hat = mean over the round's participants only; the workers
# sitting out keep their message in the EF residual.
mask = np.asarray(S.round_mask(key, 0, M, S.n_participants(0.5, M)))
assert mask.sum() == 4
An = np.asarray(A)
scales = 1.0 + np.arange(M) / 8.0
gs = [{"x": scales[m] * (An @ np.ones(4, np.float32)),
       "y": -scales[m] * (An.T @ np.ones(4, np.float32))} for m in range(M)]
part = [m for m in range(M) if mask[m] == 1.0]
qh = {k: np.mean([eta * gs[m][k] for m in part], axis=0) for k in "xy"}
np.testing.assert_allclose(st1.params["x"], 1.0 - qh["x"], rtol=1e-5,
                           atol=1e-6)
np.testing.assert_allclose(st1.params["y"], 1.0 - qh["y"], rtol=1e-5,
                           atol=1e-6)

# EF: participants untouched (identity => zero residual), absentees carry
# their unsent message eta*g
for m in range(M):
    for k in "xy":
        e1 = np.asarray(st1.ef[k]["e1"])[m]
        want = np.zeros(4) if mask[m] == 1.0 else eta * gs[m][k]
        np.testing.assert_allclose(e1, want, rtol=1e-5, atol=1e-6)
print("OK")
"""


@pytest.mark.multidevice
def test_participation_semantics_8dev(multidevice):
    out = multidevice(PARTICIPATION_SCRIPT)
    assert "OK" in out


PARTICIPATION_TAU_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, set_mesh
from repro.configs.base import DQConfig
from repro.core.dqgan import DQGAN
from repro import sched as S

A = jnp.array(np.random.RandomState(0).randn(4,4), jnp.float32)
def field(params, batch, rng):
    x, y = params["x"], params["y"]
    s = 1.0 + jnp.mean(batch)
    return {"x": s * (A @ y), "y": -s * (A.T @ x)}, {"loss": x @ A @ y}

mesh = make_mesh((8,), ("data",))
params = {"x": jnp.ones(4), "y": jnp.ones(4)}
batch = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) / 8.0
key = jax.random.key(7)
M, eta, tau, steps = 8, 0.05, 2, 6
n = S.n_participants(0.5, M)

dq = DQConfig(optimizer="omd", compressor="identity", exchange="sim",
              error_feedback=True, lr=eta, worker_axes=("data",),
              schedule="delayed", staleness_tau=tau, participation=0.5)
tr = DQGAN(field_fn=field, dq=dq, mesh=mesh,
           param_specs={"x": P(), "y": P()}, batch_spec=P(("data",)))
with set_mesh(mesh):
    st = tr.init(params)
    step = jax.jit(tr.step, static_argnums=(3,))
    stale_maxes = []
    for i in range(steps):
        out = step(st, batch, key, True)
        st = out.state
        stale_maxes.append(float(jax.device_get(out.metrics)["staleness_max"]))
got = jax.device_get(st)

# numpy reference: delayed(tau) ring x count-exact participation. A
# participant sends its ring head + residual (identity: sent exactly,
# residual drains); a skipper sends zero and folds the head into e1 —
# the skipped round extends its staleness (version not advanced) while
# the ring stays clamped at depth tau.
masks = [np.asarray(S.round_mask(key, t, M, n)) for t in range(steps)]
An = np.asarray(A)
scales = 1.0 + np.arange(M) / 8.0
w = {k: np.ones(4, np.float32) for k in "xy"}
gp = [{k: np.zeros(4, np.float32) for k in "xy"} for _ in range(M)]
Rd = [{k: np.zeros((tau, 4), np.float32) for k in "xy"} for _ in range(M)]
e1 = [{k: np.zeros(4, np.float32) for k in "xy"} for _ in range(M)]
ver = np.full(M, -tau)
ref_stale_max = []
for t in range(steps):
    mask = masks[t]
    gs = []
    for m in range(M):
        wh = {k: w[k] - (eta * gp[m][k] + e1[m][k] + Rd[m][k].sum(0))
              for k in w}
        gs.append({"x": scales[m] * (An @ wh["y"]),
                   "y": -scales[m] * (An.T @ wh["x"])})
    part = [m for m in range(M) if mask[m] == 1.0]
    qh = {k: np.mean([Rd[m][k][0] + e1[m][k] for m in part], axis=0)
          for k in w}
    for k in w:
        w[k] = w[k] - qh[k]
    for m in range(M):
        for k in w:
            if mask[m] != 1.0:
                e1[m][k] = e1[m][k] + Rd[m][k][0]   # unsent head rides EF
            else:
                e1[m][k] = np.zeros(4, np.float32)  # identity: drained
            Rd[m][k] = np.concatenate([Rd[m][k][1:],
                                       (eta * gs[m][k])[None]], 0)
        if mask[m] == 1.0:
            ver[m] = t - tau
        gp[m] = gs[m]
    ref_stale_max.append(float((t - ver).max()))

np.testing.assert_allclose(got.params["x"], w["x"], rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(got.params["y"], w["y"], rtol=1e-5, atol=1e-6)
for m in range(M):
    for k in "xy":
        np.testing.assert_allclose(np.asarray(got.ef[k]["e1"])[m],
                                   e1[m][k], rtol=1e-5, atol=1e-6)
# version vector: skipped rounds count toward staleness, participants
# reset to exactly tau
np.testing.assert_array_equal(np.asarray(got.sched["versions"]), ver)
assert stale_maxes == ref_stale_max, (stale_maxes, ref_stale_max)
assert max(stale_maxes) > tau       # someone actually skipped a round
print("OK")
"""


@pytest.mark.multidevice
def test_participation_tau_composition_8dev(multidevice):
    """participation × τ: a skipped round extends that worker's staleness
    (version vector frozen) while its unsent ring head is preserved in
    the EF residual — asserted against a full numpy reference."""
    out = multidevice(PARTICIPATION_TAU_SCRIPT)
    assert "OK" in out
